"""Per-layer ("entry") assembly: norm → mixer (attn | mamba) → norm → ffn
(dense MLP | MoE), with gemma-style optional post-norms.  Entries are the
elements of ``cfg.layer_pattern``; a stack of ``n_units`` repetitions is
scanned over in the model (stacked-parameter scan keeps HLO size and compile
time flat in depth)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import attn_decode, attn_train, init_attn, prefill_fill_cache
from .common import rms_norm
from .mamba import init_mamba, mamba_decode, mamba_train
from .mlp import init_mlp, mlp
from .moe import DistCtx, init_moe, moe_apply

__all__ = ["init_entry", "entry_train", "entry_prefill", "entry_decode"]


def _has_ffn(cfg: ModelConfig, idx: int) -> Optional[str]:
    """What follows the mixer at pattern position ``idx``:
    'moe' | 'mlp' | None (pure-mamba archs fold the MLP into the mixer)."""
    if cfg.is_moe_layer(idx):
        return "moe"
    if cfg.d_ff > 0:
        return "mlp"
    return None


def init_entry(cfg: ModelConfig, kind: str, idx: int, key, cross: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    p: Dict = {"ln1": jnp.zeros((D,), dtype=pd)}
    if kind == "mamba":
        p["mamba"] = init_mamba(cfg, ks[0])
    else:
        p["attn"] = init_attn(cfg, ks[0])
    if cfg.post_norms:
        p["ln1_post"] = jnp.zeros((D,), dtype=pd)
    if cross:  # whisper decoder: add a cross-attention sub-block
        p["ln_x"] = jnp.zeros((D,), dtype=pd)
        p["xattn"] = init_attn(cfg, ks[3], cross=True)
    ffn = _has_ffn(cfg, idx)
    if ffn:
        p["ln2"] = jnp.zeros((D,), dtype=pd)
        if cfg.post_norms:
            p["ln2_post"] = jnp.zeros((D,), dtype=pd)
        if ffn == "moe":
            p["moe"] = init_moe(cfg, ks[1])
        else:
            p["mlp"] = init_mlp(cfg, ks[1])
    return p


def _ffn_apply(cfg, idx, p, x, dist=None):
    ffn = _has_ffn(cfg, idx)
    if ffn is None:
        return x, 0.0
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    if ffn == "moe":
        h, aux = moe_apply(cfg, p["moe"], h, dist)
    else:
        h, aux = mlp(cfg, p["mlp"], h), 0.0
    if cfg.post_norms:
        h = rms_norm(h, p["ln2_post"], cfg.rms_eps)
    return x + h, aux


def entry_train(
    cfg: ModelConfig,
    kind: str,
    idx: int,
    p: Dict,
    x: jax.Array,
    *,
    causal: bool = True,
    enc_out: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    dist: Optional[DistCtx] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (x, aux_loss)."""
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    if kind == "mamba":
        h = mamba_train(cfg, p["mamba"], h)
    else:
        h = attn_train(cfg, p["attn"], h, kind, causal=causal,
                       q_chunk=q_chunk, dist=dist)
    if cfg.post_norms:
        h = rms_norm(h, p["ln1_post"], cfg.rms_eps)
    x = x + h
    if enc_out is not None:  # whisper decoder cross-attention
        h = rms_norm(x, p["ln_x"], cfg.rms_eps)
        h = attn_train(cfg, p["xattn"], h, "global", kv_source=enc_out,
                       causal=False, q_chunk=q_chunk)
        x = x + h
    return _ffn_apply(cfg, idx, p, x, dist)


def entry_prefill(
    cfg: ModelConfig,
    kind: str,
    idx: int,
    p: Dict,
    x: jax.Array,
    cache_len: int,
    *,
    enc_out: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    cache_dtype=jnp.bfloat16,
    dist: Optional[DistCtx] = None,
) -> Tuple[jax.Array, Dict]:
    """Forward + build this entry's decode cache."""
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    cache: Dict = {}
    if kind == "mamba":
        h, cache = mamba_train(cfg, p["mamba"], h, return_cache=True)
    else:
        h, (k, v) = attn_train(
            cfg, p["attn"], h, kind, q_chunk=q_chunk, return_kv=True,
            dist=dist,
        )
        cache = prefill_fill_cache(cfg, kind, k, v, cache_len, cache_dtype)
    if cfg.post_norms:
        h = rms_norm(h, p["ln1_post"], cfg.rms_eps)
    x = x + h
    if enc_out is not None:
        h = rms_norm(x, p["ln_x"], cfg.rms_eps)
        dt = x.dtype
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        Senc = enc_out.shape[1]
        kx = (enc_out @ p["xattn"]["wk"].astype(dt)).reshape(-1, Senc, KV, hd)
        vx = (enc_out @ p["xattn"]["wv"].astype(dt)).reshape(-1, Senc, KV, hd)
        h = attn_train(cfg, p["xattn"], h, "global", kv_source=enc_out,
                       causal=False, q_chunk=q_chunk)
        x = x + h
        cache = {"self": cache, "cross_k": kx.astype(cache_dtype),
                 "cross_v": vx.astype(cache_dtype)}
    x, _ = _ffn_apply(cfg, idx, p, x, dist)
    return x, cache


def entry_decode(
    cfg: ModelConfig,
    kind: str,
    idx: int,
    p: Dict,
    x: jax.Array,  # (B, 1, D)
    cache: Dict,
    pos: jax.Array,
    dist: Optional[DistCtx] = None,
) -> Tuple[jax.Array, Dict]:
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    is_encdec_entry = "cross_k" in cache
    self_cache = cache["self"] if is_encdec_entry else cache
    if kind == "mamba":
        h, new_self = mamba_decode(cfg, p["mamba"], h, self_cache)
    else:
        h, new_self = attn_decode(cfg, p["attn"], h, kind, self_cache, pos)
    if cfg.post_norms:
        h = rms_norm(h, p["ln1_post"], cfg.rms_eps)
    x = x + h
    if is_encdec_entry:
        h = rms_norm(x, p["ln_x"], cfg.rms_eps)
        h, _ = attn_decode(
            cfg, p["xattn"], h, "global", {}, pos,
            cross_kv=(cache["cross_k"], cache["cross_v"]),
        )
        x = x + h
        new_cache = dict(cache)
        new_cache["self"] = new_self
    else:
        new_cache = new_self
    x, _ = _ffn_apply(cfg, idx, p, x, dist)
    return x, new_cache
