"""Dense MLP blocks: gated (llama/gemma-style) and plain (starcoder/whisper)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import activation, normal_init

__all__ = ["init_mlp", "mlp"]


def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None) -> Dict:
    d_ff = d_ff or cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {
        "w_up": normal_init(ks[0], (cfg.d_model, d_ff), dtype=pd),
        "w_down": normal_init(ks[1], (d_ff, cfg.d_model), dtype=pd),
    }
    if cfg.mlp_gated:
        p["w_gate"] = normal_init(ks[2], (cfg.d_model, d_ff), dtype=pd)
    return p


def mlp(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    act = activation(cfg.act)
    up = x @ params["w_up"].astype(dt)
    if cfg.mlp_gated:
        gate = act(x @ params["w_gate"].astype(dt))
        h = gate * up
    else:
        h = act(up)
    return h @ params["w_down"].astype(dt)
