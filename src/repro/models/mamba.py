"""Mamba-2 SSD (state-space duality) block — TPU-native SSM.

The selective scan is computed in its *dual* chunked-matmul form
(arXiv 2405.21060 §6): within chunks of length Q the recurrence becomes
dense attention-like matmuls (MXU work); across chunks a short
``lax.scan`` passes the (H, P, N) state.  This is the hardware adaptation
recorded in DESIGN §4 — Jamba's Mamba-1 layers also run through this block
(d_state 16 preserved).

Block layout (mamba_ssm convention):
  in_proj: D → [z (d_inner), x (d_inner), B (G·N), C (G·N), dt (H)]
  causal depthwise conv (width 4) over the [x, B, C] channels
  SSD core, per-head RMS-norm gated by z, out_proj: d_inner → D.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import normal_init, rms_norm

__all__ = ["init_mamba", "mamba_train", "mamba_decode", "init_mamba_cache"]


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    H = cfg.ssm_n_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_d_state
    G = cfg.ssm_n_groups
    conv_ch = d_in + 2 * G * N
    return d_in, H, P, N, G, conv_ch


def init_mamba(cfg: ModelConfig, key) -> Dict:
    pd = jnp.dtype(cfg.param_dtype)
    d_in, H, P, N, G, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * G * N + H
    return {
        "in_proj": normal_init(ks[0], (cfg.d_model, proj_out), dtype=pd),
        "conv_w": normal_init(ks[1], (cfg.conv_width, conv_ch), dtype=pd),
        "conv_b": jnp.zeros((conv_ch,), dtype=pd),
        "A_log": jnp.zeros((H,), dtype=pd),  # A = -exp(A_log) ∈ (-∞, 0)
        "D": jnp.ones((H,), dtype=pd),
        "dt_bias": jnp.zeros((H,), dtype=pd),
        "norm_w": jnp.zeros((d_in,), dtype=pd),
        "out_proj": normal_init(ks[4], (d_in, cfg.d_model), dtype=pd),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_in, H, P, N, G, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype=dtype),
        "state": jnp.zeros((batch, H, P, N), dtype=dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in, H, P, N, G, _ = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along time. xBC (B, L, C); w (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(W):  # width is 4 — unrolled taps, still one fused HLO
        out = out + pad[:, i : i + xBC.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def _segsum(dA: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] =
    Σ_{k=j+1..i} dA[..., k] for i ≥ j, -inf above the diagonal."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) — post-softplus
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B, L, G, N)
    Cm: jax.Array,  # (B, L, G, N)
    chunk: int,
    init_state: jax.Array | None = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B, L, H, P), final_state (B, H, P, N))."""
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (L + pad) // Q

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, Q, G, N), rep, axis=3)  # (B,c,Q,H,N)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, Q, G, N), rep, axis=3)

    dA = dtc * A.astype(f32)  # (B, c, Q, H)
    dA_cs = jnp.cumsum(dA, axis=2)  # within-chunk cumsum

    # ---- intra-chunk (block-diagonal) term --------------------------------
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B, c, H, Q, Q)
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc, preferred_element_type=f32)
    xdt = xc.astype(f32) * dtc[..., None]  # (B,c,Q,H,P)
    y_diag = jnp.einsum(
        "bchqk,bchqk,bckhp->bcqhp",
        CB, Lmat, xdt,
        preferred_element_type=f32,
    )
    # note: einsum above multiplies CB ⊙ L then contracts k

    # ---- chunk states -------------------------------------------------------
    decay_tail = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B,c,Q,H)
    S_chunk = jnp.einsum(
        "bcqhn,bcqh,bcqhp->bchpn", Bc, decay_tail, xdt,
        preferred_element_type=f32,
    )
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (B, c, H)

    # ---- inter-chunk recurrence (short scan over nc) -----------------------
    def scan_fn(carry, xs):
        S_c, decay_c = xs  # (B,H,P,N), (B,H)
        new = S_c + decay_c[..., None, None] * carry
        return new, carry  # emit the state *entering* this chunk

    init = (
        init_state.astype(f32)
        if init_state is not None
        else jnp.zeros((Bsz, H, P, N), f32)
    )
    final_state, S_in = jax.lax.scan(
        scan_fn,
        init,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    S_in = S_in.transpose(1, 0, 2, 3, 4)  # (B, c, H, P, N)

    # ---- inter-chunk output term ---------------------------------------------
    state_decay = jnp.exp(dA_cs)  # (B,c,Q,H)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Cc, S_in, state_decay,
        preferred_element_type=f32,
    )

    y = (y_diag + y_off).reshape(Bsz, nc * Q, H, P)[:, :L]
    return y, final_state


def mamba_train(
    cfg: ModelConfig,
    params: Dict,
    x: jax.Array,
    *,
    return_cache: bool = False,
) -> jax.Array | Tuple[jax.Array, Dict]:
    """Full-sequence SSD forward. x: (B, L, D)."""
    Bsz, L, D = x.shape
    d_in, H, P, N, G, conv_ch = _dims(cfg)
    dt_ = x.dtype

    proj = x @ params["in_proj"].astype(dt_)
    z, xBC, dt_raw = _split_proj(cfg, proj)
    xBC = _causal_conv(xBC, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_))
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(Bsz, L, H, P)
    Bm = Bm.reshape(Bsz, L, G, N)
    Cm = Cm.reshape(Bsz, L, G, N)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    y, last_state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, L, d_in).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.rms_eps)
    out = y @ params["out_proj"].astype(dt_)
    if return_cache:
        # conv cache holds the *pre-conv* channel inputs of the last
        # (width-1) steps, taken from the pre-conv projection:
        proj_tail = proj[:, max(0, L - (cfg.conv_width - 1)) :]
        _, xBC_tail, _ = _split_proj(cfg, proj_tail)
        pad_t = cfg.conv_width - 1 - xBC_tail.shape[1]
        if pad_t:
            xBC_tail = jnp.pad(xBC_tail, ((0, 0), (pad_t, 0), (0, 0)))
        cache = {
            "conv": xBC_tail.astype(jnp.float32),
            "state": last_state,
        }
        return out, cache
    return out


def mamba_decode(
    cfg: ModelConfig, params: Dict, x: jax.Array, cache: Dict
) -> Tuple[jax.Array, Dict]:
    """Single-token recurrent step. x: (B, 1, D) — O(1) in context length."""
    Bsz = x.shape[0]
    d_in, H, P, N, G, conv_ch = _dims(cfg)
    dt_ = x.dtype

    proj = x[:, 0] @ params["in_proj"].astype(dt_)  # (B, proj_out)
    z, xBC_new, dt_raw = _split_proj(cfg, proj)

    # conv ring: window = [cache, new]
    win = jnp.concatenate(
        [cache["conv"], xBC_new[:, None].astype(cache["conv"].dtype)], axis=1
    )  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", win, params["conv_w"].astype(win.dtype))
    xBC = jax.nn.silu(conv_out + params["conv_b"].astype(win.dtype))
    new_conv = win[:, 1:]

    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(Bsz, H, P).astype(jnp.float32)
    rep = H // G
    Bm = jnp.repeat(Bm.reshape(Bsz, G, N), rep, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cm.reshape(Bsz, G, N), rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B, H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)  # (B, H)

    # S' = decay·S + dt·x ⊗ B ;  y = (S'·C) + D·x
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xs, Bm, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Cm)
    y = y + xs * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, d_in).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.rms_eps)
    out = (y @ params["out_proj"].astype(dt_))[:, None]
    return out, {"conv": new_conv, "state": state}
