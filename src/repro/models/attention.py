"""GQA attention for the assigned architectures.

Covers: grouped-query attention, RoPE (per-kind base for gemma3), sliding
window / local layers, attention-logit softcapping (gemma2), QK-norm
(gemma3/olmoe), gemma2 query scaling, encoder (bidirectional) and
cross-attention (whisper), KV caches (full, ring/window), and
**q-chunked attention** for long sequences: scores are materialized only
per q-chunk — (B, H, chunk, S_k) — which bounds activation memory for the
32k prefill shapes; local layers additionally slice keys to the window, so
their compute is O(S · W), not O(S²).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import apply_rope, normal_init, rms_norm, softcap

__all__ = ["init_attn", "attn_train", "attn_decode", "init_attn_cache"]

NEG_INF = -2.0**30  # large-but-finite: keeps fully-masked rows NaN-free


def _rope_base(cfg: ModelConfig, kind: str) -> float:
    if kind == "local" and cfg.rope_base_local is not None:
        return cfg.rope_base_local
    return cfg.rope_base


def _scale(cfg: ModelConfig) -> float:
    if cfg.query_scale is not None:
        return cfg.query_scale**-0.5
    return float(cfg.head_dim) ** -0.5


def _head_shard(cfg: ModelConfig, dist, q, k, v):
    """Pin q/k/v to an explicit head-axis sharding (§Perf hillclimb #1).

    The fused projection dim (KV·G·hd) shards cleanly over ``model``, but
    its reshape to (KV, G, hd) does not — GSPMD then shards the *head_dim
    contraction* and all-reduces the (B, KV, G, qc, S) scores every q-chunk.
    Constraining the head axis with the least padding (KV vs G; uneven dims
    are allowed in sharding constraints) keeps scores device-local: 2× padded
    compute at worst instead of TB-scale score reductions.
    """
    if dist is None or cfg.attn_head_shard == "none" or not dist.head_shard:
        return q, k, v
    mesh = dist.mesh
    if "model" not in mesh.axis_names:
        return q, k, v
    from jax.sharding import NamedSharding, PartitionSpec as P

    ms = mesh.shape["model"]
    if cfg.n_heads % ms == 0:
        # the fused-dim sharding already lands exactly on head boundaries —
        # GSPMD shards heads cleanly on its own; constraining here only
        # forces resharding (measured: gemma2-9b train 14.0 → 23.9 s coll)
        return q, k, v
    KV, G = cfg.n_kv_heads, cfg.n_heads // max(cfg.n_kv_heads, 1)
    waste_kv = (-(-KV // ms) * ms) / KV if KV else 1e9
    waste_g = (-(-G // ms) * ms) / G if G else 1e9
    if min(waste_kv, waste_g) > 2.0:
        return q, k, v  # padding waste would exceed the comm it saves
    b = dist.moe_axes if dist.moe_axes else None
    if waste_kv <= waste_g:
        q_spec = P(b, None, "model", None, None)  # (B,S,KV,G,hd)
        kv_spec = P(b, None, "model", None)  # (B,S,KV,hd)
    else:
        q_spec = P(b, None, None, "model", None)
        kv_spec = P(b, None, None, None)  # k/v replicated across model
    c = lambda x, s: jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, s)
    )
    return c(q, q_spec), c(k, kv_spec), c(v, kv_spec)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attn(cfg: ModelConfig, key, cross: bool = False) -> Dict:
    """Attention parameter subtree. Weights stored fused:
    wq (D, H·hd), wk/wv (D, KV·hd), wo (H·hd, D)."""
    ks = jax.random.split(key, 6)
    D = cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": normal_init(ks[0], (D, H * hd), dtype=pd),
        "wk": normal_init(ks[1], (D, KV * hd), dtype=pd),
        "wv": normal_init(ks[2], (D, KV * hd), dtype=pd),
        "wo": normal_init(ks[3], (H * hd, D), dtype=pd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype=pd)
        p["k_norm"] = jnp.zeros((hd,), dtype=pd)
    return p


def init_attn_cache(
    cfg: ModelConfig, kind: str, batch: int, cache_len: int, dtype=jnp.bfloat16
) -> Dict:
    """KV cache for one attention layer.  Local layers keep a ring buffer of
    ``window`` slots (keys cached post-RoPE, so ring order is irrelevant —
    softmax is permutation-invariant over the key set)."""
    cap = cache_len
    if kind == "local" and cfg.window:
        cap = min(cfg.window, cache_len)
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, cap, KV, hd), dtype=dtype),
        "v": jnp.zeros((batch, cap, KV, hd), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# q-chunked attention (train / prefill)
# ---------------------------------------------------------------------------


def _attend_chunk(q, k, v, mask, scale, cap):
    """q (B, qc, KV, G, hd); k/v (B, Sk, KV, hd); mask (qc, Sk) or None."""
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    scores = softcap(scores, cap)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def attn_train(
    cfg: ModelConfig,
    params: Dict,
    x: jax.Array,
    kind: str,
    *,
    positions: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    causal: bool = True,
    kv_source: Optional[jax.Array] = None,
    return_kv: bool = False,
    dist=None,
) -> jax.Array | Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (training / prefill).

    ``kind``: "global" (causal full), "local" (causal windowed).
    ``causal=False`` gives the whisper encoder (bidirectional).
    ``kv_source``: cross-attention (keys/values from the encoder output).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    dt = x.dtype

    src = x if kv_source is None else kv_source
    Sk = src.shape[1]
    q = (x @ params["wq"].astype(dt)).reshape(B, S, KV, G, hd)
    k = (src @ params["wk"].astype(dt)).reshape(B, Sk, KV, hd)
    v = (src @ params["wv"].astype(dt)).reshape(B, Sk, KV, hd)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)

    if kv_source is None:  # self-attention gets RoPE
        pos = (
            positions
            if positions is not None
            else jnp.arange(S, dtype=jnp.int32)[None, :]
        )
        base = _rope_base(cfg, kind)
        q = apply_rope(q.reshape(B, S, KV * G, hd), pos, base).reshape(
            B, S, KV, G, hd
        )
        k = apply_rope(k, pos, base)

    q, k, v = _head_shard(cfg, dist, q, k, v)
    scale = _scale(cfg)
    cap = cfg.attn_logit_softcap
    window = cfg.window if kind == "local" else None

    qc = min(q_chunk, S)
    pad = (-S) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    nq = (S + pad) // qc
    qs = q.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    if window is not None and causal and kv_source is None and Sk > window + qc:
        # local layers: slice keys to [start, start + W + qc) per q chunk —
        # compute is O(S·W) instead of O(S²)
        W = window
        kwin = W + qc

        def body(i, qi):
            q0 = i * qc
            start = jnp.maximum(0, q0 - W)
            # clamp so the static-size slice stays in bounds
            start = jnp.minimum(start, Sk - kwin)
            ks = jax.lax.dynamic_slice_in_dim(k, start, kwin, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kwin, axis=1)
            qpos = q0 + jnp.arange(qc)
            kpos = start + jnp.arange(kwin)
            m = (
                (qpos[:, None] >= kpos[None, :])
                & (qpos[:, None] - kpos[None, :] < W)
            )
            return _attend_chunk(qi, ks, vs, m, scale, cap)

        # remat per chunk: backward replays one q-chunk at a time, so probs
        # never materialize beyond (B, KV, G, qc, W+qc) — flash-style memory
        out = jax.lax.map(
            jax.checkpoint(lambda iq: body(iq[0], iq[1])),
            (jnp.arange(nq), qs),
        )
    else:

        def body(i, qi):
            qpos = i * qc + jnp.arange(qc)
            kpos = jnp.arange(Sk)
            if causal and kv_source is None:
                m = qpos[:, None] >= kpos[None, :]
                if window is not None:
                    m &= qpos[:, None] - kpos[None, :] < window
            else:
                m = None
            return _attend_chunk(qi, k, v, m, scale, cap)

        out = jax.lax.map(
            jax.checkpoint(lambda iq: body(iq[0], iq[1])),
            (jnp.arange(nq), qs),
        )

    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S + pad, H * hd)[:, :S]
    out = out @ params["wo"].astype(dt)
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# decode (one token against a cache)
# ---------------------------------------------------------------------------


def attn_decode(
    cfg: ModelConfig,
    params: Dict,
    x: jax.Array,  # (B, 1, D)
    kind: str,
    cache: Dict,
    pos: jax.Array,  # scalar int32 — number of tokens already in cache
    *,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Dict]:
    B, _, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    dt = x.dtype

    q = (x @ params["wq"].astype(dt)).reshape(B, 1, KV, G, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
    vector_pos = hasattr(pos, "ndim") and pos.ndim == 1  # per-seq positions
    if vector_pos:
        posb = pos[:, None].astype(jnp.int32)
    else:
        posb = jnp.full((B, 1), pos, dtype=jnp.int32)
    base = _rope_base(cfg, kind)
    q = apply_rope(q.reshape(B, 1, KV * G, hd), posb, base).reshape(
        B, 1, KV, G, hd
    )
    scale = _scale(cfg)
    cap = cfg.attn_logit_softcap

    if cross_kv is not None:
        k, v = cross_kv  # (B, S_enc, KV, hd) — static, no cache update
        scores = (
            jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
            * scale
        )
        scores = softcap(scores, cap)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = (
            jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
            .reshape(B, 1, H * hd)
            .astype(dt)
        )
        return out @ params["wo"].astype(dt), cache

    k_new = (x @ params["wk"].astype(dt)).reshape(B, 1, KV, hd)
    v_new = (x @ params["wv"].astype(dt)).reshape(B, 1, KV, hd)
    if cfg.qk_norm:
        k_new = rms_norm(k_new, params["k_norm"], cfg.rms_eps)
    k_new = apply_rope(k_new, posb, base)

    cap_len = cache["k"].shape[1]
    is_ring = kind == "local" and cfg.window is not None
    if vector_pos:
        slot = pos % cap_len if is_ring else jnp.minimum(pos, cap_len - 1)
        onehot = (jnp.arange(cap_len)[None, :] == slot[:, None])  # (B, S)
        ck = jnp.where(
            onehot[:, :, None, None],
            k_new.astype(cache["k"].dtype),
            cache["k"],
        )
        cv = jnp.where(
            onehot[:, :, None, None],
            v_new.astype(cache["v"].dtype),
            cache["v"],
        )
        idx = jnp.arange(cap_len)[None, :]
        valid = (idx <= slot[:, None]) | (pos[:, None] >= cap_len)  # (B, S)
        vmask = valid[:, None, None, None, :]
    else:
        slot = pos % cap_len if is_ring else jnp.minimum(pos, cap_len - 1)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1
        )
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1
        )
        # validity: ring buffers are fully valid once wrapped; otherwise ≤ pos
        idx = jnp.arange(cap_len)
        valid = (idx <= slot) | (pos >= cap_len)
        vmask = valid[None, None, None, None, :]
    scores = (
        jnp.einsum(
            "bqkgh,bskh->bkgqs", q, ck.astype(dt), preferred_element_type=jnp.float32
        )
        * scale
    )
    scores = softcap(scores, cap)
    scores = jnp.where(vmask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, cv.astype(dt)).reshape(
        B, 1, H * hd
    )
    out = out @ params["wo"].astype(dt)
    return out, {"k": ck, "v": cv}


def prefill_fill_cache(
    cfg: ModelConfig,
    kind: str,
    k: jax.Array,
    v: jax.Array,
    cache_len: int,
    dtype=jnp.bfloat16,
) -> Dict:
    """Build a cache from full-sequence K/V (post-RoPE) after prefill."""
    B, S = k.shape[0], k.shape[1]
    cap = cache_len
    if kind == "local" and cfg.window:
        cap = min(cfg.window, cache_len)
    if S >= cap:
        ks, vs = k[:, S - cap : S], v[:, S - cap : S]
        # ring layout: element at position p lives in slot p % cap
        slots = (jnp.arange(S - cap, S)) % cap if kind == "local" and cfg.window else jnp.arange(cap)
        ck = jnp.zeros((B, cap) + k.shape[2:], dtype).at[:, slots].set(ks.astype(dtype))
        cv = jnp.zeros((B, cap) + v.shape[2:], dtype).at[:, slots].set(vs.astype(dtype))
    else:
        ck = jnp.zeros((B, cap) + k.shape[2:], dtype).at[:, :S].set(k.astype(dtype))
        cv = jnp.zeros((B, cap) + v.shape[2:], dtype).at[:, :S].set(v.astype(dtype))
    return {"k": ck, "v": cv}
