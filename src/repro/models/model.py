"""Top-level models: decoder-only LM (dense / MoE / SSM / hybrid / VLM) and
encoder–decoder (whisper), built from scanned stacks of pattern units.

Public functional API (everything jit/pjit-able):
  init_params(cfg, key)                  → params pytree
  train_loss(cfg, params, batch)         → scalar loss
  prefill(cfg, params, batch, cache_len) → (caches, last_logits)
  decode_step(cfg, params, batch, caches, pos) → (logits, new_caches)
  init_caches(cfg, batch, cache_len)     → zeroed caches (decode dry-run)
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import init_attn_cache
from .blocks import entry_decode, entry_prefill, entry_train, init_entry
from .common import chunked_cross_entropy, rms_norm, sinusoidal_positions, softcap
from .mamba import init_mamba_cache
from .moe import DistCtx

__all__ = [
    "init_params",
    "train_loss",
    "prefill",
    "decode_step",
    "init_caches",
    "count_params",
]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Dict:
    pd = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.n_units + cfg.n_enc_layers + 4)
    pattern = cfg.layer_pattern
    cross = cfg.family == "encdec"

    units = []
    for u in range(cfg.n_units):
        eks = jax.random.split(keys[u], len(pattern))
        unit = {
            f"e{i}": init_entry(cfg, kind, i, eks[i], cross=cross)
            for i, kind in enumerate(pattern)
        }
        units.append(unit)

    params: Dict = {
        "embed": 0.02 * jax.random.normal(
            keys[-1], (cfg.vocab_size, cfg.d_model)
        ).astype(pd),
        "final_norm": jnp.zeros((cfg.d_model,), dtype=pd),
        "units": _stack_trees(units),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = 0.02 * jax.random.normal(
            keys[-2], (cfg.vocab_size, cfg.d_model)
        ).astype(pd)

    if cfg.family == "encdec":
        enc_units = [
            {"e0": init_entry(cfg, "global", 0, keys[cfg.n_units + u])}
            for u in range(cfg.n_enc_layers)
        ]
        params["enc_units"] = _stack_trees(enc_units)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype=pd)
    return params


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via abstract init (no allocation)."""
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if active_only and "moe" in keys and keys[-1] in (
            "w_up", "w_down", "w_gate"
        ):
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# Shared forward plumbing
# ---------------------------------------------------------------------------


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "full": save only inputs


def _sp_constrain(x, dist):
    """Sequence-parallel activation constraint at unit boundaries."""
    if dist is None or not dist.sp_axes:
        return x
    import numpy as _np
    from jax.sharding import NamedSharding, PartitionSpec as P

    size = int(_np.prod([dist.mesh.shape[a] for a in dist.sp_axes]))
    if x.ndim != 3 or x.shape[1] % size != 0:
        return x  # uneven seq (whisper's 1500 frames): leave unconstrained
    spec = P(dist.moe_axes if dist.moe_axes else None, dist.sp_axes, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(dist.mesh, spec))


def _scan_units(cfg: ModelConfig, units, x, entry_fn, dist=None):
    """Scan over stacked pattern units. ``entry_fn(unit_params, x) -> (x, aux)``."""

    def body(carry, unit_p):
        h, aux = carry
        h = _sp_constrain(h, dist)
        h, a = entry_fn(unit_p, h)
        return (h, aux + a), None

    body = _remat_wrap(cfg, body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), units)
    return x, aux


def _embed_tokens(cfg: ModelConfig, params, tokens):
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.scale_embed:
        h = h * jnp.sqrt(float(cfg.d_model)).astype(h.dtype)
    return h


def _vocab_weight(cfg: ModelConfig, params):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over stub frame embeddings (B, S_enc, D)."""
    pos = sinusoidal_positions(frames.shape[1], cfg.d_model)
    h = frames.astype(jnp.dtype(cfg.dtype)) + jnp.asarray(
        pos, dtype=cfg.dtype
    )[None]
    h, _ = _scan_units(
        cfg, params["enc_units"], h,
        lambda up, hh: entry_train(cfg, "global", 0, up["e0"], hh, causal=False),
    )
    return rms_norm(h, params["enc_norm"], cfg.rms_eps)


def _decoder_inputs(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, int]:
    """Token (+modality) embeddings.  Returns (embeds, n_prefix) where
    n_prefix = positions that carry no next-token loss (VLM patches)."""
    h = _embed_tokens(cfg, params, batch["tokens"])
    n_prefix = 0
    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(h.dtype)
        h = jnp.concatenate([vis, h], axis=1)
        n_prefix = vis.shape[1]
    if cfg.family == "encdec":
        pos = sinusoidal_positions(h.shape[1], cfg.d_model)
        h = h + jnp.asarray(pos, dtype=h.dtype)[None]
    return h, n_prefix


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def train_loss(
    cfg: ModelConfig, params: Dict, batch: Dict, *, q_chunk: int = 1024,
    dist: Optional[DistCtx] = None,
) -> jax.Array:
    """Next-token cross-entropy (+ MoE aux).  ``batch``:
      tokens (B, S) int32; labels (B, S) int32
      [vlm]  vision_embeds (B, P, D)
      [encdec] frames (B, S_enc, D)
    """
    h, n_prefix = _decoder_inputs(cfg, params, batch)
    enc_out = (
        _encode(cfg, params, batch["frames"]) if cfg.family == "encdec" else None
    )
    pattern = cfg.layer_pattern

    def entry_fn(unit_p, hh):
        aux = jnp.float32(0.0)
        for i, kind in enumerate(pattern):
            hh, a = entry_train(
                cfg, kind, i, unit_p[f"e{i}"], hh,
                enc_out=enc_out, q_chunk=q_chunk, dist=dist,
            )
            aux = aux + a
        return hh, aux

    h, aux = _scan_units(cfg, params["units"], h, entry_fn, dist=dist)
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    if n_prefix:
        h = h[:, n_prefix:]
    loss = chunked_cross_entropy(
        h,
        _vocab_weight(cfg, params).astype(h.dtype),
        batch["labels"],
        chunk=cfg.loss_chunk,
        final_softcap=cfg.final_logit_softcap,
    )
    return loss + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# Serve: prefill + decode
# ---------------------------------------------------------------------------


def init_caches(
    cfg: ModelConfig, batch: int, cache_len: int, cache_dtype=jnp.bfloat16
) -> Dict:
    """Zeroed caches with the exact decode-time structure (stacked units)."""
    pattern = cfg.layer_pattern
    cross = cfg.family == "encdec"
    unit_caches = []
    for u in range(cfg.n_units):
        entry = {}
        for i, kind in enumerate(pattern):
            if kind == "mamba":
                c = init_mamba_cache(cfg, batch)
            else:
                c = init_attn_cache(cfg, kind, batch, cache_len, cache_dtype)
            if cross:
                c = {
                    "self": c,
                    "cross_k": jnp.zeros(
                        (batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim),
                        cache_dtype,
                    ),
                    "cross_v": jnp.zeros(
                        (batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim),
                        cache_dtype,
                    ),
                }
            entry[f"e{i}"] = c
        unit_caches.append(entry)
    return _stack_trees(unit_caches)


def prefill(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict,
    cache_len: int,
    *,
    q_chunk: int = 1024,
    cache_dtype=jnp.bfloat16,
    dist: Optional[DistCtx] = None,
    last_index: Optional[jax.Array] = None,
) -> Tuple[Dict, jax.Array]:
    """Run the full prompt, build caches, return logits at the last position."""
    h, _ = _decoder_inputs(cfg, params, batch)
    enc_out = (
        _encode(cfg, params, batch["frames"]) if cfg.family == "encdec" else None
    )
    pattern = cfg.layer_pattern

    def body(hh, unit_p):
        caches = {}
        for i, kind in enumerate(pattern):
            hh, c = entry_prefill(
                cfg, kind, i, unit_p[f"e{i}"], hh, cache_len,
                enc_out=enc_out, q_chunk=q_chunk, cache_dtype=cache_dtype,
                dist=dist,
            )
            caches[f"e{i}"] = c
        return hh, caches

    h, caches = jax.lax.scan(body, h, params["units"])
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    if last_index is None:
        last = h[:, -1]
    else:  # ragged prompts (batched serving): per-seq last real position
        last = h[jnp.arange(h.shape[0]), last_index]
    logits = jnp.einsum(
        "bd,vd->bv", last, _vocab_weight(cfg, params).astype(last.dtype),
        preferred_element_type=jnp.float32,
    )
    logits = softcap(logits, cfg.final_logit_softcap)
    return caches, logits


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,  # (B, 1)
    caches: Dict,
    pos: jax.Array,  # scalar int32: tokens already in cache
    *,
    dist: Optional[DistCtx] = None,
) -> Tuple[jax.Array, Dict]:
    h = _embed_tokens(cfg, params, tokens)
    if cfg.family == "encdec":
        # sinusoidal position for the current (dynamic) step; handles scalar
        # or per-sequence vector positions
        half = cfg.d_model // 2
        inv = jnp.exp(
            -jnp.log(10_000.0) / (half - 1) * jnp.arange(half, dtype=jnp.float32)
        )
        posf = jnp.atleast_1d(jnp.asarray(pos, jnp.float32))  # (1,) or (B,)
        ang = posf[:, None] * inv[None, :]
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        h = h + pe[:, None, :].astype(h.dtype)
    pattern = cfg.layer_pattern

    def body(hh, xs):
        unit_p, unit_c = xs
        new_c = {}
        for i, kind in enumerate(pattern):
            hh, c = entry_decode(
                cfg, kind, i, unit_p[f"e{i}"], hh, unit_c[f"e{i}"], pos,
                dist=dist,
            )
            new_c[f"e{i}"] = c
        return hh, new_c

    h, new_caches = jax.lax.scan(body, h, (params["units"], caches))
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = jnp.einsum(
        "bqd,vd->bqv", h, _vocab_weight(cfg, params).astype(h.dtype),
        preferred_element_type=jnp.float32,
    )[:, 0]
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits, new_caches
