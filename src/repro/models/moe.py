"""Top-k Mixture-of-Experts with sort-based capacity dispatch.

TPU-friendly: no per-expert ragged shapes.  Tokens pick top-k experts; a
stable argsort groups (token, expert) assignments by expert; each expert
processes a fixed-capacity slab gathered from the token stream; results
scatter-add back weighted by the router gate.  Overflowing tokens beyond
``capacity_factor`` drop (standard Switch/GShard semantics).

Distribution: a *global* argsort/gather does not shard — GSPMD would
all-gather the token stream onto every chip (observed: 360 GiB/chip on
mixtral × train_4k).  Production path ``dist.moe_axes``: the dispatch runs
inside a **partial-auto shard_map** — manual over the batch axes (each data
shard routes its resident tokens, per-shard capacity — GShard semantics),
auto over ``model`` so the expert FFN stays tensor-parallel under GSPMD.
Raw tokens never cross data shards; only expert activations move.

The router load-balancing auxiliary loss (Switch §2.2) is returned alongside
so the train step can add ``cfg.router_aux_coef * aux``.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.compat import shard_map
from .common import activation, normal_init

__all__ = ["init_moe", "moe_apply", "DistCtx"]


class DistCtx(NamedTuple):
    """Static distribution context threaded through the model (hashable)."""

    mesh: object  # jax.sharding.Mesh
    moe_axes: Tuple[str, ...]  # mesh axes carrying the token batch
    # sequence-parallel axes: inter-layer activations (the per-layer remat
    # checkpoints) are sharded over these on their sequence dim — cuts the
    # dominant training-memory term (B·S·D per layer) by |axes|
    sp_axes: Tuple[str, ...] = ()
    # apply the explicit attention head-shard constraint (helps prefill
    # 8× on llava; hurts FSDP training — measured +9× collectives)
    head_shard: bool = False


def init_moe(cfg: ModelConfig, key) -> Dict:
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    p = {
        "router": normal_init(ks[0], (D, E), dtype=pd),
        "w_up": normal_init(ks[1], (E, D, F), dtype=pd),
        "w_down": normal_init(ks[2], (E, F, D), dtype=pd),
    }
    if cfg.mlp_gated:
        p["w_gate"] = normal_init(ks[3], (E, D, F), dtype=pd)
    return p


def moe_apply(
    cfg: ModelConfig,
    params: Dict,
    x: jax.Array,
    dist: Optional[DistCtx] = None,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out, aux_loss).  With ``dist`` the dispatch is
    data-shard-local (see module docstring)."""
    if dist is not None and dist.moe_axes:
        return _moe_sharded(cfg, params, x, dist)
    return _moe_dense_dispatch(cfg, params, x)


def _moe_sharded(cfg: ModelConfig, params: Dict, x: jax.Array, dist: DistCtx):
    axes = tuple(a for a in dist.moe_axes if a in dist.mesh.axis_names)
    wg = params.get("w_gate")

    def local(xl, router, w_up, w_down, w_gate):
        p = {"router": router, "w_up": w_up, "w_down": w_down}
        if cfg.mlp_gated:  # static
            p["w_gate"] = w_gate
        out, aux = _moe_dense_dispatch(cfg, p, xl)
        aux = jax.lax.pmean(aux, axis_name=axes)
        return out, aux

    rep = P(None, None, None)
    mapped = shard_map(
        local,
        mesh=dist.mesh,
        in_specs=(
            P(axes, None, None),
            P(None, None),
            rep, rep,
            rep if cfg.mlp_gated else P(),
        ),
        out_specs=(P(axes, None, None), P()),
        axis_names=set(axes),  # manual over batch; 'model' stays auto (TP)
    )
    return mapped(
        x, params["router"], params["w_up"], params["w_down"],
        wg if cfg.mlp_gated else jnp.zeros((), x.dtype),
    )


def _moe_dense_dispatch(
    cfg: ModelConfig, params: Dict, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    dt = x.dtype
    act = activation(cfg.act)

    xt = x.reshape(N, D)
    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, K)  # (N, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # ---- load-balancing aux loss (fraction_tokens · fraction_router_prob) --
    me = probs.mean(axis=0)  # (E,)
    one_hot_top1 = jax.nn.one_hot(topk_idx[:, 0], E, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)
    aux = (me * ce).sum() * E

    # ---- sort-based capacity dispatch ---------------------------------------
    capacity = int(max(1, -(-N * K // E) * cfg.capacity_factor))
    flat_e = topk_idx.reshape(-1)  # (N·K,)
    flat_g = gate_vals.reshape(-1)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    # position within the expert's group
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(N * K, dtype=jnp.int32) - group_start
    ok = pos < capacity
    token_of = (sort_idx // K).astype(jnp.int32)

    # slots (E, C): token index feeding each expert slot (N = dummy row).
    # Overflowing assignments write to column `capacity` → dropped.
    col = jnp.where(ok, pos, capacity)
    slot_tok = jnp.full((E, capacity), N, dtype=jnp.int32)
    slot_tok = slot_tok.at[sorted_e, col].set(token_of, mode="drop")
    slot_gate = jnp.zeros((E, capacity), dtype=jnp.float32)
    slot_gate = slot_gate.at[sorted_e, col].set(flat_g[sort_idx], mode="drop")

    x_pad = jnp.concatenate([xt, jnp.zeros((1, D), dtype=dt)], axis=0)
    xe = x_pad[slot_tok]  # (E, C, D)

    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    if cfg.mlp_gated:
        gate = act(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt)))
        h = gate * up
    else:
        h = act(up)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))
    ye = ye * slot_gate[..., None].astype(dt)

    # combine: scatter-add expert outputs back to tokens
    out = jnp.zeros((N + 1, D), dtype=dt)
    out = out.at[slot_tok.reshape(-1)].add(ye.reshape(-1, D), mode="drop")
    return out[:N].reshape(B, S, D), aux
