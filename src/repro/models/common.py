"""Shared model components: norms, RoPE, activations, losses, init."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "apply_rope",
    "rope_angles",
    "softcap",
    "activation",
    "sinusoidal_positions",
    "chunked_cross_entropy",
    "normal_init",
]


def normal_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in f32, gemma-style (1 + w) scaling with zeros-init weight."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Logit soft-capping: cap * tanh(x / cap) (gemma2)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return functools.partial(jax.nn.gelu, approximate=True)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, base: float) -> jax.Array:
    """(…, head_dim/2) angles for given integer positions."""
    inv_freq = 1.0 / (
        base ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )
    return positions.astype(jnp.float32)[..., None] * inv_freq


def apply_rope(x: jax.Array, positions: jax.Array, base: float) -> jax.Array:
    """x: (..., S, H, head_dim); positions: (..., S). Pairs split as
    [first half, second half] (HF convention)."""
    if base <= 0:  # architecture without RoPE (whisper/jamba)
        return x
    hd = x.shape[-1]
    ang = rope_angles(positions, hd, base)  # (..., S, hd/2)
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> np.ndarray:
    """Whisper-style sinusoidal embedding table (length, dim)."""
    log_timescale = np.log(10_000.0) / (dim // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(dim // 2))
    scaled = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1).astype(
        np.float32
    )


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def chunked_cross_entropy(
    h: jax.Array,
    w_vocab: jax.Array,
    labels: jax.Array,
    *,
    chunk: int = 512,
    final_softcap: Optional[float] = None,
    label_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Mean NLL with the (B, S, V) logits never materialized for full S.

    ``h`` (B, S, D); ``w_vocab`` (V, D) — possibly vocab-sharded; ``labels``
    (B, S) int32.  Scans over sequence chunks: per step the logits tensor is
    (B, chunk, V).  This is the memory trick that keeps 262k-vocab training
    inside HBM (DESIGN §5).
    """
    B, S, D = h.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        lm = jnp.zeros((B, S + pad), dtype=jnp.float32).at[:, :S].set(
            1.0 if label_mask is None else label_mask.astype(jnp.float32)
        )
    else:
        lm = (
            jnp.ones((B, S), dtype=jnp.float32)
            if label_mask is None
            else label_mask.astype(jnp.float32)
        )
    nc = (S + pad) // c
    hs = h.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, c).transpose(1, 0, 2)
    ms = lm.reshape(B, nc, c).transpose(1, 0, 2)

    @jax.checkpoint  # backward re-derives per-chunk logits: the (B, S, V)
    # tensor never exists — neither forward nor as saved residuals
    def body(carry, xs):
        hc, lc, mc = xs
        logits = jnp.einsum(
            "bqd,vd->bqv", hc, w_vocab, preferred_element_type=jnp.float32
        )
        logits = softcap(logits, final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mc
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    (total, denom), _ = jax.lax.scan(body, (0.0, 0.0), (hs, ls, ms))
    return total / jnp.maximum(denom, 1.0)
