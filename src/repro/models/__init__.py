from .model import (
    count_params,
    decode_step,
    init_caches,
    init_params,
    prefill,
    train_loss,
)

__all__ = [
    "count_params", "decode_step", "init_caches", "init_params",
    "prefill", "train_loss",
]
