"""Graph-native + streaming token replay — conformance where the data lives.

Three evaluation paths, pinned bit-identical on shared inputs:

* **columnar** — :func:`repro.core.conformance.replay_fitness`, the oracle:
  one vectorized pass over the repository's pair columns;
* **graph** — :func:`replay_fitness_graph`: the same arithmetic as segment
  walks over the event-knowledge graph's stored tables (canonical
  ``:BELONGS_TO`` order makes each case a contiguous segment whose ``:DF``
  steps are adjacent rows), so a built graph replays with **zero
  re-materialization** of the source;
* **streaming** — :class:`StreamingReplayer`: one O(A² + chunk + cases)
  scan over a memmap log, with ``snapshot()/restore()`` state (per-case
  tails + fitness accumulators) so the engine's delta plans resume replay
  over just an appended suffix, exactly like the PR 2 miner.

:class:`StreamingModelDiscoverer` is the out-of-core companion for the
"model defaults to the log's own discovered dependency graph" case: it
accumulates Ψ plus per-case first/last activities in the same single scan,
so discovery never needs to materialize the log either.
"""

from __future__ import annotations

import dataclasses
from time import perf_counter
from typing import Callable, Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.conformance import (
    ModelSpec,
    ReplayResult,
    deviation_census,
    model_tables,
    replay_core,
)
from repro.core.discovery import DiscoveredModel, discover_dependency_graph
from repro.core.streaming import MemmapLog, StreamingDFGMiner

__all__ = [
    "ReplayState",
    "StreamingReplayer",
    "StreamingModelDiscoverer",
    "replay_fitness_arrays",
    "replay_fitness_graph",
    "replay_fitness_streaming",
]


# ---------------------------------------------------------------------------
# Shared array-level replay (graph + transformed columnar paths)
# ---------------------------------------------------------------------------


def replay_fitness_arrays(
    activity: np.ndarray,
    trace: np.ndarray,
    names: Sequence[str],
    model: Union[DiscoveredModel, ModelSpec],
    num_traces: Optional[int] = None,
) -> ReplayResult:
    """Token replay over canonical (trace-contiguous, time-sorted) columns.

    ``num_traces=None`` scores exactly the traces that own events,
    renumbered by ascending trace id — the semantics of a diced/transformed
    selection (and of a streaming scan, which can only see cases with
    rows).  Passing an explicit ``num_traces`` scores every trace,
    including empty ones (the whole-repository oracle semantics).
    """
    activity = np.asarray(activity)
    trace = np.asarray(trace)
    if num_traces is None:
        _uniq, t = np.unique(trace, return_inverse=True)
        T = int(_uniq.shape[0])
    else:
        t, T = trace, int(num_traces)
    allowed, start_ok, end_ok = model_tables(model, names)
    trace_fit, bad_src, bad_dst = replay_core(
        activity, t, T, allowed, start_ok, end_ok
    )
    return ReplayResult(
        fitness=float(trace_fit.mean()) if trace_fit.shape[0] else 1.0,
        trace_fitness=trace_fit,
        perfectly_fitting=int((trace_fit >= 1.0 - 1e-12).sum()),
        deviating_edges=deviation_census(bad_src, bad_dst, names),
    )


def replay_fitness_graph(
    graph, model: Union[DiscoveredModel, ModelSpec]
) -> ReplayResult:
    """Replay straight off an :class:`~repro.graph.build.EventGraph`'s
    stored event tables — the ``:BELONGS_TO`` CSR guarantees each case is a
    contiguous segment, so the ``:DF`` walk is the adjacent-row gather the
    oracle vectorizes.  Topology-only graphs (built out-of-core) carry no
    tables and cannot replay."""
    if not graph.has_event_tables:
        raise ValueError(
            "topology-only graph has no event tables; replay needs a full "
            "graph (in-budget build) or the streaming path"
        )
    return replay_fitness_arrays(
        np.asarray(graph.event_activity),
        np.asarray(graph.event_trace),
        graph.activity_names,
        model,
        num_traces=graph.num_traces,
    )


# ---------------------------------------------------------------------------
# Streaming replay (out-of-core, resumable)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ReplayState:
    """Resumable snapshot of a :class:`StreamingReplayer`: the per-case
    tails (last activity) plus fitness accumulators (allowed-move counts,
    lengths, start scores) and the disallowed-move census matrix.  Resuming
    over an appended suffix reproduces a full rescan bit for bit — end
    scores are derived from the tails only at :meth:`StreamingReplayer.
    finalize`, so an open case's end contribution is never baked in."""

    num_activities: int
    last_act: np.ndarray  # (C,) int32, -1 = case unseen
    ok_moves: np.ndarray  # (C,) int64 allowed directly-follows moves
    lengths: np.ndarray  # (C,) int64 events per case
    start_fit: np.ndarray  # (C,) int64 ∈ {0, 1}
    bad_pairs: np.ndarray  # (A, A) int64 disallowed-move census
    events_seen: int

    def copy(self) -> "ReplayState":
        return ReplayState(
            self.num_activities,
            self.last_act.copy(), self.ok_moves.copy(),
            self.lengths.copy(), self.start_fit.copy(),
            self.bad_pairs.copy(), self.events_seen,
        )


class StreamingReplayer:
    """One-pass token replay over a time-ordered event stream with
    interleaved cases — the conformance twin of
    :class:`~repro.core.streaming.StreamingDFGMiner`.

    State is O(A² + cases): per-case tails/accumulators are dense arrays
    indexed by raw case id (grown on demand), and every chunk update is
    fully vectorized (one lexsort + boolean gathers; no Python loop over
    case runs).  ``snapshot()/restore()`` make the scan resumable across
    appends; a grown activity vocabulary pads the model tables with
    all-False rows (new activities are never allowed by the old model).
    """

    def __init__(
        self,
        names: Sequence[str],
        model: Union[DiscoveredModel, ModelSpec],
        state: Optional[ReplayState] = None,
        observer: Optional[Callable[[float, int], None]] = None,
    ):
        self.names = list(names)
        # per-chunk timing hook, called as ``observer(seconds, rows)``
        # after every non-empty update — the engine wires this to its
        # ``replay_chunk_seconds`` histogram
        self.observer = observer
        a = len(self.names)
        self.allowed, self.start_ok, self.end_ok = model_tables(
            model, self.names
        )
        if state is None:
            self.last_act = np.full((0,), -1, dtype=np.int32)
            self.ok_moves = np.zeros((0,), dtype=np.int64)
            self.lengths = np.zeros((0,), dtype=np.int64)
            self.start_fit = np.zeros((0,), dtype=np.int64)
            self.bad_pairs = np.zeros((a, a), dtype=np.int64)
            self.events_seen = 0
        else:
            if state.num_activities > a:
                raise ValueError(
                    "cannot shrink the vocabulary on resume "
                    f"({state.num_activities} -> {a})"
                )
            self.last_act = state.last_act.copy()
            self.ok_moves = state.ok_moves.copy()
            self.lengths = state.lengths.copy()
            self.start_fit = state.start_fit.copy()
            self.bad_pairs = np.zeros((a, a), dtype=np.int64)
            old = state.num_activities
            self.bad_pairs[:old, :old] = state.bad_pairs
            self.events_seen = int(state.events_seen)

    def snapshot(self) -> ReplayState:
        return ReplayState(
            len(self.names),
            self.last_act.copy(), self.ok_moves.copy(),
            self.lengths.copy(), self.start_fit.copy(),
            self.bad_pairs.copy(), self.events_seen,
        )

    @classmethod
    def restore(
        cls,
        state: ReplayState,
        names: Sequence[str],
        model: Union[DiscoveredModel, ModelSpec],
        observer: Optional[Callable[[float, int], None]] = None,
    ) -> "StreamingReplayer":
        return cls(names, model, state=state, observer=observer)

    def _grow(self, max_case: int) -> None:
        c = self.last_act.shape[0]
        if max_case < c:
            return
        n = max_case + 1
        la = np.full((n,), -1, dtype=np.int32)
        la[:c] = self.last_act
        self.last_act = la
        for attr in ("ok_moves", "lengths", "start_fit"):
            arr = np.zeros((n,), dtype=np.int64)
            arr[:c] = getattr(self, attr)
            setattr(self, attr, arr)

    def update(
        self, activity: np.ndarray, case: np.ndarray, time: np.ndarray
    ) -> None:
        """Consume one chunk (time-ordered rows; cases may interleave)."""
        n = activity.shape[0]
        if n == 0:
            return
        obs = self.observer
        t0 = perf_counter() if obs is not None else 0.0
        self.events_seen += int(n)
        order = np.lexsort((np.arange(n), time, case))
        a = np.asarray(activity)[order].astype(np.int64)
        c = np.asarray(case)[order].astype(np.int64)
        self._grow(int(c.max()))

        np.add.at(self.lengths, c, 1)

        # in-chunk pairs (cases are contiguous after the sort)
        if n >= 2:
            same = c[:-1] == c[1:]
            edge_ok = self.allowed[a[:-1], a[1:]]
            np.add.at(
                self.ok_moves, c[:-1][same],
                (edge_ok & same)[same].astype(np.int64),
            )
            bad = same & ~edge_ok
            np.add.at(self.bad_pairs, (a[:-1][bad], a[1:][bad]), 1)

        # cross-chunk boundary pairs + first-ever events, at case-run starts
        rs = np.ones(n, dtype=bool)
        rs[1:] = c[1:] != c[:-1]
        rs_idx = np.nonzero(rs)[0]
        cs = c[rs_idx]
        first_a = a[rs_idx]
        prev = self.last_act[cs]
        seen = prev >= 0
        if seen.any():
            pa = prev[seen].astype(np.int64)
            fa = first_a[seen]
            edge_ok = self.allowed[pa, fa]
            np.add.at(
                self.ok_moves, cs[seen][edge_ok], 1
            )
            np.add.at(self.bad_pairs, (pa[~edge_ok], fa[~edge_ok]), 1)
        fresh = ~seen
        if fresh.any():
            self.start_fit[cs[fresh]] = self.start_ok[
                first_a[fresh]
            ].astype(np.int64)

        # carry the tail of each case-run (one run per case after the sort)
        re_ = np.ones(n, dtype=bool)
        re_[:-1] = c[:-1] != c[1:]
        re_idx = np.nonzero(re_)[0]
        self.last_act[c[re_idx]] = a[re_idx].astype(np.int32)

        if obs is not None:
            obs(perf_counter() - t0, int(n))

    def finalize(self) -> ReplayResult:
        """Score the scanned stream (non-destructive: end contributions come
        from the tails, so the replayer can keep consuming afterwards)."""
        seen = np.nonzero(self.last_act >= 0)[0]  # ascending raw case id
        ends_fit = self.end_ok[self.last_act[seen]].astype(np.int64)
        denom = np.maximum(self.lengths[seen] + 1, 1)
        trace_fit = (
            self.ok_moves[seen] + self.start_fit[seen] + ends_fit
        ) / denom
        bs, bd = np.nonzero(self.bad_pairs)
        census: Dict[tuple, int] = {
            (self.names[int(s)], self.names[int(d)]): int(
                self.bad_pairs[s, d]
            )
            for s, d in zip(bs, bd)
        }
        return ReplayResult(
            fitness=float(trace_fit.mean()) if trace_fit.shape[0] else 1.0,
            trace_fitness=trace_fit,
            perfectly_fitting=int((trace_fit >= 1.0 - 1e-12).sum()),
            deviating_edges=census,
        )


def replay_fitness_streaming(
    log: MemmapLog,
    model: Union[DiscoveredModel, ModelSpec],
    row_range: Optional[Tuple[int, int]] = None,
) -> ReplayResult:
    """End-to-end out-of-core replay of a memmap log (O(chunk) memory)."""
    rep = StreamingReplayer(log.activity_labels(), model)
    for a, c, t in log.iter_chunks(row_range=row_range):
        rep.update(a, c, t)
    return rep.finalize()


# ---------------------------------------------------------------------------
# Streaming model discovery (for the default-model case, out-of-core)
# ---------------------------------------------------------------------------


class StreamingModelDiscoverer:
    """Dependency-graph discovery in one streaming scan: Ψ via the PR 2
    miner plus per-case first/last activities (the trace boundaries
    discovery needs), O(A² + cases) memory."""

    def __init__(self, num_activities: int):
        self.miner = StreamingDFGMiner(num_activities)
        self.first_act = np.full((0,), -1, dtype=np.int32)
        self.last_act = np.full((0,), -1, dtype=np.int32)

    def _grow(self, max_case: int) -> None:
        c = self.first_act.shape[0]
        if max_case < c:
            return
        n = max_case + 1
        for attr in ("first_act", "last_act"):
            arr = np.full((n,), -1, dtype=np.int32)
            arr[:c] = getattr(self, attr)
            setattr(self, attr, arr)

    def update(
        self, activity: np.ndarray, case: np.ndarray, time: np.ndarray
    ) -> None:
        n = activity.shape[0]
        if n == 0:
            return
        order = np.lexsort((np.arange(n), time, case))
        a = np.asarray(activity)[order]
        c = np.asarray(case)[order].astype(np.int64)
        t = np.asarray(time)[order]
        self._grow(int(c.max()))
        rs = np.ones(n, dtype=bool)
        rs[1:] = c[1:] != c[:-1]
        rs_idx = np.nonzero(rs)[0]
        fresh = rs_idx[self.first_act[c[rs_idx]] < 0]
        self.first_act[c[fresh]] = a[fresh]
        re_ = np.ones(n, dtype=bool)
        re_[:-1] = c[:-1] != c[1:]
        re_idx = np.nonzero(re_)[0]
        self.last_act[c[re_idx]] = a[re_idx]
        # feed the (already sorted) chunk to the miner for Ψ
        self.miner.update(a, c.astype(np.int32), t)

    def finalize(
        self, names: Sequence[str], *, min_count: int = 1,
        min_dependency: float = 0.5,
    ) -> DiscoveredModel:
        a = len(names)
        starts = np.bincount(
            self.first_act[self.first_act >= 0], minlength=a
        ).astype(np.int64)
        ends = np.bincount(
            self.last_act[self.last_act >= 0], minlength=a
        ).astype(np.int64)
        return discover_dependency_graph(
            self.miner.finalize(), list(names), starts, ends,
            min_count=min_count, min_dependency=min_dependency,
        )
