"""DFG-based trace alignments — optimal skip/insert edit distance over the
model's edge relation.

Token replay (:mod:`repro.conformance.replay`) scores how many observed
moves the model allows; alignments answer the stronger question "what is
the *cheapest explanation* of each trace as a walk through the model":

* **synchronous move** (cost 0) — the model executes the observed event;
* **move on log** (cost 1) — the event is skipped (observed but not
  explainable);
* **move on model** (cost 1) — the model executes an activity the trace
  does not contain (required but unobserved).

On the DFG abstraction the model is the edge relation plus virtual
START/END, so the optimal alignment is a shortest path whose layered DP has
one state per model activity.  Two closures make each DP layer O(S) instead
of a per-layer graph search:

* ``D`` — all-pairs model-move distances (min-plus APSP over the edge
  relation, START-augmented);
* ``M[s, a] = min_{s'→a allowed} D[s, s']`` — "any number of model moves,
  then sync on ``a``", one f32 table reused by every trace.

The DP is **batched across the variant table** (cost is per *variant*, not
per trace — a million-trace log typically has a few thousand variants) and
its inner loop runs through :mod:`repro.kernels.align_dp` (Pallas MXU
kernel on TPU, vectorized numpy fallback on CPU, bit-identical).

``fitness(trace) = 1 − cost / (len(trace) + empty_cost)`` with
``empty_cost`` the cheapest START→END model walk — the standard
worst-case-normalized alignment fitness.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.conformance import ModelSpec, deviation_census, model_tables
from repro.core.discovery import DiscoveredModel
from repro.core.repository import EventRepository
from repro.core.variants import TraceVariants, variant_table
from repro.kernels.align_dp import BIG_COST, align_dp

__all__ = [
    "AlignmentResult",
    "alignment_cost_tables",
    "align_variants",
    "align_repository",
    "align_arrays",
]


@dataclasses.dataclass
class AlignmentResult:
    """Optimal-alignment conformance of a log against a DFG model.

    ``trace_cost`` / ``trace_fitness`` are per trace (aligned with the
    source's trace order); ``variant_costs`` the per-variant DP output the
    trace arrays were broadcast from.  ``deviating_edges`` is the same
    disallowed-move census replay reports (the *where* of the cost)."""

    fitness: float  # mean normalized trace fitness in [0, 1]
    trace_cost: np.ndarray  # (T,) int64 optimal alignment cost
    trace_fitness: np.ndarray  # (T,) float64
    variant_costs: np.ndarray  # (V,) int64
    perfectly_fitting: int  # traces with cost == 0
    empty_cost: int  # cheapest START→END model walk (∞ → -1)
    deviating_edges: Dict[tuple, int]

    def summary(self) -> Dict:
        worst = sorted(
            self.deviating_edges.items(), key=lambda kv: -kv[1]
        )[:5]
        return {
            "fitness": round(self.fitness, 4),
            "perfect_traces": self.perfectly_fitting,
            "total_traces": int(self.trace_cost.shape[0]),
            "mean_cost": (
                round(float(self.trace_cost.mean()), 4)
                if self.trace_cost.shape[0] else 0.0
            ),
            "top_deviations": [
                {"edge": list(e), "count": c} for e, c in worst
            ],
        }


def alignment_cost_tables(
    model: Union[DiscoveredModel, ModelSpec], names: Sequence[str]
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(M (S, A), d0 (S,), endcost (S,)) for the layered DP, f32.

    State ``s`` ranges over the **model ∪ log universe** plus a virtual
    START: model moves may route through activities the log never executes
    (replay can ignore them — it only gathers at observed activities — but
    a path closure cannot), so activities the model knows and ``names``
    lacks are appended as extra states.  Sync columns exist only for the
    observable ``names``.  ``M[s, a]`` folds any number of model moves
    followed by a sync on ``a``; ``endcost[s]`` the model moves to reach an
    end-allowed activity.  Unreachable entries carry :data:`BIG_COST`.
    """
    spec = ModelSpec.from_model(model)
    universe = list(names) + [
        m for m in spec.activities if m not in set(names)
    ]
    allowed, start_ok, end_ok = model_tables(spec, universe)
    a = len(names)  # observable sync columns
    s = len(universe) + 1  # + virtual START
    u = len(universe)
    big = np.float32(BIG_COST)

    # hop cost matrix over the augmented edge relation (hop x→y executes y)
    w = np.full((s, s), big, dtype=np.float32)
    w[:u, :u][allowed] = 1.0
    w[u, :u][start_ok] = 1.0
    d = w.copy()
    np.fill_diagonal(d, 0.0)
    for k in range(s):  # Floyd–Warshall, vectorized per pivot
        np.minimum(d, d[:, k, None] + d[None, k, :], out=d)
    d = np.minimum(d, big)

    # sync closure: M[x, t] = min over x' with x'→t allowed of D[x, x']
    sync_in = np.full((s, a), big, dtype=np.float32)
    sync_in[:u, :][allowed[:, :a]] = 0.0
    sync_in[u, :][start_ok[:a]] = 0.0
    m = np.full((s, a), big, dtype=np.float32)
    for t in range(a):  # O(S·A) per column, states on the fast axis
        col = sync_in[:, t]
        reach = col < big
        if reach.any():
            m[:, t] = d[:, reach].min(axis=1)
    m = np.minimum(m, big)

    d0 = np.full((s,), big, dtype=np.float32)
    d0[u] = 0.0
    end_states = np.nonzero(end_ok)[0]
    endcost = (
        d[:, end_states].min(axis=1)
        if end_states.shape[0]
        else np.full((s,), big, dtype=np.float32)
    )
    return m, d0, np.minimum(endcost, big).astype(np.float32)


def align_variants(
    tv: TraceVariants,
    names: Sequence[str],
    model: Union[DiscoveredModel, ModelSpec],
    *,
    backend: str = "auto",
) -> Tuple[np.ndarray, int]:
    """(per-variant optimal costs (V,) int64, empty_cost) via the batched
    DP.  ``backend`` routes the inner loop (auto | numpy | pallas)."""
    m, d0, endcost = alignment_cost_tables(model, names)
    empty = float((d0 + endcost).min())
    empty_cost = -1 if empty >= BIG_COST / 2 else int(empty)

    v = tv.num_variants
    if v == 0:
        return np.zeros((0,), dtype=np.int64), empty_cost
    idx = {n: i for i, n in enumerate(names)}
    lens = np.asarray([len(s) for s in tv.sequences], dtype=np.int32)
    lp = int(lens.max()) if v else 0
    seqs = np.zeros((v, max(lp, 1)), dtype=np.int32)
    for i, seq in enumerate(tv.sequences):
        seqs[i, : len(seq)] = [idx[x] for x in seq]

    raw = align_dp(seqs, lens, m, d0, endcost, backend=backend)
    costs = np.asarray(raw, dtype=np.float64)
    # an unalignable variant (model has no START→END walk) degrades to
    # all-log-moves against the empty walk; report len as its cost
    unreachable = costs >= BIG_COST / 2
    costs = np.where(unreachable, lens.astype(np.float64), costs)
    return np.round(costs).astype(np.int64), empty_cost


def align_arrays(
    activity: np.ndarray,
    trace: np.ndarray,
    names: Sequence[str],
    model: Union[DiscoveredModel, ModelSpec],
    num_traces: Optional[int] = None,
    *,
    backend: str = "auto",
) -> AlignmentResult:
    """Alignments over canonical (trace-contiguous) event columns — the
    array-level core every path (repository, graph tables, transformed
    selections) shares; nothing is materialized beyond the variant table."""
    names = list(names)
    a_col = np.asarray(activity)
    if num_traces is None:
        uniq, t_col = np.unique(np.asarray(trace), return_inverse=True)
        T = int(uniq.shape[0])
    else:
        t_col, T = np.asarray(trace), int(num_traces)
    tv = variant_table(a_col, t_col, T, names)
    variant_costs, empty_cost = align_variants(
        tv, names, model, backend=backend
    )
    trace_cost = (
        variant_costs[tv.trace_variant]
        if T and variant_costs.shape[0]
        else np.zeros((T,), dtype=np.int64)
    )
    lens = np.bincount(t_col, minlength=T).astype(np.int64)
    if empty_cost < 0:
        # no complete model walk exists: nothing aligns, fitness is 0 for
        # any non-empty trace
        fit = np.where(lens > 0, 0.0, 1.0).astype(np.float64)
    else:
        worst = np.maximum(lens + empty_cost, 1)
        fit = 1.0 - trace_cost / worst

    # the census of disallowed observed moves (same helper as replay)
    allowed, _s, _e = model_tables(model, names)
    census: Dict[tuple, int] = {}
    if a_col.shape[0] >= 2:
        same = t_col[:-1] == t_col[1:]
        bad = same & ~allowed[a_col[:-1], a_col[1:]]
        census = deviation_census(
            a_col[:-1][bad].astype(np.int64),
            a_col[1:][bad].astype(np.int64),
            names,
        )
    return AlignmentResult(
        fitness=float(fit.mean()) if T else 1.0,
        trace_cost=trace_cost,
        trace_fitness=fit,
        variant_costs=variant_costs,
        perfectly_fitting=int((trace_cost == 0).sum()) if T else 0,
        empty_cost=empty_cost,
        deviating_edges=census,
    )


def align_repository(
    repo: EventRepository,
    model: Union[DiscoveredModel, ModelSpec],
    *,
    backend: str = "auto",
) -> AlignmentResult:
    """Optimal DFG alignments of every trace, batched per variant."""
    return align_arrays(
        repo.event_activity, repo.event_trace, repo.activity_names, model,
        num_traces=repo.num_traces, backend=backend,
    )
