"""``repro.conformance`` — graph-native conformance checking.

The paper positions the in-store DFG as the backbone for "discovery,
conformance, and enhancement" (§2.1); this subsystem makes conformance run
*where the data lives*, closing the loop:

* :mod:`repro.conformance.replay` — token replay as segment walks over the
  event-knowledge graph's stored tables, plus a resumable
  :class:`StreamingReplayer` for out-of-core memmap logs (one O(chunk)
  scan; appends delta-resume over just the suffix);
* :mod:`repro.conformance.align` — optimal DFG alignments (skip / insert /
  move-on-model edit distance over the model's edge relation), batched per
  variant through the :mod:`repro.kernels.align_dp` Pallas kernel.

All paths are pinned bit-identical to the columnar oracle
:func:`repro.core.conformance.replay_fitness`; the query engine plans them
via ``Q....fitness()`` / ``Q....alignments()`` (see :mod:`repro.query`).
"""

from repro.core.conformance import (
    ModelSpec,
    ReplayResult,
    deviation_census,
    model_tables,
    replay_fitness,
)

from .align import (
    AlignmentResult,
    align_arrays,
    align_repository,
    align_variants,
    alignment_cost_tables,
)
from .replay import (
    ReplayState,
    StreamingModelDiscoverer,
    StreamingReplayer,
    replay_fitness_arrays,
    replay_fitness_graph,
    replay_fitness_streaming,
)

__all__ = [
    "ModelSpec", "ReplayResult", "replay_fitness", "model_tables",
    "deviation_census",
    "ReplayState", "StreamingReplayer", "StreamingModelDiscoverer",
    "replay_fitness_arrays", "replay_fitness_graph",
    "replay_fitness_streaming",
    "AlignmentResult", "align_repository", "align_variants", "align_arrays",
    "alignment_cost_tables",
]
