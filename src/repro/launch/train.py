"""Training CLI.

On this CPU container it drives reduced configs end-to-end (the example
path); on a pod the same entry point runs the full configs — the step
function/shardings are exactly the ones the dry-run compiled.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --preset tiny --steps 100
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/graphpm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mine", action="store_true",
                    help="process-mine the run's telemetry at the end")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import TrainHParams
    from repro.data.lm_data import TokenPipeline
    from repro.train import Trainer

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, vocab_size=256, loss_chunk=32)
    elif args.preset == "small":
        # ~100M-class model of the same family
        cfg = dataclasses.replace(
            cfg.reduced(), d_model=512, n_heads=8, head_dim=64, d_ff=2048,
            n_layers=len(cfg.layer_pattern) * 4, vocab_size=8192,
            loss_chunk=128,
        )
    hp = TrainHParams(
        learning_rate=args.lr, warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps,
    )
    data = TokenPipeline(
        vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq, seed=17
    )
    trainer = Trainer(
        cfg, hp, data, args.ckpt_dir, ckpt_every=args.ckpt_every,
        q_chunk=min(1024, args.seq),
    )
    out = trainer.run(args.steps)
    print(json.dumps({
        "arch": cfg.name,
        "steps": out["final_step"],
        "first_loss": out["history"][0],
        "last_loss": out["history"][-1],
        "bigram_entropy_floor": data.bigram_entropy(),
        "stragglers": out["stragglers"],
    }, indent=1))

    if args.mine:
        from repro.core import dfg_from_repository, discover_dependency_graph, to_dot

        repo = trainer.collector.to_repository()
        psi = dfg_from_repository(repo)
        starts, ends = repo.trace_boundaries()
        model = discover_dependency_graph(
            psi, repo.activity_names, starts, ends, min_dependency=0.0
        )
        print("\n== mined training process (GraphPM on the trainer's own log) ==")
        print(to_dot(model))


if __name__ == "__main__":
    main()
