"""Step functions + abstract inputs + shardings per (cfg, shape, mesh).

Used by the dry-run (lower/compile only) and by the real trainer/server on
hardware — same code path, so the dry-run proves the production config.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainHParams
from repro.models import (
    decode_step,
    init_caches,
    init_params,
    prefill,
    train_loss,
)
from repro.models.moe import DistCtx
from repro.sharding.spec import (
    ShardingRules,
    batch_shardings,
    cache_shardings,
    make_rules,
    param_shardings,
)
from repro.train.optimizer import OptState, adamw_update, init_opt_state

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "abstract_train_args",
    "abstract_prefill_args",
    "abstract_decode_args",
    "abstract_params",
]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)
    )


def _abstract_batch(cfg: ModelConfig, shape: ShapeConfig, with_labels: bool):
    B, S = shape.global_batch, shape.seq_len
    s_text = S - (cfg.n_patches if cfg.family == "vlm" else 0)
    batch = {"tokens": _sds((B, s_text), jnp.int32)}
    if with_labels:
        batch["labels"] = _sds((B, s_text), jnp.int32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = _sds((B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


def _q_chunk(shape: ShapeConfig, cfg: ModelConfig = None) -> int:
    if cfg is not None and cfg.q_chunk:
        return cfg.q_chunk
    return 512 if shape.seq_len > 8192 else 1024


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def abstract_train_args(cfg, shape):
    p = abstract_params(cfg)
    o = jax.eval_shape(init_opt_state, p)
    b = _abstract_batch(cfg, shape, with_labels=True)
    return p, o, b


def make_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    hp: TrainHParams = TrainHParams(),
):
    rules = make_rules(mesh, shape)
    p, o, b = abstract_train_args(cfg, shape)
    p_sh = param_shardings(rules, p, cfg)
    o_sh = OptState(
        step=rules.nd(P()),
        mu=param_shardings(rules, o.mu, cfg),
        nu=param_shardings(rules, o.nu, cfg),
    )
    b_sh = batch_shardings(rules, b)
    scalar = rules.nd(P())
    qc = _q_chunk(shape, cfg)
    # all archs get the dist ctx for training: sequence-parallel
    # activations (sp_axes) + data-local MoE dispatch (moe_axes)
    sp = ("model",) if rules.model_axis else ()
    dist = DistCtx(mesh, rules.batch_axes, sp_axes=sp)

    # microbatching (gradient accumulation): MoE dispatch transients scale
    # with per-device tokens — accumulate to stay inside HBM
    k = cfg.microbatches or (
        4 if cfg.top_k >= 8 else (2 if cfg.n_experts else 1)
    )

    def _loss(pp, bb):
        if cfg.cast_params_once:
            # cast before the FSDP gathers: the all-gather moves bf16, the
            # f32 master copy never leaves its shard.  MoE expert weights
            # are skipped: a convert feeding the dispatch shard_map trips an
            # XLA:CPU partitioner CHECK ("invalid binary opcode copy").
            def cast(path, x):
                keys = [getattr(p, "key", "") for p in path]
                if "moe" in keys or x.ndim < 2:
                    return x
                return x.astype(jnp.bfloat16)

            pp = jax.tree_util.tree_map_with_path(cast, pp)
        return train_loss(cfg, pp, bb, q_chunk=qc, dist=dist)

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(_loss)
        if k == 1:
            loss, grads = grad_fn(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
            )

            def body(acc, mbatch):
                l, g = grad_fn(params, mbatch)
                return (
                    acc[0] + l,
                    jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc[1], g
                    ),
                ), None

            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params
            )
            (loss, gsum), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), mb)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, gsum)
        new_p, new_o, metrics = adamw_update(hp, params, grads, opt_state)
        return new_p, new_o, loss, metrics

    in_sh = (p_sh, o_sh, b_sh)
    out_sh = (p_sh, o_sh, scalar, {"lr": scalar, "grad_norm": scalar})
    return train_step, in_sh, out_sh, (p, o, b), (0, 1)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def abstract_prefill_args(cfg, shape):
    p = abstract_params(cfg)
    b = _abstract_batch(cfg, shape, with_labels=False)
    return p, b


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh):
    rules = make_rules(mesh, shape)
    p, b = abstract_prefill_args(cfg, shape)
    p_sh = param_shardings(rules, p, cfg)
    b_sh = batch_shardings(rules, b)
    qc = _q_chunk(shape, cfg)
    cache_len = shape.seq_len
    # all archs: head-shard constraints + SP + data-local MoE dispatch
    sp = ("model",) if rules.model_axis else ()
    dist = DistCtx(mesh, rules.batch_axes, sp_axes=sp, head_shard=True)

    def prefill_step(params, batch):
        return prefill(cfg, params, batch, cache_len, q_chunk=qc, dist=dist)

    caches_shape = jax.eval_shape(prefill_step, p, b)[0]
    c_sh = cache_shardings(rules, caches_shape)
    logits_sh = rules.nd(
        P(
            rules.batch_if(shape.global_batch),
            rules.model_if(cfg.vocab_size),
        )
    )
    return prefill_step, (p_sh, b_sh), (c_sh, logits_sh), (p, b), ()


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def abstract_decode_args(cfg, shape):
    p = abstract_params(cfg)
    B = shape.global_batch
    toks = _sds((B, 1), jnp.int32)
    caches = jax.eval_shape(
        lambda: init_caches(cfg, B, shape.seq_len)
    )
    pos = _sds((), jnp.int32)
    return p, toks, caches, pos


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh):
    rules = make_rules(mesh, shape)
    p, toks, caches, pos = abstract_decode_args(cfg, shape)
    p_sh = param_shardings(rules, p, cfg)
    c_sh = cache_shardings(rules, caches)
    b = rules.batch_if(shape.global_batch)
    tok_sh = rules.nd(P(b, None))
    pos_sh = rules.nd(P())
    logits_sh = rules.nd(P(b, rules.model_if(cfg.vocab_size)))
    dist = (
        DistCtx(mesh, rules.batch_axes)
        if cfg.n_experts and rules.batch_axes
        else None
    )

    def serve_step(params, tokens, cache, position):
        return decode_step(cfg, params, tokens, cache, position, dist=dist)

    in_sh = (p_sh, tok_sh, c_sh, pos_sh)
    out_sh = (logits_sh, c_sh)
    return serve_step, in_sh, out_sh, (p, toks, caches, pos), (2,)
