"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the placeholder-device flag before ANY jax import — jax locks the
device count at first init.  Only this entry point does so; tests and
benches see the single real CPU device.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    LONG_CONTEXT_SKIPS,
    SHAPES,
    TrainHParams,
    cells,
    get_config,
    get_shape,
)
from repro.launch.mesh import make_production_mesh, mesh_devices  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    abstract_decode_args,
    abstract_prefill_args,
    abstract_train_args,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.roofline.analyze import analyze_compiled  # noqa: E402

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun"
)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, opts=None):
    """Lower one cell; returns (lowered, meta)."""
    cfg = get_config(arch)
    if opts:
        import dataclasses

        cfg = dataclasses.replace(cfg, **opts)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)

    if shape.kind == "train":
        fn, in_sh, out_sh, args, donate = make_train_step(cfg, shape, mesh)
    elif shape.kind == "prefill":
        fn, in_sh, out_sh, args, donate = make_prefill_step(cfg, shape, mesh)
    else:
        fn, in_sh, out_sh, args, donate = make_decode_step(cfg, shape, mesh)

    jitted = jax.jit(
        fn, in_shardings=in_sh, out_shardings=out_sh,
        donate_argnums=donate,
    )
    t0 = time.time()
    lowered = jitted.lower(*args)
    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh_devices(mesh),
        "kind": shape.kind,
        "lower_s": round(time.time() - t0, 2),
    }
    return lowered, meta, cfg, shape, mesh


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, opts=None,
             tag: str = "baseline") -> dict:
    lowered, meta, cfg, shape, mesh = lower_cell(
        arch, shape_name, multi_pod=multi_pod, opts=opts
    )
    t0 = time.time()
    compiled = lowered.compile()
    meta["compile_s"] = round(time.time() - t0, 2)
    meta["tag"] = tag
    meta.update(analyze_compiled(compiled, cfg, shape, mesh))
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--opts", default=None,
                    help="JSON dict of ModelConfig overrides (perf iteration)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    opts = json.loads(args.opts) if args.opts else None

    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape_name in todo:
        if shape_name == "long_500k" and arch in LONG_CONTEXT_SKIPS:
            print(f"SKIP {arch} × long_500k (pure full attention, DESIGN §4)")
            continue
        for mp in meshes:
            mesh_tag = "2x16x16" if mp else "16x16"
            out_path = os.path.join(
                args.out, f"{arch}__{shape_name}__{mesh_tag}__{args.tag}.json"
            )
            try:
                result = run_cell(
                    arch, shape_name, multi_pod=mp, opts=opts, tag=args.tag
                )
                with open(out_path, "w") as f:
                    json.dump(result, f, indent=1)
                print(
                    f"OK   {arch} × {shape_name} × {mesh_tag}: "
                    f"compile {result['compile_s']}s, "
                    f"{result['per_device_bytes'] / 2**30:.2f} GiB/chip, "
                    f"dominant={result['dominant_term']}"
                )
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                failures += 1
                print(f"FAIL {arch} × {shape_name} × {mesh_tag}: {e!r}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
