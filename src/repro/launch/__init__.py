"""Launch entry points: mesh.py (production meshes), dryrun.py (lower +
compile every arch × shape × mesh), train.py, serve.py, mine.py."""
