"""Process-mining CLI — the paper's pipeline end to end, through the
declarative query engine (``repro.query``): the CLI states *what* to mine
(log, dice, sink) and the engine's cost model picks the physical path
(streaming scan, device kernel, or mesh-distributed psum).

    # generate a synthetic BPI-like log and mine it
    PYTHONPATH=src python -m repro.launch.mine --events 500000 --dice-days 30

    # distributed DFG on the production mesh (placeholder devices)
    PYTHONPATH=src python -m repro.launch.mine --events 200000 --distributed
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--events", type=int, default=200_000)
    ap.add_argument("--activities", type=int, default=32)
    ap.add_argument("--dice-days", type=float, default=None)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "scatter", "onehot", "pallas",
                             "streaming"])
    ap.add_argument("--distributed", action="store_true",
                    help="shard_map DFG over the production mesh "
                         "(512 placeholder host devices)")
    ap.add_argument("--min-count", type=int, default=10)
    ap.add_argument("--dot-out", default=None)
    args = ap.parse_args()

    if args.distributed:
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", "")
        )

    from repro.core import discover_dependency_graph, to_dot
    from repro.data import ProcessSpec, generate_memmap_log
    from repro.query import Q, QueryEngine

    tmp = tempfile.mkdtemp(prefix="graphpm_mine_")
    spec = ProcessSpec(num_activities=args.activities, seed=7)
    t0 = time.perf_counter()
    log = generate_memmap_log(os.path.join(tmp, "log"), args.events, spec, seed=7)
    gen_s = time.perf_counter() - t0

    window = None
    if args.dice_days is not None:
        t_min = float(log.time[0])
        window = (t_min, t_min + args.dice_days * 86400.0)

    mesh = None
    if args.distributed:
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=True)
    engine = QueryEngine(
        mesh=mesh,
        # --distributed pins the device path: lift the out-of-core budget so
        # the pairs materialize onto the mesh instead of streaming host-side
        memory_budget_events=(
            max(args.events + 1, 1 << 22) if mesh is not None else 1 << 22
        ),
    )
    q = Q.log(log).using(engine)
    if window is not None:
        q = q.window(*window)

    t0 = time.perf_counter()
    res = q.dfg(backend=args.backend)
    psi = res.value
    mode = res.physical.backend
    if mode == "distributed":
        mode += f"({'x'.join(str(s) for s in mesh.devices.shape)})"
    dfg_s = time.perf_counter() - t0

    from repro.core.discovery import filter_dfg

    t0 = time.perf_counter()
    filtered = filter_dfg(psi, min_count=args.min_count)
    import numpy as np

    starts = np.zeros(log.num_activities, np.int64)
    ends = np.zeros(log.num_activities, np.int64)
    model = discover_dependency_graph(
        filtered, [f"act_{i:03d}" for i in range(log.num_activities)],
        starts, ends, min_count=args.min_count, min_dependency=0.3,
    )
    disc_s = time.perf_counter() - t0

    print(json.dumps({
        "events": log.num_events,
        "mode": mode,
        "plan": res.physical.describe(),
        "diced": window is not None,
        "gen_s": round(gen_s, 2),
        "dfg_s": round(dfg_s, 3),
        "discover_s": round(disc_s, 3),
        "total_pairs": int(psi.sum()),
        "edges_discovered": len(model.edges),
    }, indent=1))
    if args.dot_out:
        with open(args.dot_out, "w") as f:
            f.write(to_dot(model))
        print(f"wrote {args.dot_out}")


if __name__ == "__main__":
    main()
