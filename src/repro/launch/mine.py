"""Process-mining CLI — the paper's pipeline end to end.

    # generate a synthetic BPI-like log and mine it
    PYTHONPATH=src python -m repro.launch.mine --events 500000 --dice-days 30

    # distributed DFG on the production mesh (placeholder devices)
    PYTHONPATH=src python -m repro.launch.mine --events 200000 --distributed
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--events", type=int, default=200_000)
    ap.add_argument("--activities", type=int, default=32)
    ap.add_argument("--dice-days", type=float, default=None)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "scatter", "onehot", "pallas"])
    ap.add_argument("--distributed", action="store_true",
                    help="shard_map DFG over the production mesh "
                         "(512 placeholder host devices)")
    ap.add_argument("--min-count", type=int, default=10)
    ap.add_argument("--dot-out", default=None)
    args = ap.parse_args()

    if args.distributed:
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", "")
        )

    from repro.core import (
        discover_dependency_graph,
        distributed_dfg,
        dfg_numpy,
        streaming_dfg,
        to_dot,
    )
    from repro.data import ProcessSpec, generate_memmap_log

    tmp = tempfile.mkdtemp(prefix="graphpm_mine_")
    spec = ProcessSpec(num_activities=args.activities, seed=7)
    t0 = time.perf_counter()
    log = generate_memmap_log(os.path.join(tmp, "log"), args.events, spec, seed=7)
    gen_s = time.perf_counter() - t0

    window = None
    if args.dice_days is not None:
        t_min = float(log.time[0])
        window = (t_min, t_min + args.dice_days * 86400.0)

    t0 = time.perf_counter()
    if args.distributed:
        import jax
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=True)
        # stream the (possibly diced) rows to pair columns
        import numpy as np

        rng = log.rows_for_window(*window) if window else None
        srcs, dsts, valids = [], [], []
        from repro.core.streaming import StreamingDFGMiner

        # build pairs chunk-wise (host), count on the mesh (device)
        prev = {}
        for a, c, t in log.iter_chunks(row_range=rng):
            order = np.lexsort((np.arange(len(t)), t, c))
            a, c = a[order], c[order]
            same = np.zeros(len(a), bool)
            same[1:] = c[1:] == c[:-1]
            srcs.append(a[:-1][same[1:]])
            dsts.append(a[1:][same[1:]])
            first = ~same
            for i in np.nonzero(first)[0]:
                if int(c[i]) in prev:
                    srcs.append(np.array([prev[int(c[i])]], np.int32))
                    dsts.append(np.array([a[i]], np.int32))
            last = np.ones(len(a), bool)
            last[:-1] = ~same[1:]
            for i in np.nonzero(last)[0]:
                prev[int(c[i])] = int(a[i])
        src = np.concatenate(srcs).astype(np.int32)
        dst = np.concatenate(dsts).astype(np.int32)
        valid = np.ones_like(src, dtype=bool)
        psi = distributed_dfg(mesh, src, dst, valid, log.num_activities)
        mode = f"distributed({'x'.join(str(s) for s in mesh.devices.shape)})"
    else:
        psi = streaming_dfg(log, time_window=window)
        mode = "streaming"
    dfg_s = time.perf_counter() - t0

    from repro.core.discovery import filter_dfg

    t0 = time.perf_counter()
    filtered = filter_dfg(psi, min_count=args.min_count)
    import numpy as np

    starts = np.zeros(log.num_activities, np.int64)
    ends = np.zeros(log.num_activities, np.int64)
    model = discover_dependency_graph(
        filtered, [f"act_{i:03d}" for i in range(log.num_activities)],
        starts, ends, min_count=args.min_count, min_dependency=0.3,
    )
    disc_s = time.perf_counter() - t0

    print(json.dumps({
        "events": log.num_events,
        "mode": mode,
        "diced": window is not None,
        "gen_s": round(gen_s, 2),
        "dfg_s": round(dfg_s, 3),
        "discover_s": round(disc_s, 3),
        "total_pairs": int(psi.sum()),
        "edges_discovered": len(model.edges),
    }, indent=1))
    if args.dot_out:
        with open(args.dot_out, "w") as f:
            f.write(to_dot(model))
        print(f"wrote {args.dot_out}")


if __name__ == "__main__":
    main()
