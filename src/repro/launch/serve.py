"""Serving CLI: batched generation with the wave engine (reduced configs on
CPU; the decode step is the one the dry-run compiled for the pod).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --requests 6
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, vocab_size=512, loss_chunk=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(
        cfg, params, max_batch=args.max_batch, max_cache=256,
        temperature=args.temperature,
    )
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(1, cfg.vocab_size, size=rng.integers(2, 24)).tolist()
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    results = engine.generate(prompts, max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results)
    print(json.dumps({
        "arch": cfg.name,
        "requests": len(results),
        "generated_tokens": toks,
        "wall_s": round(dt, 3),
        "tok_per_s": round(toks / dt, 1),
        "sample": results[0].tokens[:8],
    }, indent=1))


if __name__ == "__main__":
    main()
