"""Production meshes.

Built as FUNCTIONS so importing this module never touches jax device state.
``dryrun.py`` sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import; tests/benches see the single real CPU device.
"""

from __future__ import annotations

import math

import jax

from repro.core.compat import make_mesh

__all__ = ["make_production_mesh", "make_test_mesh", "mesh_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devs)} — "
            "run under dryrun.py (placeholder host devices) or on the pod"
        )
    return make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    n = math.prod(shape)
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def mesh_devices(mesh) -> int:
    return math.prod(list(mesh.shape.values()))
