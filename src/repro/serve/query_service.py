"""Multi-tenant process-query serving — the ROADMAP's "mining queries for
millions of users" front door.

A :class:`QueryService` owns a registry of named event stores (in-memory
repositories and/or out-of-core memmap logs) and one shared
:class:`~repro.query.execute.QueryEngine`, so every tenant's dashboard
queries share the plan/result cache: the first analyst to ask for a diced
DFG pays the scan, everyone after is O(1).

The request surface is deliberately wire-friendly (dict in, dict out) so an
HTTP/RPC layer can wrap it without touching engine internals::

    svc = QueryService()
    svc.register("bpi", repo)
    out = svc.query({
        "log": "bpi", "sink": "dfg",
        "window": [t0, t1], "activities": ["a", "b"],
    })
    out["psi"], out["names"], out["from_cache"]

Topology sinks ride the engine's graph tier: ``{"sink": "process_map"}``
(significance-filtered map, k-anonymity floor applied to nodes *and*
edges) and ``{"sink": "neighborhood", "activity": a, "k": 2}`` are served
from the CSR event-knowledge graph once the engine's repeat-query
crossover builds it — repeated dashboard topology queries stop rescanning
the log entirely.

Conformance sinks (``{"sink": "fitness"}`` / ``{"sink": "alignments"}``)
expose aggregate replay/alignment conformance.  The model defaults to the
log's own whole-log discovered dependency graph; ``"model_of": other_log``
replays against another registered log's model (cross-deployment
conformance) — the other log's policy joins the request's policy
combination, so a tenant cannot route around a view through a model.  Only
aggregates and the deviation census leave the service, and the census
obeys the k-anonymity floor: deviating flows below the floor are not
reported.

Multi-log requests name several registered logs at once and compile to the
engine's union source algebra::

    svc.query({"logs": ["prod", "canary"], "sink": "compare",
               "window": [t0, t1]})
    # → per-log Ψ on the aligned vocabulary, drift matrices, replay fitness

Per-tenant access control reuses :class:`repro.core.views.AccessPolicy`:
a policy registered with the log is enforced on every request (view
projection applied in-plan, time dicing gated).  Across a union the
*combination* of the named logs' policies applies — the k-anonymity floor
is the maximum of the per-log floors, time dicing must be allowed by every
log, and logs under different (or partially missing) views cannot be
combined at all: a compare must not leak a log the tenant cannot see at
full resolution through the diff against a log they can.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.lockdep import make_lock
from repro.core.streaming import MemmapLog, MemmapLogWriter
from repro.core.views import AccessDenied, AccessPolicy, ActivityView
from repro.graph.shard import ShardedLog
from repro.query import (
    AlignmentsSink,
    ApplyView,
    CompareSink,
    DFGSink,
    FitnessSink,
    HistogramSink,
    NeighborhoodSink,
    ProcessMapSink,
    Q,
    Query,
    QueryEngine,
    QueryPlanError,
    VariantsSink,
)

__all__ = ["QueryService", "RequestProbe"]


@dataclasses.dataclass
class _Grant:
    """The effective policy for one request (single log or union)."""

    floor: int = 0
    view: Optional[ActivityView] = None
    time_windows_allowed: bool = True

    @property
    def has_view(self) -> bool:
        return self.view is not None


def _combine_policies(
    names: List[str], policies: List[Optional[AccessPolicy]]
) -> _Grant:
    """Cross-union policy combination (strictest-wins).

    Views are special: applying one view to a union only makes sense when
    every member is governed by the *same* view — otherwise the union (or a
    compare diff) would expose a log at a resolution its own policy forbids.
    """
    floor = max(
        (p.min_group_count for p in policies if p is not None), default=0
    )
    allowed = all(
        p.time_windows_allowed for p in policies if p is not None
    )
    views = [(n, p.view) for n, p in zip(names, policies)
             if p is not None and p.view is not None]
    if not views:
        return _Grant(floor=floor, view=None, time_windows_allowed=allowed)
    if len(views) != len(names):
        bare = [n for n, p in zip(names, policies)
                if p is None or p.view is None]
        raise AccessDenied(
            f"logs {sorted(n for n, _ in views)} are view-protected but "
            f"{bare} are not; a union would expose them side by side"
        )
    canon = ApplyView.from_view(views[0][1])
    for n, v in views[1:]:
        if ApplyView.from_view(v) != canon:
            raise AccessDenied(
                f"logs {names} are governed by different views and cannot "
                "be combined in one union/compare"
            )
    return _Grant(
        floor=floor, view=views[0][1], time_windows_allowed=allowed
    )


@dataclasses.dataclass(frozen=True)
class RequestProbe:
    """Everything the transport tier needs to admit, coalesce, and lane one
    request — computed at *enqueue time*, before anything queues.

    ``group_key`` is the in-flight coalescing identity: requests are
    dedup'd by (effective tenant policy, canonical plan, source
    fingerprint).  The fingerprint is the one observed when this probe ran,
    so an append that moves a log's fingerprint splits pre-append and
    post-append waiters into different groups — a coalesced execution that
    started against the old bytes is never fanned out to a waiter that
    enqueued after the data changed.

    ``cached`` / ``delta_hint`` / ``estimated_cost_s`` are the SLO
    classifier's inputs: a predicted cache/delta/graph serve is *hot*
    (~µs–ms), a predicted cold scan is *cold* (~100s of ms) and must not
    head-of-line-block the warm lane."""

    sink: str
    names: Tuple[str, ...]
    fingerprint: str
    policy_token: str
    plan_token: str
    backend: str
    cached: bool
    delta_hint: bool
    estimated_cost_s: float
    coalescable: bool

    @property
    def group_key(self) -> Tuple[str, str, str]:
        return (self.policy_token, self.plan_token, self.fingerprint)


def _policy_token(grant: _Grant) -> str:
    """Canonical string identity of an effective request policy: two
    tenants under byte-identical effective policies may share a coalesced
    execution; any difference (floor, view, dicing rights) must not."""
    view = (
        repr(ApplyView.from_view(grant.view)) if grant.has_view else "-"
    )
    return (
        f"floor={grant.floor};dicing={int(grant.time_windows_allowed)};"
        f"view={view}"
    )


class QueryService:
    def __init__(
        self,
        engine: Optional[QueryEngine] = None,
        *,
        forensics_floor: int = 0,
        slo_objectives=None,
    ):
        from repro.obs import SLOEngine

        self.engine = engine or QueryEngine()
        # k-anonymity floor for the engine-introspection sinks ("forensics",
        # "metrics", and "slo") when the request names no logs; when it
        # does, the strictest of this and the named logs' combined floor
        # applies
        self.forensics_floor = int(forensics_floor)
        # declarative SLOs over the shared engine registry (the transport
        # tier's series live there too), served via {"sink": "slo"} and the
        # HTTP GET /slo endpoint
        self.slo = SLOEngine(self.engine.metrics, objectives=slo_objectives)
        self._logs: Dict[str, object] = {}
        self._policies: Dict[str, Optional[AccessPolicy]] = {}
        self._lock = make_lock("QueryService")
        # one lock per registered name: appends write three column files +
        # meta.json and must never interleave on the same log
        self._append_locks: Dict[str, threading.Lock] = {}

    # -- registry ------------------------------------------------------------
    def register(
        self, name: str, source, policy: Optional[AccessPolicy] = None
    ) -> None:
        """Attach a repository or memmap log under a tenant-visible name."""
        with self._lock:
            self._logs[name] = source
            self._policies[name] = policy

    def unregister(self, name: str) -> None:
        with self._lock:
            self._logs.pop(name, None)
            self._policies.pop(name, None)
            self._append_locks.pop(name, None)

    def logs(self):
        with self._lock:
            return sorted(self._logs)

    # -- the live-append endpoint ---------------------------------------------
    def append(self, request: Dict) -> Dict:
        """Append a time-ordered batch of events to a registered memmap log.

        Request: ``{"log": name, "activity": [...], "case": [...],
        "time": [...]}`` (aligned arrays).  The grown log replaces the
        registered handle, and because the engine's fingerprints are
        prefix-preserving, tenants' cached dashboard queries stay warm: the
        next query per plan runs a ``delta`` scan over just this suffix (or
        is served unchanged when its window predates the append) instead of
        a full rescan.  Union dashboards over several logs stay warm the
        same way — only the appended branch is rescanned.

        A registered :class:`ShardedLog` routes the batch to its owning
        shards (``case % K``): only those shards' fingerprints change, so
        the next sharded-graph query rescans just the owning shards'
        suffixes and serves every other shard from cache.
        """
        name = request.get("log")
        with self._lock:
            if name not in self._logs:
                raise KeyError(f"unknown log {name!r}")
            source = self._logs[name]
            append_lock = self._append_locks.setdefault(
                name, make_lock("QueryService.append")
            )
        if not isinstance(source, (MemmapLog, ShardedLog)):
            raise QueryPlanError(
                f"log {name!r} is an in-memory repository; only memmap and "
                "sharded logs support live appends"
            )
        activity = np.asarray(request["activity"], dtype=np.int32)
        case = np.asarray(request["case"], dtype=np.int32)
        time = np.asarray(request["time"], dtype=np.float64)
        if not (activity.shape == case.shape == time.shape):
            raise ValueError("activity/case/time must be aligned 1-D arrays")
        with append_lock:  # serialize writers: column files must not interleave
            with self._lock:
                source = self._logs.get(name, source)  # newest handle
            if isinstance(source, ShardedLog):
                grown = source.append(activity, case, time)
            else:
                writer = MemmapLogWriter.open_append(source.path)
                writer.append(activity, case, time)
                grown = writer.close()
            with self._lock:
                if name in self._logs:  # unless unregistered mid-append
                    self._logs[name] = grown
        return {
            "log": name,
            "appended": int(activity.shape[0]),
            "num_events": grown.num_events,
            "num_activities": grown.num_activities,
        }

    # -- the serving endpoint -------------------------------------------------
    def _resolve(self, names: List[str]) -> Tuple[List[object], _Grant]:
        with self._lock:
            for n in names:
                if n not in self._logs:
                    raise KeyError(f"unknown log {n!r}")
            sources = [self._logs[n] for n in names]
            policies = [self._policies[n] for n in names]
        return sources, _combine_policies(names, policies)

    def _build_query(
        self, request: Dict, sources: List[object], names: List[str],
        grant: _Grant,
    ) -> Query:
        if len(names) == 1:
            q = Q.log(sources[0]).using(self.engine)
        else:
            q = Q.logs(*zip(sources, names)).using(self.engine)
        if request.get("window") is not None:
            if not grant.time_windows_allowed:
                raise AccessDenied("time dicing not permitted by policy")
            t0, t1 = request["window"]
            q = q.window(float(t0), float(t1))
        if request.get("activities") is not None:
            if grant.has_view:
                # a raw-activity filter under a coarsening view would expose
                # per-activity counts inside a group (and probe raw names)
                raise AccessDenied(
                    "activity filters name raw activities and are not "
                    "permitted under a view policy"
                )
            q = q.activities(
                request["activities"], relink=bool(request.get("relink", False))
            )
        if request.get("top_variants") is not None:
            q = q.top_variants(int(request["top_variants"]))
        if grant.has_view:
            q = q.view(grant.view)
        return q

    @staticmethod
    def _floor_process_map(pm, floor: int) -> Dict:
        """k-anonymity on a process map: nodes below the floor disappear,
        and so does every edge below the floor or touching a dropped node —
        a sub-floor activity must not be reconstructible from its flows."""
        keep = {
            a for a, c in zip(pm.activities, pm.node_counts)
            if not floor or int(c) >= floor
        }
        edges = [
            (s, d, int(c)) for s, d, c in pm.edges
            if s in keep and d in keep and (not floor or int(c) >= floor)
        ]
        return {
            "activities": [a for a in pm.activities if a in keep],
            "node_counts": [
                int(c) for a, c in zip(pm.activities, pm.node_counts)
                if a in keep
            ],
            "edges": [list(e) for e in edges],
            "top": pm.top,
            "edge_top": pm.edge_top,
            "dropped_activities": (
                pm.dropped_activities + len(pm.activities) - len(keep)
            ),
            "dropped_edges": pm.dropped_edges + len(pm.edges) - len(edges),
        }

    @staticmethod
    def _floor_census(res, floor: int) -> List[Dict]:
        """k-anonymity on a deviation census: a deviating flow observed
        fewer than ``floor`` times is not reported (it could identify a
        handful of cases); survivors are sorted most-frequent first."""
        kept = [
            {"edge": [s, d], "count": int(c)}
            for (s, d), c in res.deviating_edges.items()
            if not floor or int(c) >= floor
        ]
        kept.sort(key=lambda e: (-e["count"], e["edge"]))
        return kept

    @staticmethod
    def _floor_neighborhood(nb, floor: int) -> Dict:
        """k-anonymity on a neighborhood: sub-floor edges are dropped, and
        with them any reached activity left without a surviving edge (the
        center always remains)."""
        edges = [
            (s, d, int(c)) for s, d, c in nb.edges
            if not floor or int(c) >= floor
        ]
        touched = {nb.center}
        for s, d, _ in edges:
            touched.add(s)
            touched.add(d)
        acts = [a for a in nb.activities if a in touched]
        return {
            "center": nb.center,
            "k": nb.k,
            "direction": nb.direction,
            "activities": acts,
            "hops": {a: nb.hops[a] for a in acts},
            "edges": [list(e) for e in edges],
        }

    # -- engine introspection -------------------------------------------------
    def _introspection_floor(self, request: Dict) -> int:
        """Floor for introspection sinks: named logs' combined grant (if
        any) joined with the service-level ``forensics_floor`` — whichever
        is strictest.  Engine spans aggregate *every* tenant's activity, so
        a tenant must not see below any floor they are subject to."""
        multi = request.get("logs")
        names = [str(n) for n in multi] if multi else (
            [request["log"]] if request.get("log") is not None else []
        )
        floor = self.forensics_floor
        if names:
            _, grant = self._resolve(names)
            floor = max(floor, grant.floor)
        return floor

    def _introspect(self, request: Dict, sink: str) -> Dict:
        floor = self._introspection_floor(request)
        if sink == "slo":
            payload = self.slo.evaluate(floor=floor)
            payload["floor"] = floor
            return payload
        if sink == "metrics":
            payload = {
                "sink": "metrics",
                "floor": floor,
                "metrics": self.engine.metrics_snapshot(floor=floor),
            }
            if request.get("format") == "prometheus":
                from repro.obs import kernel_registry, prometheus_text

                payload["prometheus"] = prometheus_text(
                    self.engine.metrics, kernel_registry()
                )
            return payload
        # forensics: mine the engine's own span telemetry through the
        # engine itself (the forensics query then shows up in the next one)
        telemetry = self.engine.telemetry
        events = len(telemetry)
        if events == 0:
            return {
                "sink": "forensics", "floor": floor, "events": 0,
                "dropped_events": telemetry.dropped,
                "psi": [], "names": [],
            }
        res = Q.log(self.engine.own_telemetry()).using(self.engine).dfg()
        psi = res.value
        if floor:
            psi = np.where(psi >= floor, psi, 0)
        return {
            "sink": "forensics",
            "floor": floor,
            "events": events,
            "dropped_events": telemetry.dropped,
            "psi": psi.tolist(),
            "names": res.names,
            "from_cache": res.from_cache,
            "backend": res.physical.backend,
            "wall_s": res.wall_s,
        }

    @staticmethod
    def _sink_object(request: Dict, grant: _Grant):
        """The sink instance ``query()`` would run for this request —
        fully parameterized, so its canonical plan key covers every
        response-shaping argument (top/edge_top/k/direction/backend).
        Conformance sinks are built *without* the resolved model (resolving
        may run discovery — far too heavy for an admission-time probe);
        ``model_of`` joins the plan token instead."""
        sink = request.get("sink", "dfg")
        backend = request.get("backend", "auto")
        if sink == "dfg":
            return DFGSink(backend=backend)
        if sink == "histogram":
            return HistogramSink()
        if sink == "variants":
            if grant.has_view:
                raise AccessDenied(
                    "variants expose raw sequences and are not permitted "
                    "under a view policy"
                )
            k = request.get("k")
            return VariantsSink(int(k) if k is not None else None)
        if sink == "process_map":
            return ProcessMapSink(
                top=float(request.get("top", 0.2)),
                edge_top=(
                    float(request["edge_top"])
                    if request.get("edge_top") is not None
                    else None
                ),
                backend=backend,
            )
        if sink == "neighborhood":
            if request.get("activity") is None:
                raise KeyError('"neighborhood" requests need an "activity"')
            return NeighborhoodSink(
                str(request["activity"]),
                k=int(request.get("k", 1)),
                direction=str(request.get("direction", "out")),
                backend=backend,
            )
        if sink == "fitness":
            return FitnessSink(backend=backend)
        if sink == "alignments":
            return AlignmentsSink(backend=backend)
        if sink == "compare":
            return CompareSink(backend=backend)
        raise QueryPlanError(f"unknown sink {sink!r}")

    def probe(self, request: Dict) -> RequestProbe:
        """Admission-time probe for the transport tier (read-only).

        Resolves the request exactly as :meth:`query` would — same policy
        combination, same canonical plan — but executes nothing and mutates
        no engine state, and returns the :class:`RequestProbe` the serving
        layer coalesces and lanes on.  Raises the same ``KeyError`` /
        ``AccessDenied`` / ``QueryPlanError`` a real execution would, so
        invalid requests are rejected before they queue."""
        sink = request.get("sink", "dfg")
        if sink in ("forensics", "metrics", "slo"):
            floor = self._introspection_floor(request)
            # introspection responses are point-in-time snapshots of the
            # live engine — there is no stable source fingerprint to
            # coalesce on, and they are ~µs serves anyway
            return RequestProbe(
                sink=sink,
                names=(),
                fingerprint="live",
                policy_token=f"floor={floor}",
                plan_token=(
                    f"{sink};format={request.get('format')};"
                    f"trace={int(bool(request.get('trace')))}"
                ),
                backend="introspect",
                cached=False,
                delta_hint=False,
                estimated_cost_s=1e-4,
                coalescable=False,
            )
        multi = request.get("logs")
        if multi is not None:
            names = [str(n) for n in multi]
            if not names:
                raise QueryPlanError('"logs" must name at least one log')
        else:
            names = [request.get("log")]
            if names[0] is None:
                raise KeyError("request names no log")
        model_of = (
            str(request["model_of"])
            if sink in ("fitness", "alignments")
            and request.get("model_of") is not None
            else None
        )
        if model_of is not None:
            combined = list(dict.fromkeys(names + [model_of]))
            sources_c, grant = self._resolve(combined)
            sources = [sources_c[combined.index(n)] for n in names]
        else:
            sources, grant = self._resolve(names)
        q = self._build_query(request, sources, names, grant)
        plan = self.engine.probe(q, self._sink_object(request, grant))
        plan_token = (
            f"{plan.plan_key};trace={int(bool(request.get('trace')))}"
        )
        if model_of is not None:
            plan_token += f";model_of={model_of}"
        return RequestProbe(
            sink=sink,
            names=tuple(names),
            fingerprint=plan.fingerprint,
            policy_token=_policy_token(grant),
            plan_token=plan_token,
            backend=plan.backend,
            cached=plan.cached,
            delta_hint=plan.delta_hint,
            estimated_cost_s=plan.estimated_cost_s,
            coalescable=True,
        )

    def query(self, request: Dict, trace_context=None) -> Dict:
        """Execute one request dict; returns a JSON-shaped response dict.

        ``{"log": name}`` targets a single registered log; ``{"logs":
        [name, ...]}`` targets their union (sinks ``dfg`` / ``histogram`` /
        ``variants`` merge; sink ``compare`` keeps the logs apart and
        reports drift).

        Three introspection sinks need no log at all: ``{"sink":
        "forensics"}`` mines the engine's own execution spans into a DFG of
        the serving process, ``{"sink": "metrics"}`` snapshots the
        engine's counters/histograms (``"format": "prometheus"`` adds the
        text exposition), and ``{"sink": "slo"}`` evaluates the declarative
        objectives (verdicts, error budgets, burn rates).  Any request may
        set ``"trace": true`` to attach the per-query execution trace to
        the response; every non-introspection response carries the
        execution's ``trace_id``.

        ``trace_context`` (a :class:`repro.obs.TraceContext`) scopes the
        engine execution under the caller's distributed trace — the
        transport tier passes its request span here so the engine trace
        (and every shard/union sub-trace under it) shares the request's
        trace id."""
        if trace_context is not None:
            with self.engine.trace_scope(trace_context):
                return self._query(request)
        return self._query(request)

    def _query(self, request: Dict) -> Dict:
        if request.get("sink") in ("forensics", "metrics", "slo"):
            return self._introspect(request, request["sink"])
        multi = request.get("logs")
        if multi is not None:
            names = [str(n) for n in multi]
            if not names:
                raise QueryPlanError('"logs" must name at least one log')
        else:
            names = [request.get("log")]
            if names[0] is None:
                raise KeyError("request names no log")
        sink = request.get("sink", "dfg")
        model_src = None
        if (
            sink in ("fitness", "alignments")
            and request.get("model_of") is not None
        ):
            # cross-log conformance: the reference log's policy joins the
            # combination (strictest wins) before anything runs — a tenant
            # cannot route around a log's view through its model
            other = str(request["model_of"])
            combined = list(dict.fromkeys(names + [other]))
            sources_c, grant = self._resolve(combined)
            sources = [sources_c[combined.index(n)] for n in names]
            model_src = sources_c[combined.index(other)]
        else:
            sources, grant = self._resolve(names)
        q = self._build_query(request, sources, names, grant)
        floor = grant.floor
        if sink == "dfg":
            res = q.dfg(backend=request.get("backend", "auto"))
            psi = res.value
            if floor:
                psi = np.where(psi >= floor, psi, 0)
            payload = {"psi": psi.tolist(), "names": res.names}
        elif sink == "histogram":
            res = q.histogram()
            counts = res.value
            if floor:
                counts = np.where(counts >= floor, counts, 0)
            payload = {"counts": counts.tolist(), "names": res.names}
        elif sink == "variants":
            if grant.has_view:
                # variant sequences spell out raw activity names
                raise AccessDenied(
                    "variants expose raw sequences and are not permitted "
                    "under a view policy"
                )
            k = request.get("k")
            res = q.variants(int(k) if k is not None else None)
            tv = res.value
            keep = (
                tv.counts >= floor if floor
                else np.ones(len(tv.counts), dtype=bool)
            )
            payload = {
                "counts": tv.counts[keep].tolist(),
                "sequences": [s for s, ok in zip(tv.sequences, keep) if ok],
            }
        elif sink == "process_map":
            res = q.process_map(
                top=float(request.get("top", 0.2)),
                edge_top=(
                    float(request["edge_top"])
                    if request.get("edge_top") is not None
                    else None
                ),
                backend=request.get("backend", "auto"),
            )
            payload = self._floor_process_map(res.value, floor)
        elif sink == "neighborhood":
            if request.get("activity") is None:
                raise KeyError('"neighborhood" requests need an "activity"')
            res = q.neighborhood(
                str(request["activity"]),
                k=int(request.get("k", 1)),
                direction=str(request.get("direction", "out")),
                backend=request.get("backend", "auto"),
            )
            payload = self._floor_neighborhood(res.value, floor)
        elif sink in ("fitness", "alignments"):
            model = None
            if model_src is not None:
                from repro.query.ast import FitnessSink
                from repro.query.execute import _Collected

                st = _Collected(repo=None)
                if grant.has_view:
                    st.view = ApplyView.from_view(grant.view)
                model = self.engine._model_for_source(
                    FitnessSink(), (), model_src, st
                )
            backend = request.get("backend", "auto")
            if sink == "fitness":
                res = q.fitness(model, backend=backend)
                rr = res.value
                payload = {
                    "fitness": rr.fitness,
                    "perfect_traces": rr.perfectly_fitting,
                    "total_traces": int(rr.trace_fitness.shape[0]),
                    "deviations": self._floor_census(rr, floor),
                }
            else:
                res = q.alignments(model, backend=backend)
                ar = res.value
                payload = {
                    "fitness": ar.fitness,
                    "perfect_traces": ar.perfectly_fitting,
                    "total_traces": int(ar.trace_cost.shape[0]),
                    "mean_cost": (
                        float(ar.trace_cost.mean())
                        if ar.trace_cost.shape[0] else 0.0
                    ),
                    "empty_cost": ar.empty_cost,
                    "deviations": self._floor_census(ar, floor),
                }
        elif sink == "compare":
            res = q.compare(backend=request.get("backend", "auto"))
            cr = res.value
            # the k-anonymity floor applies to every exposed matrix; drift
            # is recomputed from the floored Ψs so sub-floor counts cannot
            # be reconstructed from a (raw) difference
            psis = [
                np.where(p >= floor, p, 0) if floor else p for p in cr.psis
            ]
            payload = {
                "names": cr.names,
                "psi": {n: p.tolist() for n, p in zip(cr.log_names, psis)},
                "diff": {
                    n: (p - psis[0]).tolist()
                    for n, p in zip(cr.log_names, psis)
                },
                "fitness": {
                    n: f for n, f in zip(cr.log_names, cr.fitness)
                },
            }
        else:
            raise QueryPlanError(f"unknown sink {sink!r}")

        payload.update({
            "log": names[0] if multi is None else None,
            "logs": names if multi is not None else None,
            "sink": sink,
            "from_cache": res.from_cache,
            "backend": res.physical.backend,
            "wall_s": res.wall_s,
            # the execution's distributed-trace id (a cache hit reports the
            # hit's own trace; its links name the populating run)
            "trace_id": (
                res.trace.trace_id if res.trace is not None else None
            ),
        })
        if request.get("trace"):
            payload["trace"] = (
                res.trace.to_dict() if res.trace is not None else None
            )
        return payload
