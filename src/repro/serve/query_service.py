"""Multi-tenant process-query serving — the ROADMAP's "mining queries for
millions of users" front door.

A :class:`QueryService` owns a registry of named event stores (in-memory
repositories and/or out-of-core memmap logs) and one shared
:class:`~repro.query.execute.QueryEngine`, so every tenant's dashboard
queries share the plan/result cache: the first analyst to ask for a diced
DFG pays the scan, everyone after is O(1).

The request surface is deliberately wire-friendly (dict in, dict out) so an
HTTP/RPC layer can wrap it without touching engine internals::

    svc = QueryService()
    svc.register("bpi", repo)
    out = svc.query({
        "log": "bpi", "sink": "dfg",
        "window": [t0, t1], "activities": ["a", "b"],
    })
    out["psi"], out["names"], out["from_cache"]

Per-tenant access control reuses :class:`repro.core.views.AccessPolicy`:
a policy registered with the log is enforced on every request (view
projection applied in-plan, time dicing gated).
"""

from __future__ import annotations

import threading
from typing import Dict, Optional

import numpy as np

from repro.core.streaming import MemmapLog, MemmapLogWriter
from repro.core.views import AccessDenied, AccessPolicy
from repro.query import Q, QueryEngine, QueryPlanError

__all__ = ["QueryService"]


class QueryService:
    def __init__(self, engine: Optional[QueryEngine] = None):
        self.engine = engine or QueryEngine()
        self._logs: Dict[str, object] = {}
        self._policies: Dict[str, Optional[AccessPolicy]] = {}
        self._lock = threading.Lock()
        # one lock per registered name: appends write three column files +
        # meta.json and must never interleave on the same log
        self._append_locks: Dict[str, threading.Lock] = {}

    # -- registry ------------------------------------------------------------
    def register(
        self, name: str, source, policy: Optional[AccessPolicy] = None
    ) -> None:
        """Attach a repository or memmap log under a tenant-visible name."""
        with self._lock:
            self._logs[name] = source
            self._policies[name] = policy

    def unregister(self, name: str) -> None:
        with self._lock:
            self._logs.pop(name, None)
            self._policies.pop(name, None)
            self._append_locks.pop(name, None)

    def logs(self):
        with self._lock:
            return sorted(self._logs)

    # -- the live-append endpoint ---------------------------------------------
    def append(self, request: Dict) -> Dict:
        """Append a time-ordered batch of events to a registered memmap log.

        Request: ``{"log": name, "activity": [...], "case": [...],
        "time": [...]}`` (aligned arrays).  The grown log replaces the
        registered handle, and because the engine's fingerprints are
        prefix-preserving, tenants' cached dashboard queries stay warm: the
        next query per plan runs a ``delta`` scan over just this suffix (or
        is served unchanged when its window predates the append) instead of
        a full rescan.
        """
        name = request.get("log")
        with self._lock:
            if name not in self._logs:
                raise KeyError(f"unknown log {name!r}")
            source = self._logs[name]
            append_lock = self._append_locks.setdefault(name, threading.Lock())
        if not isinstance(source, MemmapLog):
            raise QueryPlanError(
                f"log {name!r} is an in-memory repository; only memmap logs "
                "support live appends"
            )
        activity = np.asarray(request["activity"], dtype=np.int32)
        case = np.asarray(request["case"], dtype=np.int32)
        time = np.asarray(request["time"], dtype=np.float64)
        if not (activity.shape == case.shape == time.shape):
            raise ValueError("activity/case/time must be aligned 1-D arrays")
        with append_lock:  # serialize writers: column files must not interleave
            with self._lock:
                source = self._logs.get(name, source)  # newest handle
            writer = MemmapLogWriter.open_append(source.path)
            writer.append(activity, case, time)
            grown = writer.close()
            with self._lock:
                if name in self._logs:  # unless unregistered mid-append
                    self._logs[name] = grown
        return {
            "log": name,
            "appended": int(activity.shape[0]),
            "num_events": grown.num_events,
            "num_activities": grown.num_activities,
        }

    # -- the serving endpoint -------------------------------------------------
    def query(self, request: Dict) -> Dict:
        """Execute one request dict; returns a JSON-shaped response dict."""
        name = request.get("log")
        with self._lock:
            if name not in self._logs:
                raise KeyError(f"unknown log {name!r}")
            source = self._logs[name]
            policy = self._policies[name]

        has_view = policy is not None and policy.view is not None
        floor = policy.min_group_count if policy is not None else 0

        q = Q.log(source).using(self.engine)
        if request.get("window") is not None:
            if policy is not None and not policy.time_windows_allowed:
                raise AccessDenied("time dicing not permitted by policy")
            t0, t1 = request["window"]
            q = q.window(float(t0), float(t1))
        if request.get("activities") is not None:
            if has_view:
                # a raw-activity filter under a coarsening view would expose
                # per-activity counts inside a group (and probe raw names)
                raise AccessDenied(
                    "activity filters name raw activities and are not "
                    "permitted under a view policy"
                )
            q = q.activities(
                request["activities"], relink=bool(request.get("relink", False))
            )
        if request.get("top_variants") is not None:
            q = q.top_variants(int(request["top_variants"]))
        if has_view:
            q = q.view(policy.view)

        sink = request.get("sink", "dfg")
        if sink == "dfg":
            res = q.dfg(backend=request.get("backend", "auto"))
            psi = res.value
            if floor:
                psi = np.where(psi >= floor, psi, 0)
            payload = {"psi": psi.tolist(), "names": res.names}
        elif sink == "histogram":
            res = q.histogram()
            counts = res.value
            if floor:
                counts = np.where(counts >= floor, counts, 0)
            payload = {"counts": counts.tolist(), "names": res.names}
        elif sink == "variants":
            if has_view:
                # variant sequences spell out raw activity names
                raise AccessDenied(
                    "variants expose raw sequences and are not permitted "
                    "under a view policy"
                )
            k = request.get("k")
            res = q.variants(int(k) if k is not None else None)
            tv = res.value
            keep = (
                tv.counts >= floor if floor
                else np.ones(len(tv.counts), dtype=bool)
            )
            payload = {
                "counts": tv.counts[keep].tolist(),
                "sequences": [s for s, ok in zip(tv.sequences, keep) if ok],
            }
        else:
            raise QueryPlanError(f"unknown sink {sink!r}")

        payload.update({
            "log": name,
            "sink": sink,
            "from_cache": res.from_cache,
            "backend": res.physical.backend,
            "wall_s": res.wall_s,
        })
        return payload
