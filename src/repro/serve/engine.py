"""Batched serving engine: wave batching with ragged prompts.

Requests are grouped into fixed-size waves; within a wave prompts are
right-padded, prefilled once (per-sequence last-position logits), then
decoded with **per-sequence positions** (vector ``pos``) so each stream
advances from its own true length.  Finished sequences (stop token or
length) are masked out; the wave ends when all finish.

Greedy or temperature sampling; deterministic per seed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.telemetry import EventCollector
from repro.models import decode_step, prefill

__all__ = ["ServeEngine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    prompt: List[int]
    tokens: List[int]
    finished: str  # "stop" | "length"


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 8,
        max_cache: int = 512,
        q_chunk: int = 64,
        temperature: float = 0.0,
        seed: int = 0,
        collector: Optional[EventCollector] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_cache = max_cache
        self.q_chunk = q_chunk
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        # explicit None check: an empty collector is falsy (len == 0).
        # The default is ring-buffered: a long-lived server keeps the most
        # recent 64Ki spans for forensics instead of growing without bound
        # (drops are counted — see EventCollector.dropped).
        self.collector = (
            collector
            if collector is not None
            else EventCollector("server", max_events=1 << 16)
        )

        self._prefill = jax.jit(
            lambda p, b, li: prefill(
                cfg, p, b, self.max_cache, q_chunk=q_chunk, last_index=li
            )
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(cfg, p, t, c, pos)
        )

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1)

    def generate(
        self,
        prompts: List[List[int]],
        max_new_tokens: int = 32,
        stop_token: Optional[int] = None,
    ) -> List[GenerationResult]:
        results: List[Optional[GenerationResult]] = [None] * len(prompts)
        order = sorted(range(len(prompts)), key=lambda i: len(prompts[i]))
        for w0 in range(0, len(order), self.max_batch):
            wave = order[w0 : w0 + self.max_batch]
            self._run_wave(wave, prompts, results, max_new_tokens, stop_token)
        return [r for r in results if r is not None]

    def mine_telemetry(self, time_window=None):
        """Mine the engine's own runtime telemetry (wave/prefill/decode
        spans) through the process-query engine.

        Returns the :class:`repro.query.QueryResult` for the DFG of the
        serving process — the fault/straggler forensics view.  Each wave is
        one trace; a healthy engine's DFG is ``prefill → decode^k``."""
        from repro.query import Q

        q = Q.log(self.collector.to_repository())
        if time_window is not None:
            q = q.window(*time_window)
        return q.dfg()

    def _run_wave(self, wave, prompts, results, max_new, stop_token):
        B = len(wave)
        lens = np.asarray([len(prompts[i]) for i in wave], dtype=np.int32)
        L = int(lens.max())
        toks = np.zeros((B, L), dtype=np.int32)
        for r, i in enumerate(wave):
            toks[r, : lens[r]] = prompts[i]
        case = f"wave-{wave[0]}"
        with self.collector.span(case, "prefill"):
            caches, logits = self._prefill(
                self.params,
                {"tokens": jnp.asarray(toks)},
                jnp.asarray(lens - 1),
            )
        pos = jnp.asarray(lens)  # next write slot per sequence
        out = [[] for _ in range(B)]
        done = np.zeros(B, dtype=bool)
        finished = ["length"] * B
        for t in range(max_new):
            nxt = self._sample(logits)
            nxt_np = np.asarray(nxt)
            for r in range(B):
                if not done[r]:
                    tok = int(nxt_np[r])
                    if stop_token is not None and tok == stop_token:
                        done[r] = True
                        finished[r] = "stop"
                    else:
                        out[r].append(tok)
            if done.all() or t == max_new - 1:
                break
            with self.collector.span(case, "decode"):
                logits, caches = self._decode(
                    self.params, nxt[:, None].astype(jnp.int32), caches, pos
                )
            pos = pos + 1
            if int(pos.max()) >= self.max_cache:
                break
        for r, i in enumerate(wave):
            results[i] = GenerationResult(
                prompt=list(prompts[i]), tokens=out[r], finished=finished[r]
            )
