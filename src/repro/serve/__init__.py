from .engine import GenerationResult, ServeEngine
from .query_service import QueryService, RequestProbe

__all__ = ["GenerationResult", "ServeEngine", "QueryService", "RequestProbe"]
