from .engine import GenerationResult, ServeEngine
from .query_service import QueryService

__all__ = ["GenerationResult", "ServeEngine", "QueryService"]
