"""Hand-rolled optimizer stack (optax is not available offline).

AdamW with decoupled weight decay, global-norm clipping, and
linear-warmup + cosine-decay schedule.  Pure pytree transforms; optimizer
state shards exactly like the parameters (same tree structure)."""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainHParams

__all__ = ["OptState", "init_opt_state", "adamw_update", "lr_schedule",
           "global_norm"]


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Dict  # first moment (params tree)
    nu: Dict  # second moment (params tree)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=zeros,
        nu=jax.tree.map(jnp.copy, zeros),
    )


def lr_schedule(hp: TrainHParams, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = hp.learning_rate * s / max(hp.warmup_steps, 1)
    prog = jnp.clip(
        (s - hp.warmup_steps) / max(hp.total_steps - hp.warmup_steps, 1), 0, 1
    )
    cos = hp.learning_rate * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < hp.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    hp: TrainHParams, params, grads, state: OptState
) -> Tuple[Dict, OptState, Dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(hp, step)
    b1, b2 = hp.beta1, hp.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + hp.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + hp.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(step, new_mu, new_nu), metrics
