from .optimizer import OptState, adamw_update, global_norm, init_opt_state, lr_schedule
from .grad_compress import ErrorFeedback, compressed_psum, dequantize, quantize
from .trainer import Trainer, TrainerError

__all__ = [
    "OptState", "adamw_update", "global_norm", "init_opt_state", "lr_schedule",
    "ErrorFeedback", "compressed_psum", "dequantize", "quantize",
    "Trainer", "TrainerError",
]
