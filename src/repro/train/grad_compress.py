"""Int8 gradient compression with error feedback — the cross-pod (DCN)
reduction trick.

On a 2-pod mesh the ``pod``-axis all-reduce crosses data-center network,
~10× slower per byte than ICI.  Quantizing the gradient to int8 (per-leaf
scale) cuts that wire traffic 4× vs f32 / 2× vs bf16.  The quantization
residual is carried in an error-feedback accumulator (Seide et al., 1-bit
SGD lineage), which restores convergence to near-exact.

Two entry points:
  * :func:`quantize` / :func:`dequantize` — the codec (+ tests).
  * :func:`compressed_psum` — shard_map-compatible reduction: quantize →
    psum int32 → dequantize (used over the ``pod`` axis; intra-pod axes
    reduce in full precision first — hierarchical schedule).
  * :class:`ErrorFeedback` — stateful wrapper for the trainer.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize", "dequantize", "compressed_psum", "ErrorFeedback"]


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q int8, scale f32)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """Quantized all-reduce for use inside shard_map/pmap bodies.

    Scales are made common via a max-psum so the int8 payloads add
    exactly; the int sum rides in int32 (no overflow for ≤ 2^23 ranks)."""
    g32 = g.astype(jnp.float32)
    local_amax = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30)
    amax = jax.lax.pmax(local_amax, axis_name)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n  # mean, like pmean


class ErrorFeedback:
    """g_eff = Q(g + e);  e ← (g + e) − g_eff  (per-leaf state)."""

    def __init__(self, params_like):
        self.e = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_like
        )

    def compress(self, grads):
        def one(g, e):
            v = g.astype(jnp.float32) + e
            q, s = quantize(v)
            deq = dequantize(q, s)
            return deq, v - deq

        out = jax.tree.map(one, grads, self.e)
        deq = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        self.e = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return deq
