"""Fault-tolerant trainer.

Production behaviours exercised here (and covered by tests):

* **checkpoint/restart** — periodic async checkpoints; on a step failure the
  trainer restores the latest checkpoint and replays.  Because the data
  pipeline is a pure function of the step index, a crashed-and-restarted run
  is *bitwise identical* to an uninterrupted one (golden test).
* **failure injection** — deterministic fault hook for tests/chaos drills.
* **straggler detection** — per-phase telemetry (GraphPM event traces!); a
  step slower than ``straggler_threshold ×`` running median flags a
  straggler event; the mining example discovers these as process variants.
* **telemetry mining** — every phase is recorded into an
  :class:`repro.core.telemetry.EventCollector`, so the framework's own
  execution process is an event log analyzable by the paper's technique.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import CheckpointManager
from repro.configs.base import ModelConfig, TrainHParams
from repro.core.telemetry import EventCollector
from repro.models import init_params, train_loss
from repro.train.optimizer import OptState, adamw_update, init_opt_state

__all__ = ["Trainer", "TrainerError"]


class TrainerError(RuntimeError):
    pass


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    hp: TrainHParams
    data: Callable[[int], Dict[str, np.ndarray]]
    ckpt_dir: str
    ckpt_every: int = 50
    max_retries: int = 3
    straggler_threshold: float = 3.0
    q_chunk: int = 1024
    seed: int = 0
    failure_injector: Optional[Callable[[int], None]] = None
    collector: EventCollector = dataclasses.field(
        default_factory=lambda: EventCollector("trainer")
    )

    def __post_init__(self):
        self.ckpt = CheckpointManager(self.ckpt_dir, keep=3, async_writes=True)
        self._step_times: List[float] = []
        self.history: List[float] = []

        def _step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: train_loss(self.cfg, p, batch, q_chunk=self.q_chunk)
            )(params)
            new_p, new_o, metrics = adamw_update(
                self.hp, params, grads, opt_state
            )
            return new_p, new_o, loss, metrics

        self._jit_step = jax.jit(_step, donate_argnums=(0, 1))

    # -- state ------------------------------------------------------------
    def init_state(self):
        params = init_params(self.cfg, jax.random.PRNGKey(self.seed))
        return params, init_opt_state(params)

    def restore_or_init(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            params, opt = self.init_state()
            return params, opt, 0
        template = jax.eval_shape(self.init_state)
        (params, opt), meta = self.ckpt.restore(
            latest, template=template
        )
        params = jax.tree.map(jax.numpy.asarray, params)
        opt = jax.tree.map(jax.numpy.asarray, opt)
        return params, opt, int(meta["next_step"])

    # -- main loop -----------------------------------------------------------
    def run(self, num_steps: int) -> Dict:
        params, opt, start = self.restore_or_init()
        step = start
        retries = 0
        while step < num_steps:
            case = f"step-{step}"
            t0 = time.perf_counter()
            try:
                if self.failure_injector is not None:
                    self.failure_injector(step)
                with self.collector.span(case, "load_batch"):
                    batch = self.data(step)
                with self.collector.span(case, "train_step"):
                    params, opt, loss, metrics = self._jit_step(
                        params, opt, batch
                    )
                    loss = float(loss)
                with self.collector.span(case, "log"):
                    self.history.append(loss)
                if (step + 1) % self.ckpt_every == 0:
                    with self.collector.span(case, "checkpoint"):
                        self.ckpt.save(
                            step + 1,
                            (params, opt),
                            metadata={"next_step": step + 1, "loss": loss},
                        )
                dt = time.perf_counter() - t0
                self._check_straggler(case, dt)
                step += 1
                retries = 0
            except TrainerError:
                raise
            except Exception as e:  # noqa: BLE001 — node failure path
                retries += 1
                self.collector.record(case, "failure", duration=0.0)
                if retries > self.max_retries:
                    raise TrainerError(
                        f"step {step} failed {retries} times"
                    ) from e
                self.ckpt.wait()
                params, opt, step = self.restore_or_init()
                self.collector.record(f"step-{step}", "restart", duration=0.0)
        self.ckpt.wait()
        return {
            "final_step": step,
            "history": list(self.history),
            "stragglers": self.collector.straggler_report(
                self.straggler_threshold
            ),
        }

    def _check_straggler(self, case: str, dt: float) -> None:
        self._step_times.append(dt)
        if len(self._step_times) >= 5:
            med = float(np.median(self._step_times))
            if med > 0 and dt > self.straggler_threshold * med:
                # mitigation hook: on a pod this triggers re-slicing /
                # hot-spare swap; here it is recorded for mining
                self.collector.record(case, "straggler_detected", duration=dt)
