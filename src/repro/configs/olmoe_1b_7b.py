"""OLMoE-1B-7B [arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924].

16L, d_model 2048, 16 heads (MHA, kv=16), head_dim 128, vocab 50304,
MoE every layer: 64 experts, top-8, d_ff 1024 per expert, QK-norm,
full attention, untied embeddings.

Pure full attention → long_500k is skipped (see DESIGN §4).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,  # no dense FFN — every layer is MoE
    vocab_size=50304,
    rope_base=10_000.0,
    layer_pattern=("global",),
    qk_norm=True,
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    n_experts=64,
    top_k=8,
    d_ff_expert=1024,
    moe_every=1,
    microbatches=2,  # §Perf tuned (with EP, 2 suffice to fit HBM)
    source="arXiv:2409.02060; hf",
)
