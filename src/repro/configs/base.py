"""Model + input-shape configuration.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes as :class:`ShapeConfig`.  Full configs are only ever
*lowered* (ShapeDtypeStruct) — smoke tests instantiate ``reduced()`` copies.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "TrainHParams"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention variants -------------------------------------------------
    rope_base: float = 10_000.0
    rope_base_local: Optional[float] = None  # gemma3 dual-base (local layers)
    window: Optional[int] = None  # sliding window for "local"/SWA layers
    # repeating unit of layer kinds; n_layers % len(pattern) == 0.
    # kinds: "global" (full causal attn), "local" (windowed attn), "mamba"
    layer_pattern: Tuple[str, ...] = ("global",)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None  # gemma2 query_pre_attn_scalar
    qk_norm: bool = False

    # --- mlp ------------------------------------------------------------------
    mlp_gated: bool = True
    act: str = "silu"  # silu | gelu

    # --- embeddings -------------------------------------------------------------
    tie_embeddings: bool = True
    scale_embed: bool = False  # gemma: embed * sqrt(d_model)

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1  # 1: every layer MoE; 2: every other (jamba)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba-2 SSD) ---------------------------------------------------
    ssm_d_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_n_groups: int = 1
    conv_width: int = 4

    # --- encoder–decoder (whisper) -------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0  # precomputed frame embeddings (stub frontend)

    # --- VLM stub (llava) ------------------------------------------------------
    n_patches: int = 0  # precomputed patch embeddings prepended to text

    # --- training memory -------------------------------------------------------
    # gradient-accumulation microbatches (0 = auto: MoE archs accumulate so
    # dispatch transients fit HBM; dense archs run the full batch)
    microbatches: int = 0

    # --- distribution tuning (§Perf) -------------------------------------------
    # "auto": constrain q/k/v to an explicit head-axis sharding (KV or G,
    # whichever pads least on the model axis) so attention scores stay local
    # — without it GSPMD shards the head_dim *contraction* and all-reduces
    # the scores tensor per q-chunk (observed 3.6 TB/device on llava prefill).
    # "none": leave attention layouts to GSPMD (the recorded baseline).
    attn_head_shard: str = "auto"
    # attention q-chunk (0 = auto: 512 beyond 8k context, else 1024);
    # bigger chunks amortize per-chunk collectives, cost more VMEM/HBM
    q_chunk: int = 0
    # shard the expert dim over `model` when divisible (EP) instead of
    # TP-inside-every-expert — cuts FSDP weight-gather traffic |E|-fold
    moe_expert_parallel: bool = True
    # cast matrix params to bf16 once at step entry (before the FSDP
    # all-gathers) — halves weight-gather wire bytes; master weights stay
    # f32 in the optimizer (standard mixed precision)
    cast_params_once: bool = True

    # --- norms / numerics ------------------------------------------------------
    rms_eps: float = 1e-6
    post_norms: bool = False  # gemma2/3 post-attn & post-ffn norms
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "full"  # none | full | dots
    loss_chunk: int = 512  # sequence chunking for the vocab projection

    # --- provenance -------------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_units(self) -> int:
        assert self.n_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern {len(self.layer_pattern)}"
        )
        return self.n_layers // len(self.layer_pattern)

    def is_moe_layer(self, idx_in_pattern: int) -> bool:
        if self.n_experts == 0:
            return False
        return (idx_in_pattern % self.moe_every) == (self.moe_every - 1)

    @property
    def has_attention(self) -> bool:
        return any(k in ("global", "local") for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer kind requires unbounded full attention context
        (window'd or SSM everywhere) OR the arch is attention-free/hybrid —
        used for the long_500k run/skip decision together with family."""
        kinds = set(self.layer_pattern)
        if "global" not in kinds:
            return True
        # hybrids / local-global mixes: bounded-per-step decode, allowed
        return self.family in ("ssm", "hybrid") or "local" in kinds or "mamba" in kinds

    def params_count(self) -> int:
        """Total parameters (exact, mirrors init_params)."""
        from repro.models.model import count_params  # lazy, avoids cycle

        return count_params(self)

    def active_params_count(self) -> int:
        from repro.models.model import count_params

        return count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """A CPU-smoke-test-sized config of the same family/shape class."""
        pat = self.layer_pattern
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=len(pat) * 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            d_ff_expert=32 if self.n_experts else 0,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_d_state=min(self.ssm_d_state, 16),
            ssm_head_dim=16 if self.ssm_d_state else self.ssm_head_dim,
            ssm_chunk=8,
            window=min(self.window, 8) if self.window else None,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_seq=16 if self.enc_seq else 0,
            n_patches=8 if self.n_patches else 0,
            loss_chunk=16,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1  # gradient-accumulation factor
