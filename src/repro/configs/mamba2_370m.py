"""Mamba2-370M [arXiv:2405.21060; assignment: unverified].

48L, d_model 1024, attention-free SSD (state-space duality), ssm_state 128,
expand 2 (d_inner 2048), head_dim 64 → 32 SSD heads, conv width 4,
vocab 50280, tied embeddings.  O(1)-state decode → long_500k runs.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,  # mamba block replaces attention+FFN
    vocab_size=50280,
    layer_pattern=("mamba",),
    tie_embeddings=True,
    ssm_d_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_n_groups=1,
    conv_width=4,
    source="arXiv:2405.21060; unverified",
)
