"""StarCoder2-3B [arXiv:2402.19173; hf:bigcode/starcoder2-3b].

30L, d_model 3072, 24 heads (GQA kv=2), d_ff 12288, vocab 49152, RoPE,
sliding-window attention 4096 (HF config), non-gated GELU MLP, tied embeds.
SWA bounds the decode cache → long_500k runs with a window cache.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    rope_base=999_999.4,  # hf rope_theta
    window=4096,
    layer_pattern=("local",),  # every layer sliding-window (hf config)
    mlp_gated=False,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2402.19173; hf",
)
