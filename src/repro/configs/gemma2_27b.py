"""Gemma-2 27B [arXiv:2408.00118; hf:google/gemma-2-27b].

46L, d_model 4608, 32 heads (GQA kv=16), head_dim 128, d_ff 36864,
vocab 256000, alternating local(4096):global attention, attention-logit
softcap 50, final-logit softcap 30, query_pre_attn_scalar 144
(= d_model / n_heads), pre+post norms, scaled tied embeddings.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    rope_base=10_000.0,
    window=4096,
    layer_pattern=("local", "global"),
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=144.0,
    mlp_gated=True,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    post_norms=True,
    source="arXiv:2408.00118; hf",
)
