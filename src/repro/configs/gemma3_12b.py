"""Gemma-3 12B [hf:google/gemma-3-12b-pt; assignment tier: unverified].

48L, d_model 3840, 16 heads (GQA kv=8), head_dim 256, d_ff 15360,
vocab 262144, 5:1 local:global interleave (local window 1024), dual RoPE
base (10k local / 1M global), QK-norm, gemma norm style (pre+post norms),
embeddings scaled by sqrt(d_model), tied.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_base=1_000_000.0,
    rope_base_local=10_000.0,
    window=1024,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    qk_norm=True,
    mlp_gated=True,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    post_norms=True,
    # §Perf tuned: 256-token loss chunks + 2 microbatches fit the 262k-vocab
    # training step into HBM (19.1 → 13.6 GiB/chip)
    loss_chunk=256,
    microbatches=2,
    source="hf:google/gemma-3-1b-pt (family config, 12b dims); unverified",
)
