"""Config registry: one module per assigned architecture + input shapes."""

from .base import SHAPES, ModelConfig, ShapeConfig, TrainHParams
from .graphpm import BENCH_FAST, PAPER_EVAL, GraphPMConfig

from . import (
    gemma2_9b,
    gemma2_27b,
    gemma3_12b,
    jamba_v01_52b,
    llava_next_34b,
    mamba2_370m,
    mixtral_8x7b,
    olmoe_1b_7b,
    starcoder2_3b,
    whisper_tiny,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        starcoder2_3b,
        gemma3_12b,
        gemma2_27b,
        gemma2_9b,
        llava_next_34b,
        olmoe_1b_7b,
        mixtral_8x7b,
        mamba2_370m,
        whisper_tiny,
        jamba_v01_52b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


# long_500k applicability (DESIGN §4): skip for pure full-attention archs.
LONG_CONTEXT_SKIPS = {"llava-next-34b", "olmoe-1b-7b", "whisper-tiny"}


def cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells per the assignment."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            if (
                not include_skips
                and shape == "long_500k"
                and arch in LONG_CONTEXT_SKIPS
            ):
                continue
            out.append((arch, shape))
    return out


__all__ = [
    "ARCHS", "SHAPES", "LONG_CONTEXT_SKIPS",
    "GraphPMConfig", "PAPER_EVAL", "BENCH_FAST",
    "ModelConfig", "ShapeConfig", "TrainHParams",
    "get_config", "get_shape", "cells",
]
