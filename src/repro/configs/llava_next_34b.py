"""LLaVA-NeXT 34B [hf:llava-hf/llava-v1.6-34b-hf; assignment: unverified].

Yi-34B-class language backbone: 60L, d_model 7168, 56 heads (GQA kv=8),
head_dim 128, d_ff 20480, vocab 64000, RoPE, full attention, untied.
Anyres vision frontend is a STUB per the assignment brief: ``input_specs``
supplies precomputed patch embeddings (n_patches × d_model) that the model
prepends to the text embeddings; labels are masked over patch positions.

Pure full attention → long_500k is skipped (see DESIGN §4).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_base=5_000_000.0,  # Yi rope_theta
    layer_pattern=("global",),
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    n_patches=576,  # one 24×24 CLIP grid (anyres base tile), stubbed
    microbatches=2,  # §Perf tuned: fits train_4k in HBM (33.7 → 11.9 GiB)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (family); unverified",
)
