"""The paper's own workload as a config (the GraphPM analytic job).

Mirrors the evaluation setup of the paper: BPI-2016-scale click log
(~7.2M events), ~4-month horizon, accumulating-day dices.  Consumed by
``launch/mine.py`` and the Fig.4/Fig.5 benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["GraphPMConfig", "PAPER_EVAL", "BENCH_FAST"]


@dataclasses.dataclass(frozen=True)
class GraphPMConfig:
    name: str
    num_events: int
    num_activities: int
    horizon_days: float
    mean_trace_len: float
    # execution
    backend: str = "auto"  # scatter | onehot | pallas | auto
    chunk_events: int = 1 << 20  # streaming-tier chunk
    dice_step_days: float = 1.0  # Experiment-2 accumulation step
    # distribution
    mesh_axes: Tuple[str, ...] = ("pod", "data", "model")
    hierarchical_reduce: bool = True  # intra-pod psum before the DCN hop


# the paper's evaluation scale (BPI-2016 clicks: ~7.2M events; the paper
# dices "for almost four months" in 1-day accumulating windows)
PAPER_EVAL = GraphPMConfig(
    name="bpi2016-scale",
    num_events=7_200_000,
    num_activities=600,  # click-log page granularity, coarsened
    horizon_days=120.0,
    mean_trace_len=12.0,
)

BENCH_FAST = dataclasses.replace(
    PAPER_EVAL, name="bench-fast", num_events=200_000, num_activities=64
)
