"""Whisper-tiny [arXiv:2212.04356; assignment: unverified].

Encoder–decoder: 4 encoder + 4 decoder layers, d_model 384, 6 heads
(kv=6, head_dim 64), d_ff 1536, vocab 51865.  The conv/mel frontend is a
STUB per the assignment brief: ``input_specs`` supplies precomputed frame
embeddings (1500 × 384); the encoder runs bidirectional attention over
them, the decoder decodes tokens with self- + cross-attention.

Enc-dec with a decoder → decode shapes run; long_500k skipped (full
attention, DESIGN §4).  Non-gated GELU MLP, learned abs positions
(decoder) / sinusoidal (encoder), tied decoder embedding.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,  # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    rope_base=0.0,  # whisper uses absolute positions, not RoPE
    layer_pattern=("global",),
    mlp_gated=False,
    act="gelu",
    tie_embeddings=True,
    n_enc_layers=4,
    enc_seq=1500,
    source="arXiv:2212.04356; unverified",
)
