"""Gemma-2 9B [arXiv:2408.00118; hf:google/gemma-2-9b].

42L, d_model 3584, 16 heads (GQA kv=8), head_dim 256, d_ff 14336,
vocab 256000, alternating local(4096):global, softcaps 50/30,
query_pre_attn_scalar 256 (= head_dim).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    rope_base=10_000.0,
    window=4096,
    layer_pattern=("local", "global"),
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=256.0,
    mlp_gated=True,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    post_norms=True,
    source="arXiv:2408.00118; hf",
)
