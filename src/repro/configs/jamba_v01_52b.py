"""Jamba v0.1 52B [arXiv:2403.19887; hf:ai21labs/Jamba-v0.1].

32L in 4 Jamba blocks of 8: attention at in-block index 4, Mamba elsewhere
(1:7 attn:mamba); MoE (16 experts, top-2, d_ff 14336) every other layer,
dense d_ff 14336 otherwise.  d_model 4096, 32 heads (GQA kv=8), vocab 65536.

TPU adaptation (DESIGN §4): Jamba's Mamba-1 layers are realized with the
Mamba-2 SSD formulation (d_state 16 preserved, scalar-A-per-head) — the
selective scan's TPU-native dual that runs on the MXU.

Hybrid (bounded state + 4 attention layers) → long_500k runs.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    rope_base=0.0,  # jamba uses no positional encoding on attention
    layer_pattern=(
        "mamba", "mamba", "mamba", "mamba",
        "global", "mamba", "mamba", "mamba",
    ),
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    moe_every=2,  # every other layer is MoE
    ssm_d_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_n_groups=1,
    conv_width=4,
    source="arXiv:2403.19887; hf",
)
