"""Mixtral 8x7B [arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1].

32L, d_model 4096, 32 heads (GQA kv=8), head_dim 128, vocab 32000,
MoE every layer: 8 experts top-2, d_ff 14336 per expert, sliding-window
attention 4096, untied embeddings.  SWA bounds decode caches →
long_500k runs.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,  # all layers MoE
    vocab_size=32000,
    rope_base=1_000_000.0,
    window=4096,
    layer_pattern=("local",),  # SWA on every layer
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    n_experts=8,
    top_k=2,
    d_ff_expert=14336,
    moe_every=1,
    # §Perf tuned: single q-chunk hoists attention collectives (frac
    # 0.059→0.081); microbatches=4 keeps MoE transients inside HBM
    q_chunk=4096,
    microbatches=4,
    source="arXiv:2401.04088; hf",
)
