"""Minimal XES / CSV event-log import-export.

XES is the IEEE standard the paper's tooling (ProM, pm4py) consumes; the
subset handled here is the one the paper's data model needs:
``concept:name`` on traces (case id) and events (activity), and
``time:timestamp``.  CSV is the pragmatic interchange format
(case, activity, timestamp columns).
"""

from __future__ import annotations

import csv
import xml.etree.ElementTree as ET
from typing import Iterable, Optional, TextIO, Tuple

from repro.core.repository import EventRepository

__all__ = ["write_csv", "read_csv", "write_xes", "read_xes"]


# ---------------------------------------------------------------------------
# CSV
# ---------------------------------------------------------------------------


def write_csv(repo: EventRepository, f: TextIO) -> None:
    w = csv.writer(f)
    w.writerow(["case", "activity", "timestamp"])
    for i in range(repo.num_events):
        w.writerow([
            repo.trace_names[int(repo.event_trace[i])],
            repo.activity_names[int(repo.event_activity[i])],
            repr(float(repo.event_time[i])),
        ])


def read_csv(f: TextIO) -> EventRepository:
    r = csv.reader(f)
    header = next(r)
    idx = {name: i for i, name in enumerate(header)}
    cases, acts, times = [], [], []
    for row in r:
        if not row:
            continue
        cases.append(row[idx["case"]])
        acts.append(row[idx["activity"]])
        times.append(float(row[idx["timestamp"]]))
    return EventRepository.from_event_table(cases, acts, times)


# ---------------------------------------------------------------------------
# XES
# ---------------------------------------------------------------------------


def write_xes(repo: EventRepository, f: TextIO) -> None:
    root = ET.Element("log", {"xes.version": "1.0"})
    for t in range(repo.num_traces):
        tr = ET.SubElement(root, "trace")
        ET.SubElement(
            tr, "string",
            {"key": "concept:name", "value": repo.trace_names[t]},
        )
        for i in range(repo.num_events):
            if int(repo.event_trace[i]) != t:
                continue
            ev = ET.SubElement(tr, "event")
            ET.SubElement(ev, "string", {
                "key": "concept:name",
                "value": repo.activity_names[int(repo.event_activity[i])],
            })
            ET.SubElement(ev, "float", {
                "key": "time:timestamp",
                "value": repr(float(repo.event_time[i])),
            })
    f.write(ET.tostring(root, encoding="unicode"))


def read_xes(f: TextIO) -> EventRepository:
    root = ET.parse(f).getroot()
    cases, acts, times = [], [], []
    seq = 0.0
    for tr in root.iter("trace"):
        case = "<unnamed>"
        for attr in tr:
            if attr.tag == "string" and attr.get("key") == "concept:name":
                case = attr.get("value")
        for ev in tr.iter("event"):
            act: Optional[str] = None
            ts: Optional[float] = None
            for attr in ev:
                if attr.get("key") == "concept:name":
                    act = attr.get("value")
                if attr.get("key") == "time:timestamp":
                    ts = float(attr.get("value"))
            if act is None:
                continue
            seq += 1.0
            cases.append(case)
            acts.append(act)
            times.append(ts if ts is not None else seq)
    return EventRepository.from_event_table(cases, acts, times)
