"""Deterministic, resumable LM token pipeline.

``batch(step)`` is a pure function of ``(seed, step)`` — restart-safe by
construction: a trainer resuming from step k sees exactly the batches an
uninterrupted run would have seen (the fault-tolerance golden test relies
on this).

Modes:
  * ``uniform`` — i.i.d. tokens (shape/throughput testing)
  * ``markov``  — a seeded bigram language; learnable structure so example
    training runs show loss ↓ below ln(V)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    mode: str = "markov"  # uniform | markov
    branching: int = 16  # markov out-degree (lower = easier to learn)

    def __post_init__(self):
        if self.mode == "markov":
            rng = np.random.default_rng(self.seed)
            V = self.vocab_size
            self._succ = rng.integers(
                0, V, size=(V, self.branching), dtype=np.int32
            )
            logits = rng.normal(size=(V, self.branching)) * 1.5
            p = np.exp(logits)
            self._p = (p / p.sum(axis=1, keepdims=True)).cumsum(axis=1)

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(step)])
        )
        B, S, V = self.batch, self.seq_len, self.vocab_size
        if self.mode == "uniform":
            toks = rng.integers(0, V, size=(B, S + 1), dtype=np.int32)
        else:
            toks = np.zeros((B, S + 1), dtype=np.int32)
            toks[:, 0] = rng.integers(0, V, size=B)
            u = rng.random(size=(B, S))
            for t in range(S):
                prev = toks[:, t]
                choice = (u[:, t, None] > self._p[prev]).sum(axis=1)
                toks[:, t + 1] = self._succ[
                    prev, np.minimum(choice, self.branching - 1)
                ]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def bigram_entropy(self) -> float:
        """Achievable NLL floor for the markov language (nats/token)."""
        if self.mode != "markov":
            return float(np.log(self.vocab_size))
        p = np.diff(np.concatenate(
            [np.zeros((self.vocab_size, 1)), self._p], axis=1
        ), axis=1)
        ent = -(p * np.log(np.maximum(p, 1e-12))).sum(axis=1)
        return float(ent.mean())
