from .synthetic_log import ProcessSpec, generate_memmap_log, generate_repository

__all__ = ["ProcessSpec", "generate_memmap_log", "generate_repository"]
