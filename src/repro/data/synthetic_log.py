"""Synthetic BPI-like event log generator (BPI-2016 substitute, see DESIGN §7).

Simulates a business process as a Markov chain over activities with
designated entry/exit distributions, heavy-tailed trace lengths, and Poisson
case arrivals over a configurable horizon (~4 months by default, matching
the paper's Experiment 2 dicing range).  Deterministic per seed.

Two emission paths:
  * :func:`generate_repository` — in-memory `EventRepository` (small/medium)
  * :func:`generate_memmap_log` — streams straight to the disk tier without
    ever materializing the log (used to build ≫-RAM logs for Claim C1).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.repository import EventRepository
from repro.core.streaming import MemmapLog

__all__ = ["ProcessSpec", "generate_repository", "generate_memmap_log"]

DAY = 86400.0


@dataclasses.dataclass
class ProcessSpec:
    """A random-but-structured process model."""

    num_activities: int = 26
    mean_trace_len: float = 12.0  # geometric-ish tail
    max_trace_len: int = 200
    branching: int = 4  # out-degree of the underlying process graph
    horizon_days: float = 120.0
    seed: int = 0

    def build(self) -> "_ProcessModel":
        rng = np.random.default_rng(self.seed)
        A = self.num_activities
        br = min(self.branching, A)  # out-degree can't exceed |A|
        # sparse transition structure: each activity can go to `br`
        # successors (weights Dirichlet), giving a non-trivial DFG shape
        succ = np.zeros((A, br), dtype=np.int64)
        w = np.zeros((A, br))
        for a in range(A):
            succ[a] = rng.choice(A, size=br, replace=False)
            w[a] = rng.dirichlet(np.ones(br))
        entry_acts = rng.choice(A, size=min(5, A), replace=False)
        entry = rng.dirichlet(np.ones(entry_acts.shape[0]))
        p_stop = 1.0 / self.mean_trace_len
        return _ProcessModel(self, succ, w, entry_acts, entry, p_stop)


@dataclasses.dataclass
class _ProcessModel:
    spec: ProcessSpec
    succ: np.ndarray
    w: np.ndarray
    entry_acts: np.ndarray
    entry_w: np.ndarray
    p_stop: float

    def sample_lens(self, num_traces: int, rng: np.random.Generator) -> np.ndarray:
        return np.minimum(
            rng.geometric(self.p_stop, size=num_traces) + 1,
            self.spec.max_trace_len,
        )

    def sample_traces(
        self,
        lens: np.ndarray,
        rng: np.random.Generator,
        horizon_s: Optional[float] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized trace sampling for given per-trace lengths.

        Returns flat (case, activity, time) arrays sorted by time
        (a time-ordered stream with interleaved cases)."""
        spec = self.spec
        num_traces = lens.shape[0]
        horizon = horizon_s if horizon_s is not None else spec.horizon_days * DAY
        total = int(lens.sum())
        case = np.repeat(np.arange(num_traces, dtype=np.int64), lens).astype(np.int32)
        arrivals = rng.uniform(0, horizon * 0.8, size=num_traces)
        offsets = np.concatenate([[0], np.cumsum(lens)[:-1]])
        pos_in_case = np.arange(total, dtype=np.int64) - np.repeat(offsets, lens)
        gaps = rng.exponential(600.0, size=total)  # ~10 min between steps
        cum = np.cumsum(gaps)
        base = np.repeat(
            np.concatenate([[0.0], cum[np.cumsum(lens)[:-1] - 1]]), lens
        )
        within = cum - base
        time = np.repeat(arrivals, lens) + within * (pos_in_case > 0)
        # keep every event inside the horizon (monotone clamp; ties are
        # resolved by stable sorts downstream, preserving case order)
        time = np.minimum(time, horizon - 1e-3)

        act = np.zeros(total, dtype=np.int32)
        starts = offsets
        act[starts] = rng.choice(
            self.entry_acts, size=num_traces, p=self.entry_w
        ).astype(np.int32)
        max_len = int(lens.max()) if total else 0
        for step in range(1, max_len):
            mask = lens > step
            idx = starts[mask] + step
            prev = act[idx - 1]
            u = rng.random(idx.shape[0])
            cdf = np.cumsum(self.w[prev], axis=1)
            choice = (u[:, None] > cdf).sum(axis=1)
            act[idx] = self.succ[prev, np.minimum(choice, self.succ.shape[1] - 1)]
        order = np.argsort(time, kind="stable")
        return case[order], act[order], time[order]


def generate_repository(
    num_traces: int,
    spec: Optional[ProcessSpec] = None,
    seed: int = 0,
) -> EventRepository:
    spec = spec or ProcessSpec(seed=seed)
    model = spec.build()
    rng = np.random.default_rng(seed + 1)
    lens = model.sample_lens(num_traces, rng)
    case, act, time = model.sample_traces(lens, rng)
    vocab = [f"act_{i:03d}" for i in range(spec.num_activities)]
    width = len(str(max(num_traces, 1)))
    return EventRepository.from_event_table(
        [f"case_{c:0{width}d}" for c in case],
        [vocab[a] for a in act],
        time,
        activity_vocab=vocab,
    )


def generate_memmap_log(
    path: str,
    num_events_target: int,
    spec: Optional[ProcessSpec] = None,
    seed: int = 0,
    batch_traces: int = 50_000,
) -> MemmapLog:
    """Stream a large log straight to disk; O(batch) memory.

    Batch ``k`` owns the disjoint time slab ``[k·slab, (k+1)·slab)`` so the
    resulting stream is globally time-ordered without a global sort."""
    spec = spec or ProcessSpec(seed=seed)
    model = spec.build()

    # Pass 1: per-batch trace counts/lengths (deterministic, O(batch) each).
    batch_lens = []
    remaining = num_events_target
    bi = 0
    while remaining > 0:
        sub = np.random.default_rng((seed + 1) * 1_000_003 + 2 * bi)
        lens = model.sample_lens(batch_traces, sub)
        csum = np.cumsum(lens)
        if csum[-1] > remaining:
            k = int(np.searchsorted(csum, remaining)) + 1
            lens = lens[:k]
        batch_lens.append(lens)
        remaining -= int(lens.sum())
        bi += 1

    total_events = int(sum(int(l.sum()) for l in batch_lens))
    total_traces = int(sum(l.shape[0] for l in batch_lens))
    writer = MemmapLog.create(path, total_events, spec.num_activities, total_traces)
    slab = spec.horizon_days * DAY / len(batch_lens)
    case_base = 0
    for bi, lens in enumerate(batch_lens):
        sub = np.random.default_rng((seed + 1) * 1_000_003 + 2 * bi + 1)
        case, act, time = model.sample_traces(lens, sub, horizon_s=slab)
        writer.append(act, case + case_base, time + bi * slab)
        case_base += lens.shape[0]
    return writer.close()
