"""Runtime telemetry as event logs — the framework mines itself.

Every training/serving step emits process events (``load_batch → forward →
backward → grad_sync → optimizer → [checkpoint]``) into an in-memory
collector that converts to a standard :class:`EventRepository`.  Graph-based
process mining over these traces is the framework's fault/straggler
forensics: a healthy run's DFG is a clean chain; retries, restarts, and
stragglers appear as deviating variants and timing outliers.
"""

from __future__ import annotations

import contextlib
import threading
import time as _time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .repository import EventRepository

__all__ = ["EventCollector", "StepTimer"]


class EventCollector:
    """Thread-safe append-only event collector.

    ``case`` is typically ``step-<n>`` (each training step is one trace),
    ``activity`` a phase name.  ``record`` is O(1); conversion to a
    repository is deferred."""

    def __init__(self, log_name: str = "runtime"):
        self.log_name = log_name
        self._lock = threading.Lock()
        self._cases: List[str] = []
        self._activities: List[str] = []
        self._times: List[float] = []
        self._durations: List[float] = []

    def record(
        self,
        case: str,
        activity: str,
        timestamp: Optional[float] = None,
        duration: float = 0.0,
    ) -> None:
        with self._lock:
            self._cases.append(case)
            self._activities.append(activity)
            self._times.append(
                timestamp if timestamp is not None else _time.perf_counter()
            )
            self._durations.append(duration)

    @contextlib.contextmanager
    def span(self, case: str, activity: str):
        t0 = _time.perf_counter()
        try:
            yield
        finally:
            self.record(case, activity, timestamp=t0,
                        duration=_time.perf_counter() - t0)

    def __len__(self) -> int:
        return len(self._cases)

    def to_repository(self) -> EventRepository:
        with self._lock:
            return EventRepository.from_event_table(
                list(self._cases),
                list(self._activities),
                list(self._times),
            )

    def durations_by_activity(self) -> Dict[str, np.ndarray]:
        out: Dict[str, List[float]] = {}
        with self._lock:
            for a, d in zip(self._activities, self._durations):
                out.setdefault(a, []).append(d)
        return {k: np.asarray(v) for k, v in out.items()}

    def straggler_report(self, threshold: float = 3.0) -> Dict[str, Dict]:
        """Flag activities whose max duration exceeds ``threshold`` × median —
        the straggler-mitigation signal consumed by the trainer."""
        rep = {}
        for act, ds in self.durations_by_activity().items():
            if ds.size < 3:
                continue
            med = float(np.median(ds))
            mx = float(ds.max())
            if med > 0 and mx > threshold * med:
                rep[act] = {
                    "median_s": med,
                    "max_s": mx,
                    "ratio": mx / med,
                    "count": int(ds.size),
                }
        return rep


class StepTimer:
    """Duration tracker keyed by phase, independent of the collector."""

    def __init__(self):
        self.totals: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = _time.perf_counter()
        try:
            yield
        finally:
            dt = _time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def summary(self) -> Dict[str, Tuple[float, int]]:
        return {k: (self.totals[k], self.counts[k]) for k in self.totals}
