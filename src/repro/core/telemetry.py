"""Runtime telemetry as event logs — the framework mines itself.

Every training/serving step emits process events (``load_batch → forward →
backward → grad_sync → optimizer → [checkpoint]``) into an in-memory
collector that converts to a standard :class:`EventRepository`.  Graph-based
process mining over these traces is the framework's fault/straggler
forensics: a healthy run's DFG is a clean chain; retries, restarts, and
stragglers appear as deviating variants and timing outliers.
"""

from __future__ import annotations

import contextlib
import threading
import time as _time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .repository import EventRepository
from ..analysis.lockdep import make_lock

__all__ = ["EventCollector", "StepTimer"]


class EventCollector:
    """Thread-safe append-only event collector.

    ``case`` is typically ``step-<n>`` (each training step is one trace),
    ``activity`` a phase name.  ``record`` is O(1); conversion to a
    repository is deferred.

    ``max_events`` turns the collector into a ring buffer: the oldest
    events are evicted once the bound is reached and counted in
    :attr:`dropped` (surfaced as a gauge in the engine's metrics
    registry).  Default is unbounded — long-lived serving processes
    should bound it (``ServeEngine`` and ``QueryEngine`` do)."""

    def __init__(self, log_name: str = "runtime",
                 max_events: Optional[int] = None):
        self.log_name = log_name
        self.max_events = max_events
        self._lock = make_lock("EventCollector")
        self._cases: deque = deque(maxlen=max_events)
        self._activities: deque = deque(maxlen=max_events)
        self._times: deque = deque(maxlen=max_events)
        self._durations: deque = deque(maxlen=max_events)
        self._recorded = 0

    def record(
        self,
        case: str,
        activity: str,
        timestamp: Optional[float] = None,
        duration: float = 0.0,
    ) -> None:
        with self._lock:
            self._cases.append(case)
            self._activities.append(activity)
            self._times.append(
                timestamp if timestamp is not None else _time.perf_counter()
            )
            self._durations.append(duration)
            self._recorded += 1

    def record_many(
        self,
        cases: Union[str, Sequence[str]],
        activities: Sequence[str],
        timestamps: Sequence[float],
        durations: Optional[Sequence[float]] = None,
    ) -> None:
        """Batch append taking the lock once — the engine's forensics
        hook records a whole query trace per call.  ``cases`` may be a
        single case id broadcast over every event."""
        n = len(activities)
        if isinstance(cases, str):
            cases = [cases] * n
        if durations is None:
            durations = [0.0] * n
        with self._lock:
            self._cases.extend(cases)
            self._activities.extend(activities)
            self._times.extend(timestamps)
            self._durations.extend(durations)
            self._recorded += n

    @property
    def dropped(self) -> int:
        """Events evicted by the ring buffer since construction."""
        with self._lock:
            return self._recorded - len(self._cases)

    @contextlib.contextmanager
    def span(self, case: str, activity: str):
        t0 = _time.perf_counter()
        try:
            yield
        finally:
            self.record(case, activity, timestamp=t0,
                        duration=_time.perf_counter() - t0)

    def __len__(self) -> int:
        return len(self._cases)

    def to_repository(self) -> EventRepository:
        with self._lock:
            return EventRepository.from_event_table(
                list(self._cases),
                list(self._activities),
                list(self._times),
            )

    def durations_by_activity(self) -> Dict[str, np.ndarray]:
        out: Dict[str, List[float]] = {}
        with self._lock:
            for a, d in zip(self._activities, self._durations):
                out.setdefault(a, []).append(d)
        return {k: np.asarray(v) for k, v in out.items()}

    def straggler_report(self, threshold: float = 3.0) -> Dict[str, Dict]:
        """Flag activities whose max duration exceeds ``threshold`` × median —
        the straggler-mitigation signal consumed by the trainer."""
        rep = {}
        for act, ds in self.durations_by_activity().items():
            if ds.size < 3:
                continue
            med = float(np.median(ds))
            mx = float(ds.max())
            if med > 0 and mx > threshold * med:
                rep[act] = {
                    "median_s": med,
                    "max_s": mx,
                    "ratio": mx / med,
                    "count": int(ds.size),
                }
        return rep


class StepTimer:
    """Duration tracker keyed by phase, independent of the collector."""

    def __init__(self):
        self.totals: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = _time.perf_counter()
        try:
            yield
        finally:
            dt = _time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def summary(self) -> Dict[str, Tuple[float, int]]:
        return {k: (self.totals[k], self.counts[k]) for k in self.totals}
