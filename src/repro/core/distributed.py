"""Distributed DFG — the paper's horizontal scaling, on a TPU mesh.

Neo4j scales DFG computation by adding database nodes; here the "database"
is the pod: event-pair columns live sharded across every device's HBM, each
device counts its resident shard (MXU one-hot matmul or the Pallas kernel),
and a single ``psum`` of the (A, A) matrix produces the global DFG.

Privacy property preserved *by construction*: the only cross-device /
device-to-host traffic is the aggregated count matrix — raw events never
move (the paper's "remove the requirement to move data into analysts'
computer").

Works on any mesh rank — ``("data",)``, ``("data", "model")``, or the
production ``("pod", "data", "model")`` — events are sharded over *all*
axes flattened, because DFG counting is embarrassingly data-parallel.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map

__all__ = [
    "distributed_dfg",
    "shard_pairs",
    "local_dfg_fn",
    "merge_shard_psis",
    "merge_shard_counts",
]


def _n_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def local_dfg_fn(num_activities: int, backend: str = "onehot", chunk: int = 4096):
    """Per-shard DFG counting function (runs inside shard_map)."""

    def fn(src, dst, valid):
        if backend == "pallas":
            from repro.kernels.dfg_count import ops as _ops

            return _ops.dfg_count(
                src, dst, valid, num_activities=num_activities
            ).astype(jnp.float32)
        # one-hot MXU formulation, chunked (see core.dfg.dfg_onehot)
        n = src.shape[0]
        c = min(chunk, n)
        pad = (-n) % c
        if pad:
            src = jnp.pad(src, (0, pad))
            dst = jnp.pad(dst, (0, pad))
            valid = jnp.pad(valid, (0, pad))
        k = (n + pad) // c
        srcs = src.reshape(k, c)
        dsts = dst.reshape(k, c)
        valids = valid.reshape(k, c)

        def body(acc, xs):
            s, d, v = xs
            oh_s = jax.nn.one_hot(s, num_activities, dtype=jnp.float32)
            oh_s = oh_s * v.astype(jnp.float32)[:, None]
            oh_d = jax.nn.one_hot(d, num_activities, dtype=jnp.float32)
            return acc + jnp.dot(oh_s.T, oh_d, preferred_element_type=jnp.float32), None

        init = jnp.zeros((num_activities, num_activities), jnp.float32)
        acc, _ = jax.lax.scan(body, init, (srcs, dsts, valids))
        return acc

    return fn


def shard_pairs(
    src: np.ndarray,
    dst: np.ndarray,
    valid: np.ndarray,
    n_shards: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad pair columns to a multiple of ``n_shards`` (padding marked
    invalid) so they shard evenly across devices."""
    n = src.shape[0]
    padded = max(n_shards, math.ceil(n / n_shards) * n_shards)
    pad = padded - n
    return (
        np.pad(src, (0, pad)).astype(np.int32),
        np.pad(dst, (0, pad)).astype(np.int32),
        np.pad(valid, (0, pad)).astype(bool),
    )


def distributed_dfg(
    mesh: Mesh,
    src: np.ndarray,
    dst: np.ndarray,
    valid: np.ndarray,
    num_activities: int,
    *,
    backend: str = "onehot",
    hierarchical: bool = True,
) -> np.ndarray:
    """Compute the global DFG with events sharded over every mesh axis.

    ``hierarchical=True`` reduces over the fastest (intra-pod) axes first and
    the ``pod`` axis last — on real hardware the last hop crosses DCN, so the
    matrix is reduced intra-pod before it ever touches the slow link (the
    multi-pod collective-schedule optimization).
    """
    axes = tuple(mesh.axis_names)
    all_axes_spec = P(axes)  # events sharded over the flattened device axis
    n_dev = _n_devices(mesh)
    src_s, dst_s, valid_s = shard_pairs(src, dst, valid, n_dev)

    local = local_dfg_fn(num_activities, backend=backend)

    def shard_fn(s, d, v):
        psi_local = local(s, d, v)
        if hierarchical:
            # intra-pod first (data, model, ...), cross-pod ("pod") last
            for ax in reversed(axes):
                psi_local = jax.lax.psum(psi_local, axis_name=ax)
        else:
            psi_local = jax.lax.psum(psi_local, axis_name=axes)
        return psi_local

    mapped = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(all_axes_spec, all_axes_spec, all_axes_spec),
        out_specs=P(),  # fully replicated aggregate — the only thing leaving
    )
    sharding = NamedSharding(mesh, all_axes_spec)
    args = [
        jax.device_put(x, sharding) for x in (src_s, dst_s, valid_s)
    ]
    psi = jax.jit(mapped)(*args)
    return np.asarray(psi, dtype=np.int64)


# ---------------------------------------------------------------------------
# Sharded-graph merge (case-partitioned shards → global sinks)
# ---------------------------------------------------------------------------


def _align_dense(mat: np.ndarray, ids, num_activities: int) -> np.ndarray:
    """Embed a shard-local (a, a) matrix into the (A, A) union frame.
    ``ids[i]`` is the union id of shard-local activity ``i`` (unique), so
    plain assignment places every cell — no accumulation inside one shard."""
    out = np.zeros((num_activities, num_activities), dtype=np.int64)
    idx = np.asarray(ids, dtype=np.int64)
    out[np.ix_(idx, idx)] = mat
    return out


def merge_shard_psis(
    psis,
    id_maps,
    num_activities: int,
    *,
    mesh: Optional[Mesh] = None,
) -> np.ndarray:
    """Merge per-shard (a_k, a_k) Ψ matrices into the global (A, A) Ψ.

    Cases never span shards under the ``case % K`` partition, so every
    directly-follows pair is counted by exactly one shard and the merge is a
    *pure sum* on the aligned union vocabulary — no reconciliation, the same
    psum contract as :func:`distributed_dfg`.  With a ``mesh`` the aligned
    stack is sharded over the flattened device axis and reduced with an
    on-device ``psum`` (int32 lanes — exact, unlike a float accumulate);
    host-side the sum is a K·A² numpy reduction.
    """
    aligned = [
        _align_dense(psi, ids, num_activities)
        for psi, ids in zip(psis, id_maps)
    ]
    if not aligned:
        return np.zeros((num_activities, num_activities), dtype=np.int64)
    if mesh is None or _n_devices(mesh) <= 1:
        return np.sum(aligned, axis=0, dtype=np.int64)

    axes = tuple(mesh.axis_names)
    n_dev = _n_devices(mesh)
    stack = np.stack(aligned).astype(np.int32)
    pad = (-stack.shape[0]) % n_dev
    if pad:
        stack = np.concatenate(
            [stack, np.zeros((pad, *stack.shape[1:]), dtype=np.int32)]
        )

    def shard_fn(x):
        acc = jnp.sum(x, axis=0)
        for ax in reversed(axes):
            acc = jax.lax.psum(acc, axis_name=ax)
        return acc

    mapped = shard_map(
        shard_fn, mesh=mesh, in_specs=(P(axes),), out_specs=P(),
    )
    arg = jax.device_put(stack, NamedSharding(mesh, P(axes)))
    return np.asarray(jax.jit(mapped)(arg), dtype=np.int64)


def merge_shard_counts(counts, id_maps, num_activities: int) -> np.ndarray:
    """Merge per-shard activity-count vectors (histogram / process-map node
    weights) onto the union vocabulary.  Each event lives on exactly one
    shard, so this too is a pure aligned sum."""
    out = np.zeros(num_activities, dtype=np.int64)
    for vec, ids in zip(counts, id_maps):
        idx = np.asarray(ids, dtype=np.int64)
        out[idx] += np.asarray(vec, dtype=np.int64)
    return out


def lower_distributed_dfg(
    mesh: Mesh,
    num_pairs: int,
    num_activities: int,
    *,
    backend: str = "onehot",
):
    """Lower (no execution) the distributed DFG for dry-run/roofline use."""
    axes = tuple(mesh.axis_names)
    spec = P(axes)
    sharding = NamedSharding(mesh, spec)
    local = local_dfg_fn(num_activities, backend=backend)

    def shard_fn(s, d, v):
        psi_local = local(s, d, v)
        for ax in reversed(axes):
            psi_local = jax.lax.psum(psi_local, axis_name=ax)
        return psi_local

    mapped = shard_map(
        shard_fn, mesh=mesh, in_specs=(spec, spec, spec), out_specs=P(),
    )
    n_dev = _n_devices(mesh)
    padded = max(n_dev, math.ceil(num_pairs / n_dev) * n_dev)
    mk = lambda dt: jax.ShapeDtypeStruct((padded,), dt, sharding=sharding)
    return jax.jit(mapped).lower(
        mk(jnp.int32), mk(jnp.int32), mk(jnp.bool_)
    )
