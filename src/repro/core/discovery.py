"""Process discovery on top of the DFG (paper Fig. 1, step 3).

The paper deliberately separates DFG computation (heavy, in-store) from model
inference (light, on the analyst side) — "this step usually does not take
much time since the computation is performed on top of DFG".  We implement
the standard DFG-based discovery stack so the framework is end-to-end:

* frequency filtering (spaghetti-model control, §5.2),
* heuristics-miner dependency measures and dependency-graph discovery,
* alpha-miner footprint relations (→, ←, ∥, #) + footprint conformance,
* DOT export for visualization.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = [
    "filter_dfg",
    "dependency_matrix",
    "DiscoveredModel",
    "discover_dependency_graph",
    "footprint",
    "footprint_conformance",
    "to_dot",
]

START = "<start>"
END = "<end>"


def filter_dfg(psi: np.ndarray, min_count: int = 1) -> np.ndarray:
    """Drop edges below a frequency threshold (keeps the 'big picture')."""
    out = psi.copy()
    out[out < min_count] = 0
    return out


def dependency_matrix(psi: np.ndarray) -> np.ndarray:
    """Heuristics-miner dependency measure
    ``dep(a,b) = (|a>b| - |b>a|) / (|a>b| + |b>a| + 1)`` (a≠b) and
    ``dep(a,a) = |a>a| / (|a>a| + 1)`` for self-loops."""
    f = psi.astype(np.float64)
    ft = f.T
    dep = (f - ft) / (f + ft + 1.0)
    self_loops = np.diag(f) / (np.diag(f) + 1.0)
    np.fill_diagonal(dep, self_loops)
    return dep


@dataclasses.dataclass
class DiscoveredModel:
    activities: List[str]
    edges: List[Tuple[str, str, int, float]]  # (src, dst, count, dependency)
    start_activities: Dict[str, int]
    end_activities: Dict[str, int]

    @property
    def edge_set(self) -> set:
        return {(s, d) for (s, d, _, _) in self.edges}


def discover_dependency_graph(
    psi: np.ndarray,
    activity_names: Sequence[str],
    start_counts: np.ndarray,
    end_counts: np.ndarray,
    *,
    min_count: int = 1,
    min_dependency: float = 0.5,
) -> DiscoveredModel:
    """Heuristics-style dependency-graph discovery from the DFG."""
    dep = dependency_matrix(psi)
    edges: List[Tuple[str, str, int, float]] = []
    a_n = list(activity_names)
    for i in range(psi.shape[0]):
        for j in range(psi.shape[1]):
            c = int(psi[i, j])
            if c >= min_count and dep[i, j] >= min_dependency:
                edges.append((a_n[i], a_n[j], c, float(dep[i, j])))
    starts = {a_n[i]: int(c) for i, c in enumerate(start_counts) if c > 0}
    ends = {a_n[i]: int(c) for i, c in enumerate(end_counts) if c > 0}
    return DiscoveredModel(
        activities=a_n, edges=edges, start_activities=starts, end_activities=ends
    )


def footprint(psi: np.ndarray) -> np.ndarray:
    """Alpha-miner footprint: 0 = # (never), 1 = a→b, 2 = a←b, 3 = ∥."""
    fwd = psi > 0
    bwd = psi.T > 0
    out = np.zeros(psi.shape, dtype=np.int8)
    out[fwd & ~bwd] = 1
    out[~fwd & bwd] = 2
    out[fwd & bwd] = 3
    return out


def footprint_conformance(f1: np.ndarray, f2: np.ndarray) -> float:
    """Fraction of matching footprint cells (1.0 = behaviourally identical
    at the directly-follows abstraction)."""
    if f1.shape != f2.shape:
        raise ValueError("footprints must have equal shape")
    if f1.size == 0:
        return 1.0
    return float((f1 == f2).mean())


def to_dot(model: DiscoveredModel) -> str:
    lines = ["digraph dfg {", "  rankdir=LR;", '  node [shape=box];']
    lines.append(f'  "{START}" [shape=circle,label="▶"];')
    lines.append(f'  "{END}" [shape=doublecircle,label="■"];')
    for a, c in model.start_activities.items():
        lines.append(f'  "{START}" -> "{a}" [label="{c}"];')
    for s, d, c, dep in model.edges:
        lines.append(f'  "{s}" -> "{d}" [label="{c} ({dep:.2f})"];')
    for a, c in model.end_activities.items():
        lines.append(f'  "{a}" -> "{END}" [label="{c}"];')
    lines.append("}")
    return "\n".join(lines)
