"""Trace-variant analysis — the paper's §5.2 motivation made first-class.

"Event logs usually contain different variations … discovering the process
based on the whole event log usually produces so-called spaghetti models"
— the standard remedy is variant analysis: group traces by their activity
sequence, mine the top-k variants.  Vectorized via per-trace sequence
hashing (no Python loop over events), so it runs on million-event logs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .repository import EventRepository

__all__ = [
    "TraceVariants",
    "trace_variants",
    "variant_table",
    "variant_filtered_repository",
]

_P1 = np.uint64(1_000_000_007)
_P2 = np.uint64(0x9E3779B97F4A7C15)


@dataclasses.dataclass
class TraceVariants:
    """Variants sorted by descending frequency."""

    counts: np.ndarray  # (V,) traces per variant
    sequences: List[List[str]]  # activity-name sequence per variant
    trace_variant: np.ndarray  # (T,) variant index per trace

    @property
    def num_variants(self) -> int:
        return int(self.counts.shape[0])

    def coverage(self, k: int) -> float:
        """Fraction of traces covered by the top-k variants."""
        total = self.counts.sum()
        return float(self.counts[:k].sum() / total) if total else 1.0


def variant_table(
    event_activity: np.ndarray,
    event_trace: np.ndarray,
    num_traces: int,
    activity_names: List[str],
) -> TraceVariants:
    """Variant analysis straight off canonical (trace-contiguous) columns —
    the array-level core :func:`trace_variants` wraps, usable by callers
    (graph tables, transformed selections) that have no repository."""
    t = np.asarray(event_trace).astype(np.int64)
    act = np.asarray(event_activity)
    a = act.astype(np.uint64)
    T = int(num_traces)
    n = t.shape[0]
    if n == 0:
        return TraceVariants(
            counts=np.zeros((0,), np.int64), sequences=[],
            trace_variant=np.zeros((T,), np.int64),
        )
    # polynomial rolling hash per trace (canonical order is trace-contiguous)
    pos = np.arange(n, dtype=np.int64)
    starts = np.zeros(n, dtype=bool)
    starts[0] = True
    starts[1:] = t[1:] != t[:-1]
    start_pos = np.maximum.accumulate(np.where(starts, pos, 0))
    offset = (pos - start_pos).astype(np.uint64)
    term = (a + np.uint64(1)) * ((offset + np.uint64(1)) * _P2 + np.uint64(1))
    h = np.zeros(T, dtype=np.uint64)
    np.add.at(h, t, term * _P1 + (term >> np.uint64(7)))
    lens = np.bincount(t, minlength=T).astype(np.uint64)
    h = h ^ (lens * _P2)

    uniq, first_idx, inv, counts = np.unique(
        h, return_index=True, return_inverse=True, return_counts=True
    )
    order = np.argsort(-counts, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(order.shape[0])
    trace_variant = rank[inv]

    # reconstruct one representative sequence per variant
    sequences: List[List[str]] = []
    rep_traces = first_idx[order]  # trace index owning each variant
    names = activity_names
    for tr in rep_traces:
        idx = np.nonzero(t == tr)[0]
        sequences.append([names[int(a_)] for a_ in act[idx]])
    return TraceVariants(
        counts=counts[order].astype(np.int64),
        sequences=sequences,
        trace_variant=trace_variant,
    )


def trace_variants(repo: EventRepository) -> TraceVariants:
    return variant_table(
        repo.event_activity, repo.event_trace, repo.num_traces,
        repo.activity_names,
    )


def variant_filtered_repository(
    repo: EventRepository, keep_top: int
) -> EventRepository:
    """Keep only traces of the top-k variants (the spaghetti-model remedy:
    mine the mainstream behaviour, inspect the tail separately)."""
    tv = trace_variants(repo)
    keep_tr = np.nonzero(tv.trace_variant < keep_top)[0]
    mask = np.isin(repo.event_trace, keep_tr)
    idx = np.nonzero(mask)[0]
    old_to_new = {int(o): n for n, o in enumerate(keep_tr.tolist())}
    return EventRepository(
        event_activity=repo.event_activity[idx].copy(),
        event_trace=np.asarray(
            [old_to_new[int(x)] for x in repo.event_trace[idx]], np.int32
        ),
        event_time=repo.event_time[idx].copy(),
        trace_log=repo.trace_log[keep_tr].copy(),
        activity_names=list(repo.activity_names),
        trace_names=[repo.trace_names[int(x)] for x in keep_tr],
        log_names=list(repo.log_names),
    )
