"""Event repository (Definition 1 of Jalali 2020) in two isomorphic forms.

The paper stores logs in a graph database as nodes ``N = L ∪ T ∪ E ∪ A`` with
relations ``R = L×T ∪ T×E ∪ E×E ∪ E×A``.  We keep:

* :class:`GraphRepo` — the *literal* formalization: explicit node sets and a
  relation set.  Used for small inputs, the paper's worked example, soundness
  checking in the paper's exact terms, and property tests.

* :class:`EventRepository` — the scalable **columnar** form (struct of
  arrays).  This is the TPU-native encoding of the same graph: relations are
  aligned integer columns instead of pointers.  All heavy computation
  (Algorithm 1 / DFG) runs on this form, on-device.

The two forms convert losslessly in both directions for sound repositories.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = [
    "GraphRepo",
    "EventRepository",
    "concat_repositories",
    "paper_example_repo",
]


# ---------------------------------------------------------------------------
# Literal graph form (Definition 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphRepo:
    """``G = (N = L ∪ T ∪ E ∪ A, R)`` with explicit node/relation sets.

    Node identity is a string; the four subsets must be disjoint.  Relations
    are ordered pairs of node ids.
    """

    logs: Set[str]
    traces: Set[str]
    events: Set[str]
    attributes: Set[str]
    relations: Set[Tuple[str, str]]

    # -- the paper's two neighborhood operators ---------------------------
    def preset(self, n: str) -> Set[str]:
        """``•n`` — nodes with a relation *into* ``n``."""
        return {a for (a, b) in self.relations if b == n}

    def postset(self, n: str) -> Set[str]:
        """``n•`` — nodes with a relation *from* ``n``."""
        return {b for (a, b) in self.relations if a == n}

    # -- structural helpers ------------------------------------------------
    @property
    def nodes(self) -> Set[str]:
        return self.logs | self.traces | self.events | self.attributes

    def relation_classes(self) -> Dict[str, Set[Tuple[str, str]]]:
        """Split R into the four classes of Definition 1."""
        out: Dict[str, Set[Tuple[str, str]]] = {
            "LT": set(),
            "TE": set(),
            "EE": set(),
            "EA": set(),
            "other": set(),
        }
        for a, b in self.relations:
            if a in self.logs and b in self.traces:
                out["LT"].add((a, b))
            elif a in self.traces and b in self.events:
                out["TE"].add((a, b))
            elif a in self.events and b in self.events:
                out["EE"].add((a, b))
            elif a in self.events and b in self.attributes:
                out["EA"].add((a, b))
            else:
                out["other"].add((a, b))
        return out

    def well_formed(self) -> bool:
        """Definition 1 structural constraints (disjoint subsets, R classes)."""
        subsets = [self.logs, self.traces, self.events, self.attributes]
        for i in range(len(subsets)):
            for j in range(i + 1, len(subsets)):
                if subsets[i] & subsets[j]:
                    return False
        return not self.relation_classes()["other"]

    # -- conversion ---------------------------------------------------------
    def to_columnar(self) -> "EventRepository":
        """Convert a *sound* GraphRepo to the columnar form.

        Event order within a trace follows the E×E successor chain (the
        repository has no timestamps in the formal model, so synthetic
        times 0,1,2,… are assigned along each chain).
        """
        classes = self.relation_classes()
        log_names = sorted(self.logs)
        trace_names = sorted(self.traces)
        act_names = sorted(self.attributes)
        log_idx = {n: i for i, n in enumerate(log_names)}
        trace_idx = {n: i for i, n in enumerate(trace_names)}
        act_idx = {n: i for i, n in enumerate(act_names)}

        trace_of_event: Dict[str, str] = {}
        for t, e in classes["TE"]:
            trace_of_event[e] = t
        act_of_event: Dict[str, str] = {}
        for e, a in classes["EA"]:
            act_of_event[e] = a
        succ: Dict[str, str] = {}
        has_pred: Set[str] = set()
        for e1, e2 in classes["EE"]:
            succ[e1] = e2
            has_pred.add(e2)

        trace_log = np.zeros(len(trace_names), dtype=np.int32)
        for l, t in classes["LT"]:
            trace_log[trace_idx[t]] = log_idx[l]

        ev_act: List[int] = []
        ev_trace: List[int] = []
        ev_time: List[float] = []
        ev_names: List[str] = []
        for t in trace_names:
            members = [e for e in self.events if trace_of_event.get(e) == t]
            heads = [e for e in members if e not in has_pred]
            # sound repo ⇒ exactly one chain per trace (or empty trace)
            heads.sort()
            order: List[str] = []
            for h in heads:
                cur: Optional[str] = h
                while cur is not None and cur in set(members) - set(order):
                    order.append(cur)
                    cur = succ.get(cur)
            for k, e in enumerate(order):
                ev_names.append(e)
                ev_act.append(act_idx[act_of_event[e]])
                ev_trace.append(trace_idx[t])
                ev_time.append(float(k))

        return EventRepository(
            event_activity=np.asarray(ev_act, dtype=np.int32),
            event_trace=np.asarray(ev_trace, dtype=np.int32),
            event_time=np.asarray(ev_time, dtype=np.float64),
            trace_log=trace_log,
            activity_names=act_names,
            trace_names=trace_names,
            log_names=log_names,
            event_names=ev_names,
        )


# ---------------------------------------------------------------------------
# Columnar form — the scalable representation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EventRepository:
    """Columnar event repository (canonical form).

    Canonical invariants (established by :meth:`from_event_table`):

    * events are **trace-contiguous**: all events of a trace are adjacent;
    * within a trace, events are sorted by ``event_time`` (stable);
    * ``event_trace`` is therefore non-decreasing.

    The E×E "directly follows" relation is *implicit*: event ``i`` directly
    precedes ``i+1`` iff ``event_trace[i] == event_trace[i+1]``.  This is the
    struct-of-arrays encoding of the paper's successor pointers.
    """

    event_activity: np.ndarray  # (E,) int32 — the E×A relation
    event_trace: np.ndarray  # (E,) int32 — the T×E relation (segment ids)
    event_time: np.ndarray  # (E,) float64 — Event property (paper §4)
    trace_log: np.ndarray  # (T,) int32 — the L×T relation
    activity_names: List[str]
    trace_names: List[str]
    log_names: List[str]
    event_names: Optional[List[str]] = None

    # -- sizes --------------------------------------------------------------
    @property
    def num_events(self) -> int:
        return int(self.event_activity.shape[0])

    @property
    def num_traces(self) -> int:
        return int(self.trace_log.shape[0])

    @property
    def num_activities(self) -> int:
        return len(self.activity_names)

    @property
    def num_logs(self) -> int:
        return len(self.log_names)

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_event_table(
        case_ids: Sequence,
        activities: Sequence,
        timestamps: Optional[Sequence[float]] = None,
        log_ids: Optional[Sequence] = None,
        activity_vocab: Optional[List[str]] = None,
    ) -> "EventRepository":
        """Ingest a flat event table (one row per event) and canonicalize.

        Rows may arrive in any order; they are stably sorted by
        (case, timestamp).  When ``timestamps`` is None, arrival order within
        a case is used (the paper: "events should be stored according to the
        execution order, unless we have information about execution time").
        """
        n = len(case_ids)
        if len(activities) != n:
            raise ValueError("case_ids and activities must align")
        ts = (
            np.asarray(timestamps, dtype=np.float64)
            if timestamps is not None
            else np.arange(n, dtype=np.float64)
        )
        if ts.shape[0] != n:
            raise ValueError("timestamps must align with events")

        case_arr = np.asarray([str(c) for c in case_ids])
        trace_names = sorted(set(case_arr.tolist()))
        trace_idx = {c: i for i, c in enumerate(trace_names)}
        trace_col = np.asarray([trace_idx[c] for c in case_arr], dtype=np.int32)

        act_arr = [str(a) for a in activities]
        if activity_vocab is None:
            activity_vocab = sorted(set(act_arr))
        act_idx = {a: i for i, a in enumerate(activity_vocab)}
        try:
            act_col = np.asarray([act_idx[a] for a in act_arr], dtype=np.int32)
        except KeyError as e:
            raise ValueError(f"activity {e} not in provided vocabulary") from e

        if log_ids is None:
            log_names = ["l1"]
            trace_log = np.zeros(len(trace_names), dtype=np.int32)
        else:
            log_arr = np.asarray([str(x) for x in log_ids])
            log_names = sorted(set(log_arr.tolist()))
            log_idx = {x: i for i, x in enumerate(log_names)}
            trace_log = np.zeros(len(trace_names), dtype=np.int32)
            for c, l in zip(case_arr, log_arr):
                trace_log[trace_idx[c]] = log_idx[l]

        order = np.lexsort((np.arange(n), ts, trace_col))
        return EventRepository(
            event_activity=act_col[order],
            event_trace=trace_col[order],
            event_time=ts[order],
            trace_log=trace_log,
            activity_names=list(activity_vocab),
            trace_names=trace_names,
            log_names=log_names,
        )

    @staticmethod
    def from_traces(
        traces: Sequence[Sequence[str]],
        activity_vocab: Optional[List[str]] = None,
        log_name: str = "l1",
    ) -> "EventRepository":
        """Build from a list of activity-name sequences (one per trace)."""
        cases: List[str] = []
        acts: List[str] = []
        times: List[float] = []
        for i, tr in enumerate(traces):
            for k, a in enumerate(tr):
                cases.append(f"t{i + 1}")
                acts.append(a)
                times.append(float(k))
        repo = EventRepository.from_event_table(
            cases, acts, times, activity_vocab=activity_vocab
        )
        repo.log_names = [log_name]
        return repo

    # -- paper operators on the columnar form --------------------------------
    def events_of_activity(self, activity: str) -> np.ndarray:
        """``•a`` for an attribute node — indices of events executing it."""
        a = self.activity_names.index(activity)
        return np.nonzero(self.event_activity == a)[0]

    def trace_of(self, event_index: int) -> str:
        return self.trace_names[int(self.event_trace[event_index])]

    # -- the L×T relation as a dice ------------------------------------------
    def select_logs(self, names: Sequence[str]) -> "EventRepository":
        """Sub-repository of the traces belonging to the named logs.

        This is the L×T dice of Definition 1: keep exactly the traces whose
        ``trace_log`` entry names one of ``names`` (whole traces — the E×E
        chains are untouched, so canonical invariants are preserved).  The
        activity vocabulary is kept in full so per-log results from one
        repository stay aligned on a shared activity axis.
        """
        ids = []
        for n in names:
            if n not in self.log_names:
                raise ValueError(
                    f"unknown log {n!r}; repository has {self.log_names}"
                )
            ids.append(self.log_names.index(n))
        keep_trace = np.isin(self.trace_log, ids)
        new_trace_idx = np.cumsum(keep_trace) - 1  # old trace id -> new
        keep_event = keep_trace[self.event_trace]

        wanted = set(names)
        sub_log_names = [n for n in self.log_names if n in wanted]
        new_log_idx = {
            self.log_names.index(n): i for i, n in enumerate(sub_log_names)
        }
        trace_log = np.asarray(
            [new_log_idx[int(l)] for l in self.trace_log[keep_trace]],
            dtype=np.int32,
        )
        return EventRepository(
            event_activity=self.event_activity[keep_event],
            event_trace=new_trace_idx[self.event_trace[keep_event]].astype(
                np.int32
            ),
            event_time=self.event_time[keep_event],
            trace_log=trace_log,
            activity_names=list(self.activity_names),
            trace_names=[
                t for t, k in zip(self.trace_names, keep_trace) if k
            ],
            log_names=sub_log_names,
            event_names=(
                [e for e, k in zip(self.event_names, keep_event) if k]
                if self.event_names is not None
                else None
            ),
        )

    def split_logs(self, names: Sequence[str]) -> Dict[str, "EventRepository"]:
        """Multi-way :meth:`select_logs` in one shared pass.

        Splitting a k-log repository branch-by-branch would gather the
        per-event log id k times; this computes it once and slices each
        requested log off it — the per-branch results are exactly
        ``select_logs([name])``."""
        ids = {}
        for n in names:
            if n not in self.log_names:
                raise ValueError(
                    f"unknown log {n!r}; repository has {self.log_names}"
                )
            ids[n] = self.log_names.index(n)
        event_log = self.trace_log[self.event_trace]  # the shared gather
        out: Dict[str, EventRepository] = {}
        for n, lid in ids.items():
            keep_trace = self.trace_log == lid
            new_trace_idx = np.cumsum(keep_trace) - 1
            keep_event = event_log == lid
            out[n] = EventRepository(
                event_activity=self.event_activity[keep_event],
                event_trace=new_trace_idx[
                    self.event_trace[keep_event]
                ].astype(np.int32),
                event_time=self.event_time[keep_event],
                trace_log=np.zeros(int(keep_trace.sum()), dtype=np.int32),
                activity_names=list(self.activity_names),
                trace_names=[
                    t for t, k in zip(self.trace_names, keep_trace) if k
                ],
                log_names=[n],
                event_names=(
                    [e for e, k in zip(self.event_names, keep_event) if k]
                    if self.event_names is not None
                    else None
                ),
            )
        return out

    # -- directly-follows pairs (the E×E relation, vectorized) ---------------
    def df_pairs(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return ``(src_act, dst_act, pair_valid)`` aligned arrays.

        ``src_act[i] = activity of event i``, ``dst_act[i] = activity of
        event i+1``, valid iff both belong to the same trace.  Shape (E-1,)
        (or (0,) for empty/singleton repositories).
        """
        a = self.event_activity
        t = self.event_trace
        if a.shape[0] < 2:
            z = np.zeros((0,), dtype=np.int32)
            return z, z, np.zeros((0,), dtype=bool)
        return a[:-1], a[1:], t[:-1] == t[1:]

    def padded_pairs(
        self, multiple: int
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """df_pairs padded to a length multiple (for sharding / kernels).

        Returns (src, dst, valid, src_time, dst_time), all length
        ``ceil((E-1)/multiple) * multiple`` (min. one multiple).
        """
        src, dst, valid = self.df_pairs()
        ts = self.event_time
        st = ts[:-1] if ts.shape[0] >= 2 else np.zeros((0,), np.float64)
        dt = ts[1:] if ts.shape[0] >= 2 else np.zeros((0,), np.float64)
        n = src.shape[0]
        padded = max(multiple, ((n + multiple - 1) // multiple) * multiple)
        pad = padded - n
        src = np.pad(src, (0, pad))
        dst = np.pad(dst, (0, pad))
        valid = np.pad(valid, (0, pad))
        st = np.pad(st, (0, pad))
        dt = np.pad(dt, (0, pad))
        return src, dst, valid, st, dt

    # -- trace boundaries -----------------------------------------------------
    def trace_boundaries(self) -> Tuple[np.ndarray, np.ndarray]:
        """(start_counts, end_counts) per activity — used for discovery's
        artificial START/END transitions."""
        starts = np.zeros(self.num_activities, dtype=np.int64)
        ends = np.zeros(self.num_activities, dtype=np.int64)
        t = self.event_trace
        a = self.event_activity
        if t.shape[0] == 0:
            return starts, ends
        is_start = np.ones(t.shape[0], dtype=bool)
        is_start[1:] = t[1:] != t[:-1]
        is_end = np.ones(t.shape[0], dtype=bool)
        is_end[:-1] = t[:-1] != t[1:]
        np.add.at(starts, a[is_start], 1)
        np.add.at(ends, a[is_end], 1)
        return starts, ends

    # -- conversion back to the literal graph --------------------------------
    def to_graph(self) -> GraphRepo:
        logs = {f"log:{n}" for n in self.log_names}
        traces = {f"trace:{n}" for n in self.trace_names}
        attrs = {f"act:{n}" for n in self.activity_names}
        ev_names = self.event_names or [f"e{i + 1}" for i in range(self.num_events)]
        events = set(ev_names)
        rel: Set[Tuple[str, str]] = set()
        for ti, li in enumerate(self.trace_log):
            rel.add((f"log:{self.log_names[int(li)]}", f"trace:{self.trace_names[ti]}"))
        for i in range(self.num_events):
            rel.add((f"trace:{self.trace_names[int(self.event_trace[i])]}", ev_names[i]))
            rel.add((ev_names[i], f"act:{self.activity_names[int(self.event_activity[i])]}"))
            if i + 1 < self.num_events and self.event_trace[i] == self.event_trace[i + 1]:
                rel.add((ev_names[i], ev_names[i + 1]))
        return GraphRepo(logs=logs, traces=traces, events=events, attributes=attrs, relations=rel)

    # -- persistence (two-tier store: see core/streaming.py for memmap tier) --
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.save(os.path.join(path, "event_activity.npy"), self.event_activity)
        np.save(os.path.join(path, "event_trace.npy"), self.event_trace)
        np.save(os.path.join(path, "event_time.npy"), self.event_time)
        np.save(os.path.join(path, "trace_log.npy"), self.trace_log)
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump(
                {
                    "activity_names": self.activity_names,
                    "trace_names": self.trace_names,
                    "log_names": self.log_names,
                },
                f,
            )

    @staticmethod
    def load(path: str) -> "EventRepository":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return EventRepository(
            event_activity=np.load(os.path.join(path, "event_activity.npy")),
            event_trace=np.load(os.path.join(path, "event_trace.npy")),
            event_time=np.load(os.path.join(path, "event_time.npy")),
            trace_log=np.load(os.path.join(path, "trace_log.npy")),
            activity_names=meta["activity_names"],
            trace_names=meta["trace_names"],
            log_names=meta["log_names"],
        )


def concat_repositories(
    named: Sequence[Tuple[str, EventRepository]],
    activity_vocab: Optional[List[str]] = None,
) -> EventRepository:
    """Concatenate named repositories into one canonical multi-log repository.

    The result is exactly what :meth:`EventRepository.from_event_table` would
    build from the flat union of the branches' event tables:

    * trace names are namespaced ``"<log>/<trace>"`` — traces never merge
      across branches, so Ψ of the concatenation is the branch-wise sum;
    * ``log_names`` is the sorted branch-name list, ``trace_log`` records the
      provenance of every trace (the L×T relation of Definition 1);
    * the activity vocabulary is the sorted union of the branch vocabularies
      (or the provided ``activity_vocab``), and events are re-lexsorted into
      trace-contiguous, time-sorted canonical order with arrival order (=
      branch order) as the stable tie-break.

    The query engine's union sinks are pinned bit-identical against
    Algorithm 1 on this concatenation.
    """
    if not named:
        raise ValueError("concat_repositories needs at least one repository")
    branch_names = [n for n, _ in named]
    if len(set(branch_names)) != len(branch_names):
        raise ValueError(f"duplicate branch names: {branch_names}")

    if activity_vocab is None:
        activity_vocab = sorted(
            set().union(*[set(r.activity_names) for _, r in named])
        )
    vidx = {a: i for i, a in enumerate(activity_vocab)}

    trace_names: List[str] = []
    for bname, repo in named:
        trace_names.extend(f"{bname}/{t}" for t in repo.trace_names)
    if len(set(trace_names)) != len(trace_names):
        # e.g. branches "a" and "a/x" with traces "x/t" and "t" both
        # namespace to "a/x/t" — merging them would silently corrupt Ψ
        raise ValueError(
            "namespaced trace names collide across branches; rename the "
            "branches so '<branch>/<trace>' stays unique"
        )
    trace_names.sort()
    tidx = {t: i for i, t in enumerate(trace_names)}

    log_names = sorted(branch_names)
    lidx = {n: i for i, n in enumerate(log_names)}
    trace_log = np.zeros(len(trace_names), dtype=np.int32)

    acts, traces, times = [], [], []
    for bname, repo in named:
        try:
            actmap = np.asarray(
                [vidx[a] for a in repo.activity_names], dtype=np.int32
            )
        except KeyError as e:
            raise ValueError(f"activity {e} not in provided vocabulary") from e
        tmap = np.asarray(
            [tidx[f"{bname}/{t}"] for t in repo.trace_names], dtype=np.int32
        )
        trace_log[tmap] = lidx[bname]
        if repo.num_events:
            acts.append(actmap[repo.event_activity])
            traces.append(tmap[repo.event_trace])
            times.append(repo.event_time)

    a = np.concatenate(acts) if acts else np.zeros((0,), np.int32)
    t = np.concatenate(traces) if traces else np.zeros((0,), np.int32)
    ts = np.concatenate(times) if times else np.zeros((0,), np.float64)
    order = np.lexsort((np.arange(a.shape[0]), ts, t))
    return EventRepository(
        event_activity=a[order].astype(np.int32),
        event_trace=t[order].astype(np.int32),
        event_time=ts[order],
        trace_log=trace_log,
        activity_names=list(activity_vocab),
        trace_names=trace_names,
        log_names=log_names,
    )


def paper_example_repo() -> EventRepository:
    """The worked example of Fig. 3: l1 = {t1: a1,a2,a3 ; t2: a2,a3,a4}."""
    return EventRepository.from_traces(
        [["a1", "a2", "a3"], ["a2", "a3", "a4"]],
        activity_vocab=["a1", "a2", "a3", "a4"],
    )
