"""Soundness (Definition 2 of the paper) for both repository forms.

A repository is sound iff:
  S1. every trace belongs to exactly one log          (|•t| = 1)
  S2. every event belongs to exactly one trace        (|•e ∩ T| = 1)
  S3. every event has at most one incoming E×E flow   (|•e ∩ E| ≤ 1)
  S4. every event has at most one outgoing E×E flow   (|e• ∩ E| ≤ 1)
  S5. every event has exactly one activity attribute  (|e• ∩ A| = 1)
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .repository import EventRepository, GraphRepo

__all__ = ["SoundnessReport", "check_graph", "check_columnar", "is_sound"]


@dataclasses.dataclass
class SoundnessReport:
    ok: bool
    violations: List[str]

    def __bool__(self) -> bool:  # pragma: no cover - convenience
        return self.ok


def check_graph(g: GraphRepo) -> SoundnessReport:
    """Literal Definition 2 on the explicit graph form."""
    v: List[str] = []
    if not g.well_formed():
        v.append("not well-formed per Definition 1 (overlapping subsets or stray relations)")
    for t in sorted(g.traces):
        n = len(g.preset(t) & g.logs)
        if n != 1:
            v.append(f"S1: trace {t} belongs to {n} logs (must be 1)")
    for e in sorted(g.events):
        pre = g.preset(e)
        post = g.postset(e)
        nt = len(pre & g.traces)
        if nt != 1:
            v.append(f"S2: event {e} belongs to {nt} traces (must be 1)")
        ne_in = len(pre & g.events)
        if ne_in > 1:
            v.append(f"S3: event {e} has {ne_in} incoming E×E flows (max 1)")
        ne_out = len(post & g.events)
        if ne_out > 1:
            v.append(f"S4: event {e} has {ne_out} outgoing E×E flows (max 1)")
        na = len(post & g.attributes)
        if na != 1:
            v.append(f"S5: event {e} has {na} activity attributes (must be 1)")
    return SoundnessReport(ok=not v, violations=v)


def check_columnar(repo: EventRepository) -> SoundnessReport:
    """Soundness + canonical-form invariants on the columnar encoding.

    S2/S5 hold *by construction* in the columnar form (each event row carries
    exactly one trace id and one activity id); what must be validated is that
    the ids are in range and the canonical invariants (trace-contiguity,
    per-trace time order) that make the implicit E×E relation well defined —
    these imply S3/S4.
    """
    v: List[str] = []
    a, t, ts = repo.event_activity, repo.event_trace, repo.event_time
    E = repo.num_events
    if a.shape != (E,) or t.shape != (E,) or ts.shape != (E,):
        v.append("column length mismatch")
        return SoundnessReport(ok=False, violations=v)
    if E and (a.min() < 0 or a.max() >= repo.num_activities):
        v.append("S5: activity id out of range")
    if E and (t.min() < 0 or t.max() >= repo.num_traces):
        v.append("S2: trace id out of range")
    if repo.num_traces and (
        repo.trace_log.min() < 0 or repo.trace_log.max() >= repo.num_logs
    ):
        v.append("S1: log id out of range")
    if repo.trace_log.shape[0] != repo.num_traces:
        v.append("S1: trace_log column must assign exactly one log per trace")
    # trace-contiguity: each trace id forms one contiguous run (⇒ S3, S4)
    if E:
        change = np.nonzero(t[1:] != t[:-1])[0]
        starts = np.concatenate([[0], change + 1])
        run_ids = t[starts]
        if len(set(run_ids.tolist())) != len(run_ids):
            v.append("S3/S4: trace ids not contiguous — implicit E×E relation ambiguous")
        # within-trace time order
        same = t[1:] == t[:-1]
        if np.any(ts[1:][same] < ts[:-1][same]):
            v.append("canonical: event_time not non-decreasing within a trace")
    return SoundnessReport(ok=not v, violations=v)


def is_sound(obj) -> bool:
    if isinstance(obj, GraphRepo):
        return check_graph(obj).ok
    if isinstance(obj, EventRepository):
        return check_columnar(obj).ok
    raise TypeError(type(obj))
