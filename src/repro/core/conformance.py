"""Conformance checking: token replay on a discovered dependency graph.

The paper positions DFG computation as the backbone for "discovery,
conformance, and enhancement" (§2.1).  Footprint comparison
(:func:`repro.core.discovery.footprint_conformance`) covers the
relation-level view; this module adds **trace-level replay fitness**: each
trace is replayed over the model's edge set and scored by the fraction of
its moves the model allows — vectorized over all traces at once (edge
lookups become one boolean gather over the pair columns), so it runs on
million-event logs.

fitness(trace) = (allowed directly-follows moves + allowed start + allowed
end) / (len(trace) + 1), matching the DFG abstraction's replay semantics.

This module is the *columnar oracle* of the wider :mod:`repro.conformance`
subsystem: the streaming and graph-native replay paths there are pinned
bit-identical to :func:`replay_fitness`.  Shared pieces live here so every
path uses the same arithmetic:

* :class:`ModelSpec` — the canonical, hashable form of a
  :class:`~repro.core.discovery.DiscoveredModel` (edge set + start/end
  sets), usable as a frozen query-plan field;
* :func:`model_tables` — (allowed, start_ok, end_ok) boolean tables over a
  given activity axis;
* :func:`deviation_census` — the disallowed-move census, vectorized via
  ``np.unique`` over encoded pair ids (no host loop over deviating pairs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple, Union

import numpy as np

from .discovery import DiscoveredModel
from .repository import EventRepository

__all__ = [
    "ModelSpec",
    "ReplayResult",
    "model_tables",
    "deviation_census",
    "replay_core",
    "replay_fitness",
]


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Canonical, hashable mirror of :class:`DiscoveredModel` — exactly the
    information replay/alignment consumes (the edge relation plus start/end
    sets), sorted so two equivalent models share one plan-cache key."""

    activities: Tuple[str, ...]
    edges: Tuple[Tuple[str, str], ...]
    starts: Tuple[str, ...]
    ends: Tuple[str, ...]

    @staticmethod
    def from_model(
        model: Union[DiscoveredModel, "ModelSpec"]
    ) -> "ModelSpec":
        if isinstance(model, ModelSpec):
            return model
        return ModelSpec(
            activities=tuple(model.activities),
            edges=tuple(sorted(model.edge_set)),
            starts=tuple(sorted(model.start_activities)),
            ends=tuple(sorted(model.end_activities)),
        )


def model_tables(
    model: Union[DiscoveredModel, ModelSpec], names: Sequence[str]
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(allowed (A,A), start_ok (A,), end_ok (A,)) boolean tables of the
    model over the activity axis ``names``.  Model activities absent from
    ``names`` are simply not representable (their edges drop); activities in
    ``names`` unknown to the model get all-False rows — both directions of
    vocabulary mismatch degrade to "move not allowed", never an error."""
    spec = ModelSpec.from_model(model)
    idx = {n: i for i, n in enumerate(names)}
    a = len(names)
    allowed = np.zeros((a, a), dtype=bool)
    for s, d in spec.edges:
        si, di = idx.get(s), idx.get(d)
        if si is not None and di is not None:
            allowed[si, di] = True
    start_ok = np.zeros(a, dtype=bool)
    for s in spec.starts:
        si = idx.get(s)
        if si is not None:
            start_ok[si] = True
    end_ok = np.zeros(a, dtype=bool)
    for e in spec.ends:
        ei = idx.get(e)
        if ei is not None:
            end_ok[ei] = True
    return allowed, start_ok, end_ok


def deviation_census(
    bad_src: np.ndarray, bad_dst: np.ndarray, names: Sequence[str]
) -> Dict[tuple, int]:
    """``(src_name, dst_name) → count`` over disallowed moves, vectorized:
    pairs are encoded as ``src·A + dst`` ids and counted with one
    ``np.unique`` — million-event logs with noisy traces no longer pay a
    Python loop per deviating pair."""
    if bad_src.shape[0] == 0:
        return {}
    a = len(names)
    keys = bad_src.astype(np.int64) * a + bad_dst.astype(np.int64)
    uniq, counts = np.unique(keys, return_counts=True)
    return {
        (names[int(k // a)], names[int(k % a)]): int(c)
        for k, c in zip(uniq, counts)
    }


@dataclasses.dataclass
class ReplayResult:
    fitness: float  # mean trace fitness in [0, 1]
    trace_fitness: np.ndarray  # (T,)
    perfectly_fitting: int  # traces with fitness == 1
    deviating_edges: Dict[tuple, int]  # (src, dst) → count of disallowed moves

    def summary(self) -> Dict:
        worst = sorted(
            self.deviating_edges.items(), key=lambda kv: -kv[1]
        )[:5]
        return {
            "fitness": round(self.fitness, 4),
            "perfect_traces": self.perfectly_fitting,
            "total_traces": int(self.trace_fitness.shape[0]),
            "top_deviations": [
                {"edge": list(e), "count": c} for e, c in worst
            ],
        }


def replay_core(
    a: np.ndarray,
    t: np.ndarray,
    num_traces: int,
    allowed: np.ndarray,
    start_ok: np.ndarray,
    end_ok: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Token replay over canonical (trace-contiguous) event columns.

    Returns ``(trace_fitness (T,), bad_src, bad_dst)`` — the per-trace
    scores plus the disallowed directly-follows pairs for the census.  This
    is the one arithmetic every replay path (columnar, streaming, graph)
    must reproduce bit for bit.
    """
    T = int(num_traces)
    lens = np.bincount(t, minlength=T)

    ok_moves = np.zeros(T, dtype=np.int64)
    bad_src = np.zeros((0,), dtype=np.int64)
    bad_dst = np.zeros((0,), dtype=np.int64)
    if a.shape[0] >= 2:
        same = t[:-1] == t[1:]
        edge_ok = allowed[a[:-1], a[1:]]
        move_ok = edge_ok & same
        np.add.at(ok_moves, t[:-1][same], move_ok[same].astype(np.int64))
        bad = same & ~edge_ok
        bad_src = a[:-1][bad].astype(np.int64)
        bad_dst = a[1:][bad].astype(np.int64)

    starts_fit = np.zeros(T, dtype=np.int64)
    ends_fit = np.zeros(T, dtype=np.int64)
    if a.shape[0]:
        is_start = np.ones(a.shape[0], dtype=bool)
        is_start[1:] = t[1:] != t[:-1]
        is_end = np.ones(a.shape[0], dtype=bool)
        is_end[:-1] = t[:-1] != t[1:]
        np.add.at(
            starts_fit, t[is_start], start_ok[a[is_start]].astype(np.int64)
        )
        np.add.at(ends_fit, t[is_end], end_ok[a[is_end]].astype(np.int64))

    denom = np.maximum(lens + 1, 1)  # (len-1) moves + start + end
    trace_fit = (ok_moves + starts_fit + ends_fit) / denom
    return trace_fit, bad_src, bad_dst


def replay_fitness(
    repo: EventRepository, model: Union[DiscoveredModel, ModelSpec]
) -> ReplayResult:
    names = repo.activity_names
    allowed, start_ok, end_ok = model_tables(model, names)
    trace_fit, bad_src, bad_dst = replay_core(
        repo.event_activity, repo.event_trace, repo.num_traces,
        allowed, start_ok, end_ok,
    )
    return ReplayResult(
        fitness=float(trace_fit.mean()) if trace_fit.shape[0] else 1.0,
        trace_fitness=trace_fit,
        perfectly_fitting=int((trace_fit >= 1.0 - 1e-12).sum()),
        deviating_edges=deviation_census(bad_src, bad_dst, names),
    )
