"""Conformance checking: token replay on a discovered dependency graph.

The paper positions DFG computation as the backbone for "discovery,
conformance, and enhancement" (§2.1).  Footprint comparison
(:func:`repro.core.discovery.footprint_conformance`) covers the
relation-level view; this module adds **trace-level replay fitness**: each
trace is replayed over the model's edge set and scored by the fraction of
its moves the model allows — vectorized over all traces at once (edge
lookups become one boolean gather over the pair columns), so it runs on
million-event logs.

fitness(trace) = (allowed directly-follows moves + allowed start + allowed
end) / (len(trace) + 1), matching the DFG abstraction's replay semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from .discovery import DiscoveredModel
from .repository import EventRepository

__all__ = ["ReplayResult", "replay_fitness"]


@dataclasses.dataclass
class ReplayResult:
    fitness: float  # mean trace fitness in [0, 1]
    trace_fitness: np.ndarray  # (T,)
    perfectly_fitting: int  # traces with fitness == 1
    deviating_edges: Dict[tuple, int]  # (src, dst) → count of disallowed moves

    def summary(self) -> Dict:
        worst = sorted(
            self.deviating_edges.items(), key=lambda kv: -kv[1]
        )[:5]
        return {
            "fitness": round(self.fitness, 4),
            "perfect_traces": self.perfectly_fitting,
            "total_traces": int(self.trace_fitness.shape[0]),
            "top_deviations": [
                {"edge": list(e), "count": c} for e, c in worst
            ],
        }


def replay_fitness(
    repo: EventRepository, model: DiscoveredModel
) -> ReplayResult:
    names = repo.activity_names
    idx = {n: i for i, n in enumerate(names)}
    A = repo.num_activities

    allowed = np.zeros((A, A), dtype=bool)
    for s, d in model.edge_set:
        if s in idx and d in idx:
            allowed[idx[s], idx[d]] = True
    start_ok = np.zeros(A, dtype=bool)
    for a in model.start_activities:
        if a in idx:
            start_ok[idx[a]] = True
    end_ok = np.zeros(A, dtype=bool)
    for a in model.end_activities:
        if a in idx:
            end_ok[idx[a]] = True

    t = repo.event_trace
    a = repo.event_activity
    T = repo.num_traces
    lens = np.bincount(t, minlength=T)

    ok_moves = np.zeros(T, dtype=np.int64)
    if repo.num_events >= 2:
        same = t[:-1] == t[1:]
        move_ok = allowed[a[:-1], a[1:]] & same
        np.add.at(ok_moves, t[:-1][same], move_ok[same].astype(np.int64))

    is_start = np.ones(repo.num_events, dtype=bool)
    is_start[1:] = t[1:] != t[:-1]
    is_end = np.ones(repo.num_events, dtype=bool)
    is_end[:-1] = t[:-1] != t[1:]
    starts_fit = np.zeros(T, dtype=np.int64)
    ends_fit = np.zeros(T, dtype=np.int64)
    np.add.at(starts_fit, t[is_start], start_ok[a[is_start]].astype(np.int64))
    np.add.at(ends_fit, t[is_end], end_ok[a[is_end]].astype(np.int64))

    denom = np.maximum(lens + 1, 1)  # (len-1) moves + start + end
    trace_fit = (ok_moves + starts_fit + ends_fit) / denom

    # deviation census (host loop over *deviating pairs only*)
    deviations: Dict[tuple, int] = {}
    if repo.num_events >= 2:
        same = t[:-1] == t[1:]
        bad = same & ~allowed[a[:-1], a[1:]]
        for s_, d_ in zip(a[:-1][bad], a[1:][bad]):
            key = (names[int(s_)], names[int(d_)])
            deviations[key] = deviations.get(key, 0) + 1

    return ReplayResult(
        fitness=float(trace_fit.mean()) if T else 1.0,
        trace_fitness=trace_fit,
        perfectly_fitting=int((trace_fit >= 1.0 - 1e-12).sum()),
        deviating_edges=deviations,
    )
