"""Dicing — the paper's WHERE-clause filtering (Experiment 2).

Two semantics are provided:

* **Paper semantics** (:func:`pair_mask_for_window`): the E×E relation is
  fixed; a directly-follows pair is counted iff *both* endpoint events fall
  in the window.  This matches the Cypher query with an added WHERE clause
  and is the semantics used by the benchmarks.

* **pm4py semantics** (:func:`dice_repository`): filter events, then
  re-link survivors within each trace (events that become adjacent after
  removal *do* count).  Provided for apples-to-apples baseline comparison.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from .repository import EventRepository

__all__ = [
    "event_mask_for_window",
    "pair_mask_for_window",
    "event_mask_for_activities",
    "dice_repository",
]


def event_mask_for_window(
    repo: EventRepository, window: Tuple[float, float]
) -> np.ndarray:
    """Boolean per-event mask for ``t0 <= time < t1``."""
    t0, t1 = window
    ts = repo.event_time
    return (ts >= t0) & (ts < t1)


def pair_mask_for_window(
    repo: EventRepository, window: Tuple[float, float]
) -> np.ndarray:
    """Per-pair mask (length E-1): both endpoints inside the window."""
    m = event_mask_for_window(repo, window)
    if m.shape[0] < 2:
        return np.zeros((0,), dtype=bool)
    return m[:-1] & m[1:]


def event_mask_for_activities(
    repo: EventRepository, keep: Sequence[str]
) -> np.ndarray:
    keep_ids = np.asarray(
        [repo.activity_names.index(a) for a in keep], dtype=np.int32
    )
    return np.isin(repo.event_activity, keep_ids)


def dice_repository(
    repo: EventRepository,
    *,
    time_window: Optional[Tuple[float, float]] = None,
    activities: Optional[Sequence[str]] = None,
) -> EventRepository:
    """pm4py-style dicing: materialize the filtered repository with events
    re-linked within traces.  O(E) host-side; used for baseline comparisons
    and for analysts who explicitly request re-linking semantics."""
    mask = np.ones(repo.num_events, dtype=bool)
    if time_window is not None:
        mask &= event_mask_for_window(repo, time_window)
    if activities is not None:
        mask &= event_mask_for_activities(repo, activities)
    idx = np.nonzero(mask)[0]
    kept_traces = np.unique(repo.event_trace[idx])
    old_to_new = {int(t): i for i, t in enumerate(kept_traces.tolist())}
    new_trace = np.asarray(
        [old_to_new[int(t)] for t in repo.event_trace[idx]], dtype=np.int32
    )
    return EventRepository(
        event_activity=repo.event_activity[idx].copy(),
        event_trace=new_trace,
        event_time=repo.event_time[idx].copy(),
        trace_log=repo.trace_log[kept_traces].copy(),
        activity_names=list(repo.activity_names),
        trace_names=[repo.trace_names[int(t)] for t in kept_traces],
        log_names=list(repo.log_names),
    )
