"""Algorithm 1 — Directly Follows Graph computation, in-store.

Formulations (all return an ``(A, A)`` count matrix ``Ψ[a, b] = |a >_L b|``):

* :func:`dfg_algorithm1` — literal transcription of the paper's pseudocode on
  the explicit graph form (O(A²·E); oracle for tests only).
* :func:`dfg_scatter` — jnp ``.at[src, dst].add`` over directly-follows
  pairs. Natural on CPU/GPU; on TPU scatters serialize, hence:
* :func:`dfg_onehot` — the MXU formulation ``Ψ = Σ OneHot(src)ᵀ·OneHot(dst)``
  (chunked so one-hots never materialize at full E×A).  This is the TPU
  adaptation of the paper's Cypher MATCH: pattern counting becomes a dense
  systolic matmul.
* ``backend="pallas"`` routes to :mod:`repro.kernels.dfg_count` (explicit
  VMEM tiling; validated in interpret mode on CPU).

The public entry point :func:`dfg` / :func:`dfg_from_repository` mirrors the
paper's single Cypher query, including the WHERE-clause dicing (a time
window mask applied to pairs) and access-control views.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .repository import EventRepository, GraphRepo

__all__ = [
    "dfg_algorithm1",
    "dfg_scatter",
    "dfg_onehot",
    "dfg",
    "dfg_from_repository",
    "dfg_numpy",
]


# ---------------------------------------------------------------------------
# Oracle: the paper's Algorithm 1, verbatim
# ---------------------------------------------------------------------------


def dfg_algorithm1(g: GraphRepo) -> Tuple[np.ndarray, list]:
    """Literal Algorithm 1 on the explicit graph: for each pair of attributes
    (a, b), ``c = Σ_{e ∈ •a, e' ∈ •b} |(e, e') ∈ R|``.

    Returns (matrix, activity_names) with activities sorted by name.
    """
    acts = sorted(g.attributes)
    idx = {a: i for i, a in enumerate(acts)}
    psi = np.zeros((len(acts), len(acts)), dtype=np.int64)
    for a in acts:
        ea = g.preset(a) & g.events
        for b in acts:
            eb = g.preset(b) & g.events
            c = sum(1 for e in ea for e2 in eb if (e, e2) in g.relations)
            psi[idx[a], idx[b]] = c
    return psi, acts


# ---------------------------------------------------------------------------
# numpy reference on pair columns (used by streaming tier & tests)
# ---------------------------------------------------------------------------


def dfg_numpy(
    src: np.ndarray, dst: np.ndarray, valid: np.ndarray, num_activities: int
) -> np.ndarray:
    psi = np.zeros((num_activities, num_activities), dtype=np.int64)
    if src.shape[0]:
        np.add.at(psi, (src[valid], dst[valid]), 1)
    return psi


# ---------------------------------------------------------------------------
# jnp formulations
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("num_activities",))
def dfg_scatter(
    src: jax.Array, dst: jax.Array, valid: jax.Array, *, num_activities: int
) -> jax.Array:
    """Scatter-add formulation (CPU/GPU friendly)."""
    psi = jnp.zeros((num_activities, num_activities), dtype=jnp.int32)
    return psi.at[src, dst].add(valid.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("num_activities", "chunk"))
def dfg_onehot(
    src: jax.Array,
    dst: jax.Array,
    valid: jax.Array,
    *,
    num_activities: int,
    chunk: int = 4096,
) -> jax.Array:
    """MXU formulation: Ψ = Σ_chunks OneHot(src)ᵀ · (valid ⊙ OneHot(dst)).

    Chunked with ``lax.scan`` so the one-hot working set is
    ``2 · chunk · A`` instead of ``2 · E · A``.
    """
    n = src.shape[0]
    pad = (-n) % chunk
    if pad:
        src = jnp.pad(src, (0, pad))
        dst = jnp.pad(dst, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    n_chunks = (n + pad) // chunk
    src = src.reshape(n_chunks, chunk)
    dst = dst.reshape(n_chunks, chunk)
    valid = valid.reshape(n_chunks, chunk)

    def body(acc, xs):
        s, d, v = xs
        oh_s = jax.nn.one_hot(s, num_activities, dtype=jnp.float32)
        oh_d = jax.nn.one_hot(d, num_activities, dtype=jnp.float32)
        oh_s = oh_s * v.astype(jnp.float32)[:, None]
        acc = acc + jnp.dot(
            oh_s.T, oh_d, preferred_element_type=jnp.float32
        )
        return acc, None

    init = jnp.zeros((num_activities, num_activities), dtype=jnp.float32)
    acc, _ = jax.lax.scan(body, init, (src, dst, valid))
    return acc.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def dfg(
    src,
    dst,
    valid,
    num_activities: int,
    backend: str = "auto",
) -> np.ndarray:
    """Compute the DFG count matrix from aligned pair columns.

    backend ∈ {"auto", "scatter", "onehot", "pallas"}.  "auto" picks
    scatter on CPU and the Pallas kernel elsewhere.
    """
    src = jnp.asarray(src, dtype=jnp.int32)
    dst = jnp.asarray(dst, dtype=jnp.int32)
    valid = jnp.asarray(valid).astype(jnp.bool_)
    if backend == "auto":
        backend = "scatter" if jax.default_backend() == "cpu" else "pallas"
    if backend == "scatter":
        out = dfg_scatter(src, dst, valid, num_activities=num_activities)
    elif backend == "onehot":
        out = dfg_onehot(src, dst, valid, num_activities=num_activities)
    elif backend == "pallas":
        from repro.kernels.dfg_count import ops as _ops

        out = _ops.dfg_count(src, dst, valid, num_activities=num_activities)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return np.asarray(out, dtype=np.int64)


def dfg_from_repository(
    repo: EventRepository,
    *,
    backend: str = "auto",
    time_window: Optional[Tuple[float, float]] = None,
    view=None,
) -> np.ndarray:
    """The paper's §4 query: MATCH (a1)<-[]-(e1)-[]->(e2)-[]->(a2) count(*),
    optionally with a WHERE timestamp clause (``time_window``) and an
    access-control ``view`` (see :mod:`repro.core.views`).

    Paper semantics for dicing: the E×E relation is *fixed*; a pair counts
    iff **both** endpoint events satisfy the WHERE clause.  (pm4py-style
    re-linking after filtering is available via
    :func:`repro.core.dicing.dice_repository`.)
    """
    src, dst, valid = repo.df_pairs()
    if time_window is not None:
        from .dicing import pair_mask_for_window

        valid = valid & pair_mask_for_window(repo, time_window)
    psi = dfg(src, dst, valid, repo.num_activities, backend=backend)
    if view is not None:
        psi = view.apply_to_dfg(psi, repo.activity_names)
    return psi
