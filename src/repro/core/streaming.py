"""Out-of-core / streaming DFG — reproduces the paper's Claim C1.

The graph-database property the paper exploits is that DFG computation runs
*where the data lives* with bounded working memory (Neo4j pages through its
store; the analyst's RAM never needs to hold the log).  Our two-tier store:

* **Device tier** — `EventRepository` columns sharded into pod HBM
  (see :mod:`repro.core.distributed`).
* **Host tier** — :class:`MemmapLog`, a disk-resident columnar log
  (`np.memmap` per column + a per-chunk time index).  The streaming miner
  scans it chunk-by-chunk with **O(A² + chunk + open-cases)** peak memory —
  independent of log size, which is the paper's "data much bigger than
  computational memory" scenario.

The per-chunk time index gives the paper's Experiment-2 win: a time dice
reads only the touched byte range instead of loading the full log.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from .dfg import dfg_numpy

__all__ = ["MemmapLog", "StreamingDFGMiner", "streaming_dfg"]


# ---------------------------------------------------------------------------
# Disk-resident columnar log
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MemmapLog:
    """Disk-backed event log: three aligned columns + metadata + time index.

    The stream is **time-ordered** (the natural order logs are recorded in);
    traces interleave.  ``chunk_time_index`` holds (start_row, min_t, max_t)
    per fixed-size chunk so time dices map to row ranges via binary search.
    """

    path: str
    num_events: int
    num_activities: int
    num_traces: int
    chunk_rows: int

    def __post_init__(self):
        self.activity = np.memmap(
            os.path.join(self.path, "activity.i32"),
            dtype=np.int32, mode="r", shape=(self.num_events,),
        )
        self.case = np.memmap(
            os.path.join(self.path, "case.i32"),
            dtype=np.int32, mode="r", shape=(self.num_events,),
        )
        self.time = np.memmap(
            os.path.join(self.path, "time.f64"),
            dtype=np.float64, mode="r", shape=(self.num_events,),
        )

    # -- writer -------------------------------------------------------------
    @staticmethod
    def create(
        path: str,
        num_events: int,
        num_activities: int,
        num_traces: int,
        chunk_rows: int = 1 << 20,
    ) -> "MemmapLogWriter":
        return MemmapLogWriter(path, num_events, num_activities, num_traces, chunk_rows)

    @staticmethod
    def open(path: str) -> "MemmapLog":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return MemmapLog(
            path=path,
            num_events=meta["num_events"],
            num_activities=meta["num_activities"],
            num_traces=meta["num_traces"],
            chunk_rows=meta["chunk_rows"],
        )

    # -- reading ------------------------------------------------------------
    def iter_chunks(
        self,
        chunk_rows: Optional[int] = None,
        row_range: Optional[Tuple[int, int]] = None,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        cr = chunk_rows or self.chunk_rows
        lo, hi = row_range if row_range else (0, self.num_events)
        for start in range(lo, hi, cr):
            end = min(start + cr, hi)
            yield (
                np.asarray(self.activity[start:end]),
                np.asarray(self.case[start:end]),
                np.asarray(self.time[start:end]),
            )

    def rows_for_window(self, t0: float, t1: float) -> Tuple[int, int]:
        """Binary search the time column (stream is time-ordered) — this is
        the index-based dicing that beats load-everything below the
        crossover (paper Fig. 5)."""
        lo = int(np.searchsorted(self.time, t0, side="left"))
        hi = int(np.searchsorted(self.time, t1, side="left"))
        return lo, hi


class MemmapLogWriter:
    def __init__(self, path, num_events, num_activities, num_traces, chunk_rows):
        os.makedirs(path, exist_ok=True)
        self.path = path
        self.meta = dict(
            num_events=num_events,
            num_activities=num_activities,
            num_traces=num_traces,
            chunk_rows=chunk_rows,
        )
        self.activity = np.memmap(
            os.path.join(path, "activity.i32"), dtype=np.int32, mode="w+",
            shape=(num_events,),
        )
        self.case = np.memmap(
            os.path.join(path, "case.i32"), dtype=np.int32, mode="w+",
            shape=(num_events,),
        )
        self.time = np.memmap(
            os.path.join(path, "time.f64"), dtype=np.float64, mode="w+",
            shape=(num_events,),
        )
        self.cursor = 0

    def append(self, activity: np.ndarray, case: np.ndarray, time: np.ndarray):
        n = activity.shape[0]
        s = self.cursor
        self.activity[s : s + n] = activity
        self.case[s : s + n] = case
        self.time[s : s + n] = time
        self.cursor += n

    def close(self) -> MemmapLog:
        assert self.cursor == self.meta["num_events"], (
            f"wrote {self.cursor} of {self.meta['num_events']} rows"
        )
        self.activity.flush()
        self.case.flush()
        self.time.flush()
        with open(os.path.join(self.path, "meta.json"), "w") as f:
            json.dump(self.meta, f)
        del self.activity, self.case, self.time
        return MemmapLog.open(self.path)


# ---------------------------------------------------------------------------
# Streaming miner
# ---------------------------------------------------------------------------


class StreamingDFGMiner:
    """Incremental DFG over a time-ordered event stream with interleaved
    traces.  State: the (A, A) count matrix + one (activity, time) per *open*
    case.  Peak memory is O(A² + chunk + open cases) — never O(E).

    Also serves as the **incremental maintenance** path: feeding a live
    event stream keeps the DFG current (beyond-paper capability).
    """

    def __init__(self, num_activities: int):
        self.num_activities = num_activities
        self.psi = np.zeros((num_activities, num_activities), dtype=np.int64)
        self.last_by_case: Dict[int, int] = {}
        self.events_seen = 0

    def update(
        self, activity: np.ndarray, case: np.ndarray, time: np.ndarray
    ) -> None:
        """Consume one chunk (time-ordered rows; traces may interleave)."""
        n = activity.shape[0]
        if n == 0:
            return
        self.events_seen += int(n)
        # Within the chunk, group rows by case via a stable (case, time) sort.
        order = np.lexsort((np.arange(n), time, case))
        a = activity[order]
        c = case[order]
        same = np.zeros(n, dtype=bool)
        same[1:] = c[1:] == c[:-1]
        # in-chunk pairs
        src = a[:-1][same[1:]]
        dst = a[1:][same[1:]]
        if src.size:
            np.add.at(self.psi, (src, dst), 1)
        # cross-chunk pairs: first row of each case-run links to carried state
        run_start = ~same
        for i in np.nonzero(run_start)[0]:
            prev = self.last_by_case.get(int(c[i]))
            if prev is not None:
                self.psi[prev, a[i]] += 1
        # carry last event of each case-run
        run_end = np.ones(n, dtype=bool)
        run_end[:-1] = ~same[1:]
        for i in np.nonzero(run_end)[0]:
            self.last_by_case[int(c[i])] = int(a[i])

    def finalize(self) -> np.ndarray:
        return self.psi.copy()


def streaming_dfg(
    log: MemmapLog,
    chunk_rows: Optional[int] = None,
    time_window: Optional[Tuple[float, float]] = None,
) -> np.ndarray:
    """End-to-end out-of-core DFG over a memmap log.

    With a ``time_window`` the scan touches only the indexed row range
    (plus per-pair endpoint masking at the range edges for paper
    semantics — for a time-ordered stream the range *is* the window)."""
    miner = StreamingDFGMiner(log.num_activities)
    rng = log.rows_for_window(*time_window) if time_window else None
    for a, c, t in log.iter_chunks(chunk_rows=chunk_rows, row_range=rng):
        miner.update(a, c, t)
    return miner.finalize()
