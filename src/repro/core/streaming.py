"""Out-of-core / streaming DFG — reproduces the paper's Claim C1.

The graph-database property the paper exploits is that DFG computation runs
*where the data lives* with bounded working memory (Neo4j pages through its
store; the analyst's RAM never needs to hold the log).  Our two-tier store:

* **Device tier** — `EventRepository` columns sharded into pod HBM
  (see :mod:`repro.core.distributed`).
* **Host tier** — :class:`MemmapLog`, a disk-resident columnar log
  (`np.memmap` per column + a per-chunk time index).  The streaming miner
  scans it chunk-by-chunk with **O(A² + chunk + open-cases)** peak memory —
  independent of log size, which is the paper's "data much bigger than
  computational memory" scenario.

The per-chunk time index gives the paper's Experiment-2 win: a time dice
reads only the touched byte range instead of loading the full log.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from .dfg import dfg_numpy

__all__ = [
    "MemmapLog",
    "MemmapLogWriter",
    "MinerState",
    "StreamingDFGMiner",
    "memmap_log_name",
    "streaming_dfg",
]


def memmap_log_name(log: "MemmapLog") -> str:
    """The log name a memmap source contributes to provenance columns and
    auto-derived union branch names: the final path component (the name the
    log was created under).  One shared rule — the query layers must agree
    on it or branch names and materialized ``log_names`` drift apart."""
    base = os.path.basename(os.path.normpath(log.path))
    return base or "memmap"


# ---------------------------------------------------------------------------
# Disk-resident columnar log
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MemmapLog:
    """Disk-backed event log: three aligned columns + metadata + time index.

    The stream is **time-ordered** (the natural order logs are recorded in);
    traces interleave.  ``chunk_time_index`` holds (start_row, min_t, max_t)
    per fixed-size chunk so time dices map to row ranges via binary search.
    """

    path: str
    num_events: int
    num_activities: int
    num_traces: int
    chunk_rows: int

    def __post_init__(self):
        self.activity = np.memmap(
            os.path.join(self.path, "activity.i32"),
            dtype=np.int32, mode="r", shape=(self.num_events,),
        )
        self.case = np.memmap(
            os.path.join(self.path, "case.i32"),
            dtype=np.int32, mode="r", shape=(self.num_events,),
        )
        self.time = np.memmap(
            os.path.join(self.path, "time.f64"),
            dtype=np.float64, mode="r", shape=(self.num_events,),
        )

    # -- writer -------------------------------------------------------------
    @staticmethod
    def create(
        path: str,
        num_events: int,
        num_activities: int,
        num_traces: int,
        chunk_rows: int = 1 << 20,
    ) -> "MemmapLogWriter":
        return MemmapLogWriter(path, num_events, num_activities, num_traces, chunk_rows)

    @staticmethod
    def open(path: str) -> "MemmapLog":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return MemmapLog(
            path=path,
            num_events=meta["num_events"],
            num_activities=meta["num_activities"],
            num_traces=meta["num_traces"],
            chunk_rows=meta["chunk_rows"],
        )

    # -- reading ------------------------------------------------------------
    def iter_chunks(
        self,
        chunk_rows: Optional[int] = None,
        row_range: Optional[Tuple[int, int]] = None,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        cr = chunk_rows or self.chunk_rows
        lo, hi = row_range if row_range else (0, self.num_events)
        for start in range(lo, hi, cr):
            end = min(start + cr, hi)
            yield (
                np.asarray(self.activity[start:end]),
                np.asarray(self.case[start:end]),
                np.asarray(self.time[start:end]),
            )

    def activity_labels(self) -> list:
        """Synthetic names for the integer activity ids — the same labels the
        mining CLI and the query engine use, so memmap branches align with
        in-memory repositories on a shared activity axis."""
        return [f"act_{i:03d}" for i in range(self.num_activities)]

    def rows_for_window(self, t0: float, t1: float) -> Tuple[int, int]:
        """Binary search the time column (stream is time-ordered) — this is
        the index-based dicing that beats load-everything below the
        crossover (paper Fig. 5)."""
        lo = int(np.searchsorted(self.time, t0, side="left"))
        hi = int(np.searchsorted(self.time, t1, side="left"))
        return lo, hi

    # -- growing ------------------------------------------------------------
    def append(
        self, activity: np.ndarray, case: np.ndarray, time: np.ndarray
    ) -> "MemmapLog":
        """Grow this log on disk by one time-ordered batch and return a
        freshly opened handle.  This instance keeps viewing the old row
        count — reopen (or use the returned log) to see the appended rows."""
        w = MemmapLogWriter.open_append(self.path)
        w.append(activity, case, time)
        return w.close()


class MemmapLogWriter:
    """Writes the disk tier.  Two modes:

    * **create** (constructor) — preallocates the three column files for a
      known ``num_events`` and fills them front to back;
    * **append** (:meth:`open_append`) — grows an *existing* log's column
      files and rewrites ``meta.json`` on close.  Appended rows must keep
      the stream time-ordered (nondecreasing, starting at or after the last
      stored timestamp): the chunk time index and the engine's append-only
      delta plans both rely on that invariant.
    """

    def __init__(self, path, num_events, num_activities, num_traces, chunk_rows):
        os.makedirs(path, exist_ok=True)
        self.path = path
        self.mode = "create"
        self.meta = dict(
            num_events=num_events,
            num_activities=num_activities,
            num_traces=num_traces,
            chunk_rows=chunk_rows,
        )
        self.activity = np.memmap(
            os.path.join(path, "activity.i32"), dtype=np.int32, mode="w+",
            shape=(num_events,),
        )
        self.case = np.memmap(
            os.path.join(path, "case.i32"), dtype=np.int32, mode="w+",
            shape=(num_events,),
        )
        self.time = np.memmap(
            os.path.join(path, "time.f64"), dtype=np.float64, mode="w+",
            shape=(num_events,),
        )
        self.cursor = 0

    @classmethod
    def open_append(cls, path: str) -> "MemmapLogWriter":
        """Open an existing log for append-only growth.

        New activity / case ids may exceed the stored vocabularies —
        ``num_activities`` / ``num_traces`` grow accordingly on close.
        """
        w = object.__new__(cls)
        w.path = path
        with open(os.path.join(path, "meta.json")) as f:
            w.meta = json.load(f)
        w.mode = "append"
        w.cursor = w.meta["num_events"]
        # an aborted earlier append (writer discarded before close, e.g. on
        # a time-order error) leaves orphan bytes past the committed row
        # count; truncate them or they would silently misalign this append
        for name, itemsize in (("activity.i32", 4), ("case.i32", 4),
                               ("time.f64", 8)):
            fpath = os.path.join(path, name)
            committed = w.cursor * itemsize
            if os.path.getsize(fpath) > committed:
                os.truncate(fpath, committed)
        w._files = {
            "activity": open(os.path.join(path, "activity.i32"), "ab"),
            "case": open(os.path.join(path, "case.i32"), "ab"),
            "time": open(os.path.join(path, "time.f64"), "ab"),
        }
        w._max_activity = w.meta["num_activities"] - 1
        w._max_case = w.meta["num_traces"] - 1
        n = w.meta["num_events"]
        if n:
            tail = np.memmap(
                os.path.join(path, "time.f64"), dtype=np.float64, mode="r",
                shape=(n,),
            )
            w._last_time = float(tail[-1])
            del tail
        else:
            w._last_time = -np.inf
        return w

    def append(self, activity: np.ndarray, case: np.ndarray, time: np.ndarray):
        activity = np.ascontiguousarray(activity, dtype=np.int32)
        case = np.ascontiguousarray(case, dtype=np.int32)
        time = np.ascontiguousarray(time, dtype=np.float64)
        n = activity.shape[0]
        if n == 0:
            return
        if self.mode == "append":
            if float(time[0]) < self._last_time or (np.diff(time) < 0).any():
                raise ValueError(
                    "appended rows must keep the stream time-ordered: "
                    f"batch starts at {float(time[0])} but the log ends at "
                    f"{self._last_time}"
                )
            self._files["activity"].write(activity.tobytes())
            self._files["case"].write(case.tobytes())
            self._files["time"].write(time.tobytes())
            self._last_time = float(time[-1])
            self._max_activity = max(self._max_activity, int(activity.max()))
            self._max_case = max(self._max_case, int(case.max()))
            self.cursor += n
            return
        s = self.cursor
        self.activity[s : s + n] = activity
        self.case[s : s + n] = case
        self.time[s : s + n] = time
        self.cursor += n

    def close(self) -> MemmapLog:
        if self.mode == "append":
            for f in self._files.values():
                f.flush()
                f.close()
            self.meta["num_events"] = self.cursor
            self.meta["num_activities"] = max(
                self.meta["num_activities"], self._max_activity + 1
            )
            self.meta["num_traces"] = max(
                self.meta["num_traces"], self._max_case + 1
            )
            with open(os.path.join(self.path, "meta.json"), "w") as f:
                json.dump(self.meta, f)
            return MemmapLog.open(self.path)
        assert self.cursor == self.meta["num_events"], (
            f"wrote {self.cursor} of {self.meta['num_events']} rows"
        )
        self.activity.flush()
        self.case.flush()
        self.time.flush()
        with open(os.path.join(self.path, "meta.json"), "w") as f:
            json.dump(self.meta, f)
        del self.activity, self.case, self.time
        return MemmapLog.open(self.path)


# ---------------------------------------------------------------------------
# Streaming miner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MinerState:
    """Resumable snapshot of a :class:`StreamingDFGMiner` — everything the
    incremental-maintenance path needs to continue a scan later: the Ψ
    counts, the last activity per open case (so pairs straddling the resume
    boundary are linked), and the consumed-row count."""

    psi: np.ndarray
    last_by_case: Dict[int, int]
    events_seen: int

    @property
    def num_activities(self) -> int:
        return int(self.psi.shape[0])

    def copy(self) -> "MinerState":
        return MinerState(self.psi.copy(), dict(self.last_by_case), self.events_seen)


class StreamingDFGMiner:
    """Incremental DFG over a time-ordered event stream with interleaved
    traces.  State: the (A, A) count matrix + one (activity, time) per *open*
    case.  Peak memory is O(A² + chunk + open cases) — never O(E).

    Also serves as the **incremental maintenance** path: feeding a live
    event stream keeps the DFG current (beyond-paper capability).
    """

    def __init__(self, num_activities: int):
        self.num_activities = num_activities
        self.psi = np.zeros((num_activities, num_activities), dtype=np.int64)
        self.last_by_case: Dict[int, int] = {}
        self.events_seen = 0

    def snapshot(self) -> MinerState:
        """Copy out the resumable state (safe to cache across appends)."""
        return MinerState(self.psi.copy(), dict(self.last_by_case), self.events_seen)

    @classmethod
    def restore(
        cls, state: MinerState, num_activities: Optional[int] = None
    ) -> "StreamingDFGMiner":
        """Resume from a snapshot.  A grown activity vocabulary pads Ψ with
        zero rows/columns; shrinking is not an append and is rejected."""
        a = state.num_activities if num_activities is None else int(num_activities)
        if a < state.num_activities:
            raise ValueError(
                f"cannot shrink the vocabulary on resume "
                f"({state.num_activities} -> {a})"
            )
        miner = cls(a)
        miner.psi[: state.num_activities, : state.num_activities] = state.psi
        miner.last_by_case = dict(state.last_by_case)
        miner.events_seen = int(state.events_seen)
        return miner

    def update(
        self, activity: np.ndarray, case: np.ndarray, time: np.ndarray
    ) -> None:
        """Consume one chunk (time-ordered rows; traces may interleave)."""
        n = activity.shape[0]
        if n == 0:
            return
        self.events_seen += int(n)
        # Within the chunk, group rows by case via a stable (case, time) sort.
        order = np.lexsort((np.arange(n), time, case))
        a = activity[order]
        c = case[order]
        same = np.zeros(n, dtype=bool)
        same[1:] = c[1:] == c[:-1]
        # in-chunk pairs
        src = a[:-1][same[1:]]
        dst = a[1:][same[1:]]
        if src.size:
            np.add.at(self.psi, (src, dst), 1)
        # cross-chunk pairs: first row of each case-run links to carried state
        run_start = ~same
        for i in np.nonzero(run_start)[0]:
            prev = self.last_by_case.get(int(c[i]))
            if prev is not None:
                self.psi[prev, a[i]] += 1
        # carry last event of each case-run
        run_end = np.ones(n, dtype=bool)
        run_end[:-1] = ~same[1:]
        for i in np.nonzero(run_end)[0]:
            self.last_by_case[int(c[i])] = int(a[i])

    def finalize(self) -> np.ndarray:
        return self.psi.copy()


def streaming_dfg(
    log: MemmapLog,
    chunk_rows: Optional[int] = None,
    time_window: Optional[Tuple[float, float]] = None,
) -> np.ndarray:
    """End-to-end out-of-core DFG over a memmap log.

    With a ``time_window`` the scan touches only the indexed row range
    (plus per-pair endpoint masking at the range edges for paper
    semantics — for a time-ordered stream the range *is* the window)."""
    miner = StreamingDFGMiner(log.num_activities)
    rng = log.rows_for_window(*time_window) if time_window else None
    for a, c, t in log.iter_chunks(chunk_rows=chunk_rows, row_range=rng):
        miner.update(a, c, t)
    return miner.finalize()
