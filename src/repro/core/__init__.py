"""GraphPM core — the paper's primary contribution in JAX.

Event repositories (Definition 1), soundness (Definition 2), Algorithm 1
(DFG) in scatter / one-hot-MXU / Pallas formulations, dicing, access-control
views, distributed (shard_map) and streaming (out-of-core) execution,
DFG-based discovery, and runtime telemetry mining.
"""

from .repository import (
    EventRepository,
    GraphRepo,
    concat_repositories,
    paper_example_repo,
)
from .soundness import SoundnessReport, check_columnar, check_graph, is_sound
from .dfg import (
    dfg,
    dfg_algorithm1,
    dfg_from_repository,
    dfg_numpy,
    dfg_onehot,
    dfg_scatter,
)
from .dicing import (
    dice_repository,
    event_mask_for_activities,
    event_mask_for_window,
    pair_mask_for_window,
)
from .views import HIDDEN, AccessPolicy, ActivityView, AnalystSession
from .discovery import (
    DiscoveredModel,
    dependency_matrix,
    discover_dependency_graph,
    filter_dfg,
    footprint,
    footprint_conformance,
    to_dot,
)
from .baseline import InMemoryDFGBaseline, dfg_from_rows
from .streaming import (
    MemmapLog,
    MemmapLogWriter,
    MinerState,
    StreamingDFGMiner,
    streaming_dfg,
)
from .distributed import distributed_dfg, lower_distributed_dfg, shard_pairs
from .telemetry import EventCollector, StepTimer
from .variants import TraceVariants, trace_variants, variant_filtered_repository
from .conformance import ReplayResult, replay_fitness

__all__ = [
    "EventRepository", "GraphRepo", "concat_repositories",
    "paper_example_repo",
    "SoundnessReport", "check_columnar", "check_graph", "is_sound",
    "dfg", "dfg_algorithm1", "dfg_from_repository", "dfg_numpy",
    "dfg_onehot", "dfg_scatter",
    "dice_repository", "event_mask_for_activities", "event_mask_for_window",
    "pair_mask_for_window",
    "HIDDEN", "AccessPolicy", "ActivityView", "AnalystSession",
    "DiscoveredModel", "dependency_matrix", "discover_dependency_graph",
    "filter_dfg", "footprint", "footprint_conformance", "to_dot",
    "InMemoryDFGBaseline", "dfg_from_rows",
    "MemmapLog", "MemmapLogWriter", "MinerState", "StreamingDFGMiner",
    "streaming_dfg",
    "distributed_dfg", "lower_distributed_dfg", "shard_pairs",
    "EventCollector", "StepTimer",
    "TraceVariants", "trace_variants", "variant_filtered_repository",
    "ReplayResult", "replay_fitness",
]
