"""JAX version-compat shims.

The repo targets the current ``jax.shard_map`` / ``jax.sharding.AxisType``
API but must also run on older jaxlibs (0.4.x) where:

* ``jax.sharding.AxisType`` does not exist and ``jax.make_mesh`` takes no
  ``axis_types`` keyword;
* ``shard_map`` lives in ``jax.experimental.shard_map`` with
  ``check_rep=``/``auto=`` instead of ``check_vma=``/``axis_names=``.

Everything mesh- or shard_map-shaped in the codebase goes through these two
helpers so the drift is handled in exactly one place.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import numpy as np

__all__ = ["make_mesh", "shard_map", "cost_analysis"]

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence] = None,
):
    """``jax.make_mesh`` with all axes Auto, on any supported jax version."""
    if devices is None:
        devices = jax.devices()[: math.prod(axis_shapes)]
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(axis_shapes),
            tuple(axis_names),
            devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    try:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names), devices=devices
        )
    except (AttributeError, TypeError):
        return jax.sharding.Mesh(
            np.asarray(devices).reshape(tuple(axis_shapes)), tuple(axis_names)
        )


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict on every jax version (pre-0.5
    returns a one-element list of per-device dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check=False):
    """Uniform shard_map front-end.

    ``axis_names`` (new-API meaning): the mesh axes the body is *manual*
    over; remaining axes stay auto (partial-auto shard_map).  ``None`` means
    manual over every axis.  ``check`` maps to ``check_vma`` (new) /
    ``check_rep`` (old).
    """
    if _HAS_JAX_SHARD_MAP:
        kw = dict(
            mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = dict(
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, **kw)
