"""pm4py-style in-memory baseline — the approach the paper compares against.

Mirrors the algorithmic shape of ``pm4py.algo.discovery.dfg``: parse the
*entire* log into per-case event lists in memory, sort each case by
timestamp, then count adjacent pairs with a dict.  Deliberately
load-everything-first (that is the point of the comparison: it fails when
the log exceeds memory, and it wins on full-log in-memory scans).
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

__all__ = ["InMemoryDFGBaseline", "dfg_from_rows"]


class LogTooLargeError(MemoryError):
    """Raised when the configured memory budget would be exceeded while
    loading (models the pm4py container OOM in the paper's Experiment 1)."""


class InMemoryDFGBaseline:
    def __init__(self, memory_budget_bytes: Optional[int] = None):
        self.memory_budget_bytes = memory_budget_bytes

    def load(
        self, rows: Iterable[Tuple[int, int, float]]
    ) -> Dict[int, list]:
        """rows: iterable of (case_id, activity_id, timestamp).

        Loads everything into a per-case dict of event lists — the
        in-memory representation whose size is what the paper's Fig. 4
        varies RAM against."""
        cases: Dict[int, list] = defaultdict(list)
        approx = 0
        for case, act, ts in rows:
            cases[case].append((ts, act))
            approx += 64  # tuple + list slot, ballpark python overhead
            if (
                self.memory_budget_bytes is not None
                and approx > self.memory_budget_bytes
            ):
                raise LogTooLargeError(
                    f"in-memory load exceeded budget "
                    f"({approx} > {self.memory_budget_bytes} bytes)"
                )
        return cases

    def dfg(
        self,
        rows: Iterable[Tuple[int, int, float]],
        num_activities: int,
        time_window: Optional[Tuple[float, float]] = None,
    ) -> np.ndarray:
        """Load-then-count.  ``time_window`` filters events *after* the full
        load (pm4py loads the XES first, filters second) — this asymmetry is
        exactly what Experiment 2 measures."""
        cases = self.load(rows)
        psi = np.zeros((num_activities, num_activities), dtype=np.int64)
        for evs in cases.values():
            evs.sort()
            if time_window is not None:
                t0, t1 = time_window
                evs = [e for e in evs if t0 <= e[0] < t1]
            for (t_a, a), (t_b, b) in zip(evs, evs[1:]):
                psi[a, b] += 1
        return psi


def dfg_from_rows(
    rows: Iterable[Tuple[int, int, float]], num_activities: int
) -> np.ndarray:
    return InMemoryDFGBaseline().dfg(rows, num_activities)
