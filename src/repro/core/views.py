"""Access-control views — the privacy mechanism of the paper (§2.2, §6.2).

In Neo4j the paper relies on fine-grained / sub-graph access control so that
analysts can compute the DFG without ever reading events or resources.  Here
the same guarantees are enforced structurally:

* :class:`ActivityView` — projection/coarsening of the attribute set: map
  each activity to a group label (the "postal-code level" example) or hide
  it.  Applied to a DFG matrix it aggregates rows/columns; applied before
  computation it relabels in-store.

* :class:`AccessPolicy` + :class:`AnalystSession` — capability wrapper: an
  analyst session holds the repository *opaque* and only exposes aggregate
  endpoints (DFG, activity histogram, trace-length stats).  Raw columns are
  unreachable through the session object, mirroring "grant access to traverse
  relations but not see node properties".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .repository import EventRepository

__all__ = ["ActivityView", "AccessPolicy", "AnalystSession", "HIDDEN"]

HIDDEN = "<hidden>"


@dataclasses.dataclass
class ActivityView:
    """Maps raw activity names to visible group labels (or HIDDEN)."""

    mapping: Dict[str, str]
    default: str = HIDDEN  # unmapped activities collapse here

    def visible_labels(self, activity_names: Sequence[str]) -> List[str]:
        labels = []
        for a in activity_names:
            g = self.mapping.get(a, self.default)
            if g not in labels:
                labels.append(g)
        return labels

    def group_matrix(self, activity_names: Sequence[str]) -> Tuple[np.ndarray, List[str]]:
        """One-hot (A, G) grouping matrix and the group label list."""
        labels = self.visible_labels(activity_names)
        gidx = {g: i for i, g in enumerate(labels)}
        m = np.zeros((len(activity_names), len(labels)), dtype=np.int64)
        for i, a in enumerate(activity_names):
            m[i, gidx[self.mapping.get(a, self.default)]] = 1
        return m, labels

    def apply_to_dfg(
        self, psi: np.ndarray, activity_names: Sequence[str]
    ) -> np.ndarray:
        """Ψ_view = Gᵀ Ψ G — aggregate counts at the group level.

        HIDDEN groups are removed from the result entirely (their flows are
        not exposed, matching sub-graph access control)."""
        g, labels = self.group_matrix(activity_names)
        out = g.T @ psi @ g
        keep = [i for i, l in enumerate(labels) if l != HIDDEN]
        return out[np.ix_(keep, keep)]

    def visible_names(self, activity_names: Sequence[str]) -> List[str]:
        return [l for l in self.visible_labels(activity_names) if l != HIDDEN]


@dataclasses.dataclass
class AccessPolicy:
    """What an analyst may see.  ``aggregate_only=True`` is the paper's
    headline guarantee: DFG out, raw events never."""

    aggregate_only: bool = True
    view: Optional[ActivityView] = None
    time_windows_allowed: bool = True  # may the analyst dice by time?
    min_group_count: int = 0  # optional k-anonymity floor on reported counts


class AccessDenied(PermissionError):
    pass


class AnalystSession:
    """Capability-style handle: all queries run *in-store* (device-side when
    distributed) and only aggregates cross the boundary.

    Aggregate endpoints compile to :mod:`repro.query` plans, so analyst
    traffic gets the engine's predicate pushdown and the shared plan/result
    cache (repeated dashboard queries are O(1))."""

    def __init__(self, repo: EventRepository, policy: AccessPolicy, engine=None):
        self.__repo = repo  # name-mangled: not reachable as a public attr
        self.policy = policy
        self.__engine = engine

    def __query(self):
        from repro.query import Q

        q = Q.log(self.__repo)
        if self.__engine is not None:
            q = q.using(self.__engine)
        if self.policy.view is not None:
            q = q.view(self.policy.view)
        return q

    # -- aggregate endpoints -------------------------------------------------
    def dfg(
        self,
        time_window: Optional[Tuple[float, float]] = None,
        backend: str = "auto",
    ) -> Tuple[np.ndarray, List[str]]:
        if time_window is not None and not self.policy.time_windows_allowed:
            raise AccessDenied("time dicing not permitted by policy")
        q = self.__query()
        if time_window is not None:
            q = q.window(*time_window)  # commutes with the view in the plan
        res = q.dfg(backend=backend)
        psi, names = res.value, list(res.names)
        if self.policy.min_group_count:
            psi = np.where(psi >= self.policy.min_group_count, psi, 0)
        return psi, names

    def activity_histogram(self) -> Tuple[np.ndarray, List[str]]:
        res = self.__query().histogram()
        return res.value, list(res.names)

    def trace_length_stats(self) -> Dict[str, float]:
        lens = np.bincount(self.__repo.event_trace, minlength=self.__repo.num_traces)
        return {
            "num_traces": float(self.__repo.num_traces),
            "num_events": float(self.__repo.num_events),
            "mean": float(lens.mean()) if lens.size else 0.0,
            "max": float(lens.max()) if lens.size else 0.0,
        }

    # -- raw access is denied --------------------------------------------------
    def events(self):
        if self.policy.aggregate_only:
            raise AccessDenied("policy is aggregate-only: raw events are not exposed")
        return (
            self.__repo.event_activity.copy(),
            self.__repo.event_trace.copy(),
            self.__repo.event_time.copy(),
        )
