"""Persisted trace store — a bounded on-disk JSONL ring of finished traces.

Completed, *sampled* traces are appended as one JSON line each to a ring
of rotating segment files (``trace-<n>.jsonl``), so the store is bounded
(``max_bytes`` across ``segments`` files) no matter how long the process
serves.  Sampling is **tail-based**: the keep/drop decision happens when
the trace is complete, so the store always keeps

* traces that errored,
* traces slower than the SLO latency bound (when one is configured),

and head-samples the rest (every ``sample_every``-th sampled trace) —
the boring fast majority is decimated, the traces worth debugging never
are.  A trace whose context carries ``sampled=False`` (inbound
traceparent flag) is only kept when the tail rules fire.

The persisted records carry the distributed-trace identity (trace id /
span id / parent) plus every span with its **absolute** monotonic start
stamp, so :meth:`to_repository` can read the whole ring back as a
canonical event log — each trace node one case, each span one event —
and ``Q.log(store.to_repository()).dfg()`` mines the serving tier's own
cross-process traces with the same Algorithm 1 the engine runs on user
logs.

Lock discipline (``repro-analysis`` lock rule): the store lock only
guards byte/sequence accounting and the file-handle swap — every
``open``/``os.remove``/write happens *outside* it.  Concurrent writers
share one buffered text handle; a single ``fh.write(line)`` of a whole
line is atomic under CPython's buffered-writer lock, so lines never
interleave.
"""

from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, Iterator, List, Optional

from repro.analysis.lockdep import make_lock

from .trace import QueryTrace

__all__ = ["TraceStore"]

_SEGMENT_FMT = "trace-{:06d}.jsonl"
_SEGMENT_GLOB = "trace-*.jsonl"


def _trace_record(tr: QueryTrace, error: bool) -> Dict[str, object]:
    """The persisted JSON shape of one finished trace (branches nested)."""
    names, t0s, durs = tr.raw_spans()
    rec: Dict[str, object] = {
        "trace_id": tr.trace_id,
        "span_id": tr.span_id,
        "parent_span_id": tr.parent_span_id,
        "sampled": tr.sampled,
        "query_id": tr.query_id,
        "sink": tr.sink,
        "source": tr.source,
        "backend": tr.executed_backend,
        "from_cache": tr.from_cache,
        "total_s": tr.total_s,
        "error": bool(error),
        "spans": [
            {"name": n, "t0": t, "duration_s": max(d, 0.0)}
            for n, t, d in zip(names, t0s, durs)
        ],
    }
    if tr.links:
        rec["links"] = dict(tr.links)
    if tr.notes:
        rec["notes"] = {
            k: v for k, v in tr.notes.items()
            if isinstance(v, (str, int, float, bool))
        }
    if tr.branches:
        rec["branches"] = [
            dict(_trace_record(sub, False), branch=name)
            for name, sub in tr.branches
        ]
    return rec


class TraceStore:
    """Bounded JSONL ring of completed traces with tail-based sampling."""

    def __init__(
        self,
        path: str,
        *,
        max_bytes: int = 16 * 1024 * 1024,
        segments: int = 4,
        sample_every: int = 1,
        slo_latency_s: Optional[float] = None,
        metrics=None,
        now=time.time,
    ):
        self.path = str(path)
        os.makedirs(self.path, exist_ok=True)
        self.segments = max(int(segments), 2)
        self.segment_bytes = max(int(max_bytes) // self.segments, 4096)
        self.sample_every = max(int(sample_every), 1)
        self.slo_latency_s = slo_latency_s
        self._now = now
        existing = sorted(glob.glob(os.path.join(self.path, _SEGMENT_GLOB)))
        seq = 0
        if existing:
            tail = os.path.basename(existing[-1])
            seq = int(tail[len("trace-"):-len(".jsonl")])
        fh = open(self._segment_path(seq), "a", encoding="utf-8")
        self._lock = make_lock("TraceStore")
        self._fh = fh                      # guarded by _lock (swap only)
        self._seq = seq                    # guarded by _lock
        self._bytes = fh.tell()            # guarded by _lock
        self._rotating = False             # guarded by _lock
        self._head_seen = 0                # guarded by _lock
        self._kept = 0                     # guarded by _lock
        if metrics is not None:
            self._c_offered = metrics.counter(
                "trace_store_offered_total",
                "Finished traces offered to the persisted trace store",
            )
            self._c_kept = {
                reason: metrics.counter(
                    "trace_store_kept_total",
                    "Traces persisted, by tail-sampling keep reason",
                    reason=reason,
                )
                for reason in ("error", "slow", "sampled")
            }
        else:
            self._c_offered = None
            self._c_kept = None

    def _segment_path(self, seq: int) -> str:
        return os.path.join(self.path, _SEGMENT_FMT.format(seq))

    # -- write side -------------------------------------------------------

    def _keep_reason(self, tr: QueryTrace, error: bool) -> Optional[str]:
        """Tail-based sampling decision; None = drop."""
        if error:
            return "error"
        if (
            self.slo_latency_s is not None
            and tr.total_s >= self.slo_latency_s
        ):
            return "slow"
        if not tr.sampled:
            return None
        with self._lock:
            self._head_seen += 1
            nth = self._head_seen
        return "sampled" if nth % self.sample_every == 0 else None

    def offer(self, tr: QueryTrace, error: bool = False) -> bool:
        """Offer one finished trace; returns True when persisted."""
        if self._c_offered is not None:
            self._c_offered.inc()
        reason = self._keep_reason(tr, error)
        if reason is None:
            return False
        line = json.dumps(_trace_record(tr, error), sort_keys=True) + "\n"
        with self._lock:
            fh = self._fh
            self._bytes += len(line)
            self._kept += 1
            rotate = (
                self._bytes >= self.segment_bytes and not self._rotating
            )
            if rotate:
                self._rotating = True
        fh.write(line)
        if rotate:
            self._rotate()
        if self._c_kept is not None:
            self._c_kept[reason].inc()
        return True

    def _rotate(self) -> None:
        """Swap in the next segment and prune the ring; all file I/O runs
        with no lock held (the single in-flight rotation is serialized by
        the ``_rotating`` flag)."""
        with self._lock:
            seq = self._seq + 1
        new_fh = open(self._segment_path(seq), "a", encoding="utf-8")
        with self._lock:
            old = self._fh
            self._fh = new_fh
            self._seq = seq
            self._bytes = 0
            self._rotating = False
        old.close()
        paths = sorted(glob.glob(os.path.join(self.path, _SEGMENT_GLOB)))
        for p in paths[:-self.segments]:
            try:
                os.remove(p)
            except OSError:  # pragma: no cover - concurrent external prune
                pass

    def __len__(self) -> int:
        """Traces persisted over this store's lifetime (not just resident
        in the ring)."""
        with self._lock:
            return self._kept

    def close(self) -> None:
        with self._lock:
            fh = self._fh
        fh.close()

    # -- read side --------------------------------------------------------

    def read_records(self) -> Iterator[Dict[str, object]]:
        """Iterate every resident trace record, oldest segment first."""
        with self._lock:
            fh = self._fh
        try:
            fh.flush()
        except ValueError:  # store closed: the ring on disk stays readable
            pass
        for p in sorted(glob.glob(os.path.join(self.path, _SEGMENT_GLOB))):
            try:
                with open(p, encoding="utf-8") as f:
                    for line in f:
                        line = line.strip()
                        if not line:
                            continue
                        try:
                            yield json.loads(line)
                        except json.JSONDecodeError:
                            continue  # torn tail line of a live segment
            except OSError:  # pragma: no cover - segment pruned mid-read
                continue

    def find(self, trace_id: str) -> List[Dict[str, object]]:
        """Every resident record belonging to ``trace_id`` — across
        processes that share the ring directory this is the full stitched
        request tree."""
        return [
            rec for rec in self.read_records()
            if rec.get("trace_id") == trace_id
        ]

    def to_repository(self):
        """The resident ring as a canonical event log: one case per trace
        node (``trace_id:span_id``), one event per span, ordered by the
        spans' absolute monotonic stamps — ready for ``Q.log(...)``."""
        from repro.core.repository import EventRepository

        cases: List[str] = []
        acts: List[str] = []
        times: List[float] = []

        def walk(rec: Dict[str, object]) -> None:
            tid = rec.get("trace_id")
            sid = rec.get("span_id")
            case = (
                f"{tid}:{sid}" if tid and sid else f"q{rec.get('query_id')}"
            )
            for span in rec.get("spans", ()):
                cases.append(case)
                acts.append(str(span["name"]))
                times.append(float(span["t0"]))
            for sub in rec.get("branches", ()):
                walk(sub)

        for rec in self.read_records():
            walk(rec)
        return EventRepository.from_event_table(cases, acts, times)
