"""Lock-protected counters and streaming histograms for the engine.

Design constraints (mirrors the engine's always-on tracing budget):

* **One lock per registry.**  Counters and histograms share their
  registry's lock, so moving ``EngineStats`` increments behind it also
  fixes the bare-``int`` data races the old dataclass had under
  concurrent ``run()`` calls.
* **No sample retention.**  Histograms bin observations into fixed
  log-scale buckets (4 per decade, 1 µs … 100 s) and estimate
  p50/p95/p99 by interpolating the cumulative bucket counts — memory is
  O(buckets) forever, independent of query volume.
* **Three export formats.**  ``to_dict`` (programmatic snapshots,
  optionally floored for multi-tenant serving), ``to_json_lines`` (one
  JSON object per metric, log-shipper friendly), ``to_prometheus``
  (text exposition format, ``*_bucket``/``*_sum``/``*_count`` series).

A module-global :func:`kernel_registry` is kept separate from per-engine
registries: Pallas kernels are process-wide jitted callables, so their
wall-times aggregate across every engine in the process.
"""

from __future__ import annotations

import json
import math
import threading
from bisect import bisect_left
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.analysis.lockdep import make_lock

__all__ = [
    "Counter",
    "Histogram",
    "MetricsRegistry",
    "kernel_registry",
    "prometheus_text",
]

# Fixed log-scale bucket upper bounds: 4 per decade from 1e-6 to 1e2
# (1 µs … 100 s), overflow bucket above.  Fractions (cache-hit ratio,
# delta suffix fraction) land in the same grid — it spans [0, 1] densely.
BUCKET_BOUNDS: Tuple[float, ...] = tuple(
    10.0 ** (k / 4.0) for k in range(-24, 9)
)

LabelItems = Tuple[Tuple[str, str], ...]


def _label_suffix(labels: LabelItems) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


def _prom_labels(labels: LabelItems, extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in labels]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class Counter:
    """Monotonic counter; ``inc`` takes the owning registry's lock."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: LabelItems, lock: threading.Lock):
        self.name = name
        self.labels = labels
        self._lock = lock
        self._value = 0

    def inc(self, n: int = 1) -> int:
        """Increment and return the new value (the engine uses the
        ``engine_queries_total`` counter as its query-id sequence)."""
        with self._lock:
            self._value += n
            return self._value

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


#: an exemplar older than this many subsequent observations is replaced
#: even by a smaller value — "worst recent", not "worst ever"
EXEMPLAR_REFRESH = 4096


class Histogram:
    """Streaming histogram over fixed log-scale buckets.

    ``observe`` is a bisect + three adds under the registry lock; no
    sample is retained.  Percentiles interpolate linearly inside the
    winning bucket and clamp to the observed ``[min, max]`` envelope.

    When an observation carries a ``trace_id``, the bucket keeps the
    (trace id, value) of its worst recent observation as an **exemplar**
    — a p99 spike in the exposition then links directly to the trace that
    caused it.  Memory stays O(buckets): one exemplar per bucket,
    refreshed after :data:`EXEMPLAR_REFRESH` further observations so a
    one-off ancient worst case cannot pin the slot forever.
    """

    __slots__ = (
        "name", "labels", "_lock", "_counts", "_count", "_sum",
        "_min", "_max", "_exemplars",
    )

    def __init__(self, name: str, labels: LabelItems, lock: threading.Lock):
        self.name = name
        self.labels = labels
        self._lock = lock
        self._counts = [0] * (len(BUCKET_BOUNDS) + 1)  # +1 overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        # bucket index -> (trace_id, value, total count at store time)
        self._exemplars: Dict[int, Tuple[str, float, int]] = {}

    def observe(self, x: float, trace_id: Optional[str] = None) -> None:
        i = bisect_left(BUCKET_BOUNDS, x)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += x
            if x < self._min:
                self._min = x
            if x > self._max:
                self._max = x
            if trace_id is not None:
                ex = self._exemplars.get(i)
                if (
                    ex is None or x >= ex[1]
                    or self._count - ex[2] > EXEMPLAR_REFRESH
                ):
                    self._exemplars[i] = (trace_id, x, self._count)

    def exemplars(self) -> Dict[int, Tuple[str, float]]:
        """``{bucket index: (trace id, value)}`` — worst recent observation
        per occupied bucket (only buckets that ever saw a trace id)."""
        with self._lock:
            return {i: (t, v) for i, (t, v, _) in self._exemplars.items()}

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """Estimate the ``q``-th percentile (0–100) from bucket counts."""
        with self._lock:
            if self._count == 0:
                return 0.0
            target = (q / 100.0) * self._count
            cum = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                if cum + c >= target:
                    lo = BUCKET_BOUNDS[i - 1] if i > 0 else 0.0
                    hi = (
                        BUCKET_BOUNDS[i]
                        if i < len(BUCKET_BOUNDS)
                        else self._max
                    )
                    frac = (target - cum) / c
                    est = lo + (hi - lo) * frac
                    return min(max(est, self._min), self._max)
                cum += c
            return self._max

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            count, total = self._count, self._sum
            mn = self._min if count else 0.0
            mx = self._max if count else 0.0
        return {
            "count": count,
            "sum": total,
            "min": mn,
            "max": mx,
            "p50": self.percentile(50.0),
            "p95": self.percentile(95.0),
            "p99": self.percentile(99.0),
        }

    def bucket_counts(self) -> List[int]:
        with self._lock:
            return list(self._counts)


class MetricsRegistry:
    """Get-or-create registry of counters, histograms, and gauges.

    All child metrics share the registry lock.  Gauges are callbacks
    evaluated at export time (e.g. telemetry ring-buffer drop counts),
    so they cost nothing between snapshots.
    """

    def __init__(self):
        self._lock = make_lock("MetricsRegistry")
        self._counters: Dict[Tuple[str, LabelItems], Counter] = {}
        self._histograms: Dict[Tuple[str, LabelItems], Histogram] = {}
        self._gauges: Dict[Tuple[str, LabelItems], Callable[[], float]] = {}
        self._help: Dict[str, str] = {}  # metric name -> # HELP text

    @staticmethod
    def _key(name: str, labels: Dict[str, str]) -> Tuple[str, LabelItems]:
        items = tuple(sorted((k, str(v)) for k, v in labels.items()))
        return (name, items)

    def counter(
        self, name: str, description: Optional[str] = None, **labels: str
    ) -> Counter:
        key = self._key(name, labels)
        with self._lock:
            if description:
                self._help.setdefault(name, description)
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter(name, key[1], self._lock)
        return c

    def histogram(
        self, name: str, description: Optional[str] = None, **labels: str
    ) -> Histogram:
        key = self._key(name, labels)
        with self._lock:
            if description:
                self._help.setdefault(name, description)
            h = self._histograms.get(key)
            if h is None:
                h = self._histograms[key] = Histogram(name, key[1], self._lock)
        return h

    def gauge(
        self,
        name: str,
        fn: Callable[[], float],
        description: Optional[str] = None,
        **labels: str,
    ) -> None:
        key = self._key(name, labels)
        with self._lock:
            if description:
                self._help.setdefault(name, description)
            self._gauges[key] = fn

    def describe(self, name: str, description: str) -> None:
        """Attach (or replace) the ``# HELP`` text of a metric name."""
        with self._lock:
            self._help[name] = description

    # -- matching (SLO objectives) ----------------------------------------

    @staticmethod
    def _matches(labels: LabelItems, want: Dict[str, str]) -> bool:
        have = dict(labels)
        return all(have.get(k) == str(v) for k, v in want.items())

    def find_histograms(self, name: str, **labels: str) -> List[Histogram]:
        """Every histogram series named ``name`` whose labels are a
        superset of ``labels`` (empty ``labels`` matches all series)."""
        with self._lock:
            items = list(self._histograms.items())
        return [
            h for (n, li), h in items
            if n == name and self._matches(li, labels)
        ]

    def find_counters(self, name: str, **labels: str) -> List[Counter]:
        """Every counter series named ``name`` whose labels are a superset
        of ``labels``."""
        with self._lock:
            items = list(self._counters.items())
        return [
            c for (n, li), c in items
            if n == name and self._matches(li, labels)
        ]

    # -- export -----------------------------------------------------------

    def _items(self):
        with self._lock:
            counters = list(self._counters.items())
            hists = list(self._histograms.items())
            gauges = list(self._gauges.items())
        return counters, hists, gauges

    def _help_snapshot(self) -> Dict[str, str]:
        with self._lock:
            return dict(self._help)

    def to_dict(self, floor: int = 0) -> Dict[str, object]:
        """Flat snapshot ``{"name{k=v}": value-or-summary}``.

        ``floor`` is the k-anonymity floor applied to multi-tenant
        snapshots: counts below it are reported as 0 (histogram
        summaries are fully zeroed so sums can't leak small counts).
        """
        out: Dict[str, object] = {}
        counters, hists, gauges = self._items()
        for (name, labels), c in counters:
            v = c.value
            out[name + _label_suffix(labels)] = v if v >= floor else 0
        for (name, labels), h in hists:
            snap = h.snapshot()
            if snap["count"] < floor:
                snap = {k: 0 if k == "count" else 0.0 for k in snap}
            else:
                # exemplars name individual traces; a floored (multi-tenant)
                # snapshot must not carry them
                ex = h.exemplars() if floor == 0 else {}
                if ex:
                    snap = dict(snap)
                    snap["exemplars"] = [
                        {
                            "le": (
                                BUCKET_BOUNDS[i]
                                if i < len(BUCKET_BOUNDS) else math.inf
                            ),
                            "trace_id": t,
                            "value": v,
                        }
                        for i, (t, v) in sorted(ex.items())
                    ]
            out[name + _label_suffix(labels)] = snap
        for (name, labels), fn in gauges:
            v = fn()
            out[name + _label_suffix(labels)] = v if v >= floor else 0
        return out

    def to_json_lines(self) -> str:
        lines = []
        counters, hists, gauges = self._items()
        for (name, labels), c in counters:
            lines.append(json.dumps({
                "name": name, "labels": dict(labels),
                "type": "counter", "value": c.value,
            }, sort_keys=True))
        for (name, labels), h in hists:
            rec = {"name": name, "labels": dict(labels),
                   "type": "histogram"}
            rec.update(h.snapshot())
            lines.append(json.dumps(rec, sort_keys=True))
        for (name, labels), fn in gauges:
            lines.append(json.dumps({
                "name": name, "labels": dict(labels),
                "type": "gauge", "value": fn(),
            }, sort_keys=True))
        return "\n".join(lines)

    def to_prometheus(self) -> str:
        lines: List[str] = []
        counters, hists, gauges = self._items()
        help_text = self._help_snapshot()
        seen_type = set()

        def _head(name: str, kind: str) -> None:
            if name in seen_type:
                return
            seen_type.add(name)
            desc = help_text.get(name)
            if desc:
                desc = desc.replace("\\", "\\\\").replace("\n", "\\n")
                lines.append(f"# HELP {name} {desc}")
            lines.append(f"# TYPE {name} {kind}")

        for (name, labels), c in counters:
            _head(name, "counter")
            lines.append(f"{name}{_prom_labels(labels)} {c.value}")
        for (name, labels), h in hists:
            _head(name, "histogram")
            counts = h.bucket_counts()
            exemplars = h.exemplars()
            cum = 0
            for i, (bound, c) in enumerate(zip(BUCKET_BOUNDS, counts[:-1])):
                cum += c
                if c == 0:
                    continue  # sparse: emit only occupied buckets (+Inf)
                le = _prom_labels(labels, f'le="{bound:.6g}"')
                row = f"{name}_bucket{le} {cum}"
                ex = exemplars.get(i)
                if ex is not None:
                    # OpenMetrics exemplar syntax: links the bucket to the
                    # worst recent trace that landed in it
                    row += f' # {{trace_id="{ex[0]}"}} {ex[1]:.9g}'
                lines.append(row)
            cum += counts[-1]
            le = _prom_labels(labels, 'le="+Inf"')
            row = f"{name}_bucket{le} {cum}"
            ex = exemplars.get(len(BUCKET_BOUNDS))
            if ex is not None:
                row += f' # {{trace_id="{ex[0]}"}} {ex[1]:.9g}'
            lines.append(row)
            lines.append(f"{name}_sum{_prom_labels(labels)} {h.sum:.9g}")
            lines.append(f"{name}_count{_prom_labels(labels)} {h.count}")
        for (name, labels), fn in gauges:
            _head(name, "gauge")
            lines.append(f"{name}{_prom_labels(labels)} {fn()}")
        return "\n".join(lines) + "\n"


def prometheus_text(*registries: MetricsRegistry) -> str:
    """Concatenate the Prometheus exposition of several registries
    (e.g. an engine registry plus the process-wide kernel registry)."""
    return "".join(r.to_prometheus() for r in registries)


_KERNEL_REGISTRY = MetricsRegistry()


def kernel_registry() -> MetricsRegistry:
    """Process-global registry for Pallas kernel wall-times
    (``kernel_seconds{kernel=...}`` histograms, one per entry point)."""
    return _KERNEL_REGISTRY
