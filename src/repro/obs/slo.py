"""Declarative SLOs with burn-rate alerting over the live metrics.

An :class:`Objective` states a promise in user terms — "99% of warm-lane
requests under 25 ms", "99.9% of requests served" — against metric series
already in a :class:`~repro.obs.metrics.MetricsRegistry`; nothing new is
instrumented.  The :class:`SLOEngine` turns the registry's cumulative
counters/histogram buckets into:

* a **verdict** per objective (latency objectives also report the
  measured percentile, so the engine reproduces exactly the p99-under-
  threshold check the serving benchmark asserts),
* **error-budget accounting** — the fraction of the allowed bad events
  not yet spent,
* **multi-window burn rates** — the classic SRE construction: the rate
  at which the budget is being consumed, measured over a short and a
  long window simultaneously via snapshot deltas (:meth:`SLOEngine.tick`
  records the snapshots); an alert fires only when *every* window burns
  faster than the objective's threshold, which keeps one latency spike
  from paging while still catching sustained budget exhaustion fast.

Latency objectives count "good" events from the histogram's cumulative
log-scale buckets (linear interpolation inside the bucket straddling the
threshold — same estimator the percentiles use).  Availability objectives
count good/bad from two counter selections.  A selection is (metric name
+ label subset) and sums every matching series, so ``lane="hot"`` or an
unlabelled total both work.

Lock discipline: the engine's own lock only guards the snapshot ring;
registry metrics are always read *before* it is taken, so there is no
SLOEngine ↔ MetricsRegistry ordering cycle under ``REPRO_LOCKDEP=1``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.analysis.lockdep import make_lock

from .metrics import BUCKET_BOUNDS, MetricsRegistry

__all__ = ["Objective", "SLOEngine", "default_service_objectives"]


@dataclasses.dataclass(frozen=True)
class Objective:
    """One service-level objective over existing metric series."""

    name: str
    #: "latency" (histogram + per-event threshold) or "availability"
    #: (good/bad counter pair)
    kind: str
    #: promised fraction of good events, e.g. 0.99 / 0.999
    target: float
    #: histogram name (latency) or good-event counter name (availability)
    metric: str
    #: label subset selecting the series to sum (empty = all series)
    labels: Tuple[Tuple[str, str], ...] = ()
    #: latency objectives: an event is good iff it finished under this
    threshold_s: Optional[float] = None
    #: reported percentile for the latency verdict (p<percentile> must be
    #: under ``threshold_s``)
    percentile: float = 99.0
    #: availability objectives: counter of bad events
    bad_metric: Optional[str] = None
    bad_labels: Tuple[Tuple[str, str], ...] = ()
    #: alert when every window burns the budget faster than this multiple
    #: of the sustainable rate (14.4 ≈ "2% of a 30-day budget in 1 hour")
    burn_alert: float = 14.4

    def __post_init__(self):
        if self.kind not in ("latency", "availability"):
            raise ValueError(f"unknown objective kind {self.kind!r}")
        if self.kind == "latency" and self.threshold_s is None:
            raise ValueError(f"latency objective {self.name!r} needs threshold_s")
        if self.kind == "availability" and self.bad_metric is None:
            raise ValueError(
                f"availability objective {self.name!r} needs bad_metric"
            )
        if not 0.0 < self.target < 1.0:
            raise ValueError(f"target must be in (0, 1), got {self.target}")


def default_service_objectives() -> List[Objective]:
    """The serving tier's stock objectives, matching the load-test
    assertions in ``benchmarks/bench_serve.py``: warm-lane p99 under
    25 ms, and 99.9% of requests admitted (not shed)."""
    return [
        Objective(
            name="warm_latency",
            kind="latency",
            target=0.99,
            metric="request_latency_seconds",
            labels=(("lane", "hot"),),
            threshold_s=0.025,
            percentile=99.0,
        ),
        Objective(
            name="availability",
            kind="availability",
            target=0.999,
            metric="transport_requests_total",
            bad_metric="transport_shed_total",
        ),
    ]


def _sum_bucket_counts(hists) -> Tuple[List[int], int, float, float]:
    """Element-wise sum of several histograms' buckets plus the combined
    count and [min, max] envelope."""
    counts = [0] * (len(BUCKET_BOUNDS) + 1)
    total = 0
    mn, mx = math.inf, -math.inf
    for h in hists:
        for i, c in enumerate(h.bucket_counts()):
            counts[i] += c
        snap = h.snapshot()
        total += snap["count"]
        if snap["count"]:
            mn = min(mn, snap["min"])
            mx = max(mx, snap["max"])
    if total == 0:
        mn = mx = 0.0
    return counts, total, mn, mx


def _percentile(
    counts: List[int], total: int, mn: float, mx: float, q: float
) -> float:
    """Percentile estimate over summed log-scale buckets (same
    interpolation as :meth:`Histogram.percentile`)."""
    if total == 0:
        return 0.0
    target = (q / 100.0) * total
    cum = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if cum + c >= target:
            lo = BUCKET_BOUNDS[i - 1] if i > 0 else 0.0
            hi = BUCKET_BOUNDS[i] if i < len(BUCKET_BOUNDS) else mx
            est = lo + (hi - lo) * (target - cum) / c
            return min(max(est, mn), mx)
        cum += c
    return mx


def _good_below(counts: List[int], threshold: float) -> float:
    """Events at or under ``threshold`` from cumulative bucket counts,
    interpolating linearly inside the straddling bucket."""
    good = 0.0
    prev = 0.0
    for i, bound in enumerate(BUCKET_BOUNDS):
        c = counts[i]
        if bound <= threshold:
            good += c
            prev = bound
            continue
        if threshold > prev and bound > prev:
            good += c * (threshold - prev) / (bound - prev)
        break
    return good


class SLOEngine:
    """Evaluate :class:`Objective`s over one or more registries.

    :meth:`tick` records a (time, per-objective good/total) snapshot into
    a bounded ring; :meth:`evaluate` reports verdicts, budgets, and the
    per-window burn rates computed from snapshot deltas.  ``now`` is
    injectable for deterministic tests.
    """

    def __init__(
        self,
        *registries: MetricsRegistry,
        objectives: Optional[List[Objective]] = None,
        windows_s: Tuple[float, ...] = (300.0, 3600.0),
        now=time.monotonic,
        max_snapshots: int = 512,
    ):
        if not registries:
            raise ValueError("SLOEngine needs at least one MetricsRegistry")
        self.registries = registries
        self.objectives = (
            list(objectives)
            if objectives is not None
            else default_service_objectives()
        )
        names = [o.name for o in self.objectives]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate objective names: {names}")
        self.windows_s = tuple(float(w) for w in windows_s)
        self._now = now
        # ring of (t, {objective: (good, total)}) — guarded by _lock
        self._snaps = deque(maxlen=max_snapshots)
        self._lock = make_lock("SLOEngine")

    # -- measurement (registry reads happen with no SLO lock held) ---------

    def _series(self, kind: str, name: str, labels) -> list:
        out = []
        want = dict(labels)
        for reg in self.registries:
            if kind == "histogram":
                out.extend(reg.find_histograms(name, **want))
            else:
                out.extend(reg.find_counters(name, **want))
        return out

    def _measure(self, obj: Objective) -> Dict[str, float]:
        """Cumulative good/total (+ measured percentile for latency)."""
        if obj.kind == "latency":
            hists = self._series("histogram", obj.metric, obj.labels)
            counts, total, mn, mx = _sum_bucket_counts(hists)
            good = _good_below(counts, obj.threshold_s)
            measured = _percentile(counts, total, mn, mx, obj.percentile)
            return {"good": good, "total": float(total), "measured": measured}
        good = float(sum(
            c.value for c in self._series("counter", obj.metric, obj.labels)
        ))
        bad = float(sum(
            c.value
            for c in self._series("counter", obj.bad_metric, obj.bad_labels)
        ))
        return {"good": good, "total": good + bad, "measured": None}

    def tick(self, now: Optional[float] = None) -> None:
        """Record one burn-rate snapshot (call periodically, or rely on
        :meth:`evaluate`'s implicit tick)."""
        t = self._now() if now is None else now
        counts = {}
        for obj in self.objectives:
            m = self._measure(obj)
            counts[obj.name] = (m["good"], m["total"])
        with self._lock:
            self._snaps.append((t, counts))

    def _burn_rates(
        self, obj: Objective, t: float, good: float, total: float,
        snaps,
    ) -> Dict[str, Optional[float]]:
        """Budget burn per window from snapshot deltas: (bad fraction over
        the window) / (allowed bad fraction).  1.0 = exactly sustainable;
        None = no snapshot old enough to span the window yet."""
        budget = 1.0 - obj.target
        out: Dict[str, Optional[float]] = {}
        for w in self.windows_s:
            base = None
            for ts, counts in snaps:  # oldest-first: last one ≤ t-w wins
                if ts <= t - w and obj.name in counts:
                    base = counts[obj.name]
                elif ts > t - w:
                    break
            key = f"{w:g}s"
            if base is None:
                # not enough history: fall back to the oldest snapshot so a
                # young process still reports a (cumulative) burn signal
                base = next(
                    (c[obj.name] for _, c in snaps if obj.name in c), None
                )
            if base is None:
                out[key] = None
                continue
            d_good = good - base[0]
            d_total = total - base[1]
            if d_total <= 0:
                out[key] = 0.0
                continue
            bad_frac = max(d_total - d_good, 0.0) / d_total
            out[key] = bad_frac / budget
        return out

    def evaluate(
        self, now: Optional[float] = None, *, tick: bool = True,
        floor: int = 0,
    ) -> Dict[str, object]:
        """Verdicts + budgets + burn rates, JSON-shaped.

        ``floor`` is the serving tier's k-anonymity floor: objectives with
        fewer than ``floor`` total events report zeroed counts and a None
        verdict (event counts must not leak below the floor)."""
        if tick:
            self.tick(now)
        t = self._now() if now is None else now
        with self._lock:
            snaps = list(self._snaps)
        objectives = []
        alerts = []
        for obj in self.objectives:
            m = self._measure(obj)
            good, total = m["good"], m["total"]
            if total < floor:
                objectives.append({
                    "name": obj.name, "kind": obj.kind, "target": obj.target,
                    "threshold_s": obj.threshold_s, "ok": None,
                    "total": 0, "good": 0, "bad": 0, "good_ratio": None,
                    "measured": None, "error_budget_remaining": None,
                    "burn_rates": {f"{w:g}s": None for w in self.windows_s},
                    "alert": False,
                })
                continue
            bad = max(total - good, 0.0)
            ratio = good / total if total else None
            if total == 0:
                ok = None
            elif obj.kind == "latency":
                ok = bool(m["measured"] <= obj.threshold_s)
            else:
                ok = bool(ratio >= obj.target)
            budget = 1.0 - obj.target
            budget_left = (
                1.0 - (bad / total) / budget if total else None
            )
            burns = self._burn_rates(obj, t, good, total, snaps)
            rates = [b for b in burns.values() if b is not None]
            alert = bool(rates) and all(b > obj.burn_alert for b in rates)
            if alert:
                alerts.append(obj.name)
            objectives.append({
                "name": obj.name,
                "kind": obj.kind,
                "target": obj.target,
                "threshold_s": obj.threshold_s,
                "percentile": obj.percentile if obj.kind == "latency" else None,
                "measured": m["measured"],
                "ok": ok,
                "total": int(total),
                "good": round(good, 3),
                "bad": round(bad, 3),
                "good_ratio": ratio,
                "error_budget_remaining": budget_left,
                "burn_rates": burns,
                "alert": alert,
            })
        return {
            "sink": "slo",
            "windows_s": list(self.windows_s),
            "objectives": objectives,
            "alerts": alerts,
            "ok": all(o["ok"] is not False for o in objectives),
        }
