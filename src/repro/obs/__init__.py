"""``repro.obs`` — observability for the process-query engine.

Three pieces, all dependency-free (stdlib + numpy) so every engine tier can
import them without cycles:

* :mod:`repro.obs.trace` — :class:`QueryTrace`, a per-query execution trace
  of timed spans (parse → cache-probe → plan → scan/resume → merge → sink)
  attached to every :class:`repro.query.QueryResult` as ``result.trace``.
  Always-on and near-zero overhead: preallocated span slabs, raw
  ``perf_counter`` reads, no string formatting on the hot path.
* :mod:`repro.obs.metrics` — a lock-protected :class:`MetricsRegistry` of
  counters and streaming histograms (p50/p95/p99 from fixed log-scale
  buckets, no sample retention), exported as a dict, JSON lines, or
  Prometheus text.  A module-global :func:`kernel_registry` collects Pallas
  kernel wall-times via :mod:`repro.kernels.timing`.
* Self-mining forensics — the engine batches every finished trace into a
  :class:`repro.core.telemetry.EventCollector`, so
  ``Q.log(engine.own_telemetry())`` mines the engine's own process with the
  engine itself (the paper's Algorithm 1 over the engine's spans).
* :mod:`repro.obs.context` — W3C-traceparent-style :class:`TraceContext`
  propagated from the transport tier through coalescing, scheduler lanes,
  and into every engine (and per-shard) :class:`QueryTrace`, so one trace
  id stitches the full distributed request tree.
* :mod:`repro.obs.slo` — declarative :class:`Objective`s evaluated over
  live registries by :class:`SLOEngine`: verdicts, error budgets, and
  multi-window burn-rate alerts (``{"sink": "slo"}`` / ``GET /slo``).
* :mod:`repro.obs.store` — :class:`TraceStore`, a bounded on-disk JSONL
  ring of tail-sampled finished traces, readable back as an event log so
  cross-process traces mine bit-identically to Algorithm 1.
"""

from .context import TraceContext, mint_context, new_span_id, parse_traceparent
from .metrics import (
    Counter,
    Histogram,
    MetricsRegistry,
    kernel_registry,
    prometheus_text,
)
from .slo import Objective, SLOEngine, default_service_objectives
from .store import TraceStore
from .trace import Span, QueryTrace

__all__ = [
    "Counter",
    "Histogram",
    "MetricsRegistry",
    "Objective",
    "SLOEngine",
    "TraceContext",
    "TraceStore",
    "default_service_objectives",
    "kernel_registry",
    "mint_context",
    "new_span_id",
    "parse_traceparent",
    "prometheus_text",
    "Span",
    "QueryTrace",
]
