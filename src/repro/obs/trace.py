"""Per-query execution traces.

A :class:`QueryTrace` is its own recorder: three preallocated parallel
slabs (name / start / duration) grown by doubling, written with nothing
but ``perf_counter`` reads and list stores.  The engine opens spans with
``begin`` (returns a slot index) and closes them with ``end`` — no
context-manager allocation, no string formatting, no dict churn on the
hot path.  Everything derived (span objects, coverage, dicts, pretty
text) is computed lazily at read time.

Span vocabulary used by the engine (a query's trace is a chain, so the
engine's own process mines as a DFG — see ``QueryEngine.own_telemetry``):

``parse`` → ``cache_probe`` → [``delta``] → ``plan`` → ``scan`` |
``merge`` → ``sink``
"""

from __future__ import annotations

from time import perf_counter
from typing import Dict, List, NamedTuple, Optional, Tuple

from .context import TraceContext, new_span_id

__all__ = ["Span", "QueryTrace", "NullTrace"]

_SLAB = 8


class Span(NamedTuple):
    name: str
    start_s: float      # offset from trace start
    duration_s: float


class QueryTrace:
    """Timed spans plus planner/cache/graph disposition for one query."""

    #: class-level flag: NullTrace instances report False, letting the
    #: engine skip publishing (metrics / forensics) without isinstance
    enabled = True

    __slots__ = (
        "query_id", "sink", "source", "planned_backend",
        "executed_backend", "from_cache", "predicted_cost_s",
        "actual_cost_s", "rows_scanned", "delta_rows", "total_s",
        "branches", "drift", "notes",
        "trace_id", "span_id", "parent_span_id", "sampled", "links",
        "_t_start", "_names", "_t0", "_dur", "_n",
    )

    def __init__(self, query_id: int, sink: str, source: str):
        self.query_id = query_id
        self.sink = sink
        self.source = source
        self.planned_backend: Optional[str] = None
        self.executed_backend: Optional[str] = None
        self.from_cache = False
        self.predicted_cost_s: Optional[float] = None
        self.actual_cost_s: Optional[float] = None
        self.rows_scanned = 0
        self.delta_rows: Optional[Tuple[int, int]] = None
        self.total_s = 0.0
        self.branches: List[Tuple[str, "QueryTrace"]] = []
        self.drift: Optional[float] = None
        self.notes: Dict[str, object] = {}
        # distributed-trace identity: None until the engine/transport binds
        # a TraceContext (bind_root / bind_child_of); links are causal
        # references to *other* traces (coalesced_into, produced_by)
        self.trace_id: Optional[str] = None
        self.span_id: Optional[str] = None
        self.parent_span_id: Optional[str] = None
        self.sampled = True
        self.links: Dict[str, str] = {}
        self._names: List[Optional[str]] = [None] * _SLAB
        self._t0 = [0.0] * _SLAB
        self._dur = [0.0] * _SLAB
        self._n = 0
        self._t_start = perf_counter()

    # -- distributed identity ---------------------------------------------

    def bind_root(self, ctx: TraceContext) -> None:
        """Adopt ``ctx`` as this trace's own identity (the request root:
        this node *is* the context's span)."""
        self.trace_id = ctx.trace_id
        self.span_id = ctx.span_id
        self.parent_span_id = None
        self.sampled = ctx.sampled

    def bind_child_of(self, ctx: TraceContext) -> None:
        """Become a child of ``ctx``: same trace id, fresh span id,
        ``ctx``'s span recorded as parent."""
        self.trace_id = ctx.trace_id
        self.span_id = new_span_id()
        self.parent_span_id = ctx.span_id
        self.sampled = ctx.sampled

    @property
    def context(self) -> Optional[TraceContext]:
        """This trace's node as a propagatable context (None if unbound)."""
        if self.trace_id is None or self.span_id is None:
            return None
        return TraceContext(self.trace_id, self.span_id, self.sampled)

    # -- hot path ---------------------------------------------------------

    def begin(self, name: str) -> int:
        i = self._n
        if i == len(self._names):
            self._names.extend([None] * i)
            self._t0.extend([0.0] * i)
            self._dur.extend([0.0] * i)
        self._names[i] = name
        self._dur[i] = -1.0
        self._n = i + 1
        self._t0[i] = perf_counter()
        return i

    def end(self, idx: int) -> None:
        self._dur[idx] = perf_counter() - self._t0[idx]

    def add_span(self, name: str, t0: float, duration_s: float) -> int:
        """Record an externally-timed span (absolute ``perf_counter``
        start).  Used for intervals measured outside the trace's own
        begin/end pairing — e.g. the scheduler's queue wait, whose start
        stamp is taken on the event loop and whose end is observed on the
        worker thread that finally picks the request up."""
        i = self.begin(name)
        self._t0[i] = t0
        self._dur[i] = max(duration_s, 0.0)
        return i

    def finish(self) -> "QueryTrace":
        t = perf_counter()
        self.total_s = t - self._t_start
        for i in range(self._n):        # close spans orphaned by errors
            if self._dur[i] < 0.0:
                self._dur[i] = t - self._t0[i]
        return self

    # -- read side --------------------------------------------------------

    def raw_spans(self):
        """``(names, start_stamps, durations)`` slab slices for batch
        forensics recording — the stamps are absolute ``perf_counter``
        values, so cross-query ordering survives in the collector."""
        n = self._n
        return self._names[:n], self._t0[:n], self._dur[:n]

    @property
    def spans(self) -> List[Span]:
        t0 = self._t_start
        return [
            Span(self._names[i], self._t0[i] - t0, max(self._dur[i], 0.0))
            for i in range(self._n)
        ]

    def span_seconds(self, name: str) -> float:
        total = 0.0
        for i in range(self._n):
            if self._names[i] == name and self._dur[i] > 0.0:
                total += self._dur[i]
        return total

    def coverage(self) -> float:
        """Fraction of wall time covered by recorded spans (spans are
        sequential and non-overlapping, so a plain sum is exact)."""
        if self.total_s <= 0.0:
            return 1.0
        covered = sum(max(self._dur[i], 0.0) for i in range(self._n))
        return min(covered / self.total_s, 1.0)

    def add_branch(self, name: str, trace: "QueryTrace") -> None:
        self.branches.append((name, trace))

    def to_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {
            "query_id": self.query_id,
            "sink": self.sink,
            "source": self.source,
            "planned_backend": self.planned_backend,
            "executed_backend": self.executed_backend,
            "from_cache": self.from_cache,
            "predicted_cost_s": self.predicted_cost_s,
            "actual_cost_s": self.actual_cost_s,
            "rows_scanned": self.rows_scanned,
            "total_s": self.total_s,
            "coverage": self.coverage(),
            "spans": [
                {"name": s.name, "start_s": s.start_s,
                 "duration_s": s.duration_s}
                for s in self.spans
            ],
        }
        if self.trace_id is not None:
            d["trace_id"] = self.trace_id
            d["span_id"] = self.span_id
            d["sampled"] = self.sampled
            if self.parent_span_id is not None:
                d["parent_span_id"] = self.parent_span_id
        if self.links:
            d["links"] = dict(self.links)
        if self.delta_rows is not None:
            d["delta_rows"] = list(self.delta_rows)
        if self.drift is not None:
            d["drift"] = self.drift
        if self.notes:
            d["notes"] = dict(self.notes)
        if self.branches:
            d["branches"] = [
                {"name": n, "trace": t.to_dict()} for n, t in self.branches
            ]
        return d

    def describe(self, *, max_depth: int = 2) -> str:
        """Pretty text.  Branch sub-traces (union branches, sharded-graph
        ``shard<k>`` sub-queries) recurse up to ``max_depth`` levels with
        indentation; deeper levels collapse to one summary line each."""
        return "\n".join(self._describe_lines("", max_depth))

    def _describe_lines(self, indent: str, depth: int) -> List[str]:
        head = (
            f"{indent}trace q{self.query_id} sink={self.sink} "
            f"backend={self.executed_backend}"
        )
        if self.planned_backend and self.planned_backend != self.executed_backend:
            head += f" (planned={self.planned_backend})"
        lines = [
            head,
            f"{indent}  total={self.total_s * 1e3:.3f}ms "
            f"coverage={self.coverage() * 100.0:.1f}% "
            f"rows={self.rows_scanned}",
        ]
        for s in self.spans:
            lines.append(
                f"{indent}  {s.name:<12s} +{s.start_s * 1e3:8.3f}ms  "
                f"{s.duration_s * 1e3:8.3f}ms"
            )
        for name, sub in self.branches:
            lines.append(
                f"{indent}  branch {name}: backend={sub.executed_backend} "
                f"cache={sub.from_cache} rows={sub.rows_scanned} "
                f"total={sub.total_s * 1e3:.3f}ms"
            )
            if depth > 1:
                lines.extend(sub._describe_lines(indent + "    ", depth - 1))
        return lines

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"QueryTrace(q{self.query_id}, sink={self.sink!r}, "
            f"backend={self.executed_backend!r}, spans={self._n}, "
            f"total={self.total_s:.6f}s)"
        )


class NullTrace(QueryTrace):
    """Recorder used when the engine runs with ``trace=False`` (e.g. the
    overhead benchmark's baseline): span begin/end are no-ops and the
    engine publishes nothing.  Disposition attributes still accept writes,
    so the execution paths stay branch-free."""

    enabled = False

    def begin(self, name: str) -> int:
        return 0

    def end(self, idx: int) -> None:
        return None

    def add_span(self, name: str, t0: float, duration_s: float) -> int:
        return 0
