"""W3C-traceparent-style trace context for cross-process propagation.

A :class:`TraceContext` is the wire identity of one node in a request
tree: a 128-bit trace id shared by every span of the request, a 64-bit
span id naming this node, and the sampling bit.  The transport tier mints
one per inbound request (or adopts the caller's via the ``traceparent``
header), hands children to the engine through
:meth:`repro.query.QueryEngine.trace_scope`, and echoes the context back
in the response — so one id stitches HTTP → coalesce → lane → engine →
per-shard sub-traces across processes.

Id generation is deliberately cheap: a per-process random prefix plus an
atomic counter (``itertools.count().__next__`` is a single C call under
the GIL), not a syscall per query — the engine mints a context for every
root query, and that sits squarely inside the always-on tracing budget
measured by ``bench_obs.py``.

Header grammar (the W3C subset we speak)::

    traceparent: 00-<32 lowercase hex>-<16 lowercase hex>-<2 hex flags>

:func:`parse_traceparent` returns None for anything malformed — a bad
header must never fail the request; the transport just mints a fresh
context instead.
"""

from __future__ import annotations

import itertools
import os
from typing import NamedTuple, Optional

__all__ = [
    "TraceContext",
    "mint_context",
    "new_span_id",
    "parse_traceparent",
]

#: sampling flag bit of the traceparent trace-flags octet
FLAG_SAMPLED = 0x01

_HEX = set("0123456789abcdef")

# per-process random prefixes keep ids unique across serving replicas
# while the low 64 bits stay a cheap atomic counter
_TRACE_PREFIX = os.urandom(8).hex()
_SPAN_PREFIX = int.from_bytes(os.urandom(3), "big")
_SEQ = itertools.count(1).__next__


def new_span_id() -> str:
    """A fresh 16-hex-char span id (process prefix + atomic sequence)."""
    return f"{_SPAN_PREFIX:06x}{_SEQ() & 0xFFFFFFFFFF:010x}"


class TraceContext(NamedTuple):
    """One node of a distributed trace: (trace id, this node's span id,
    sampling decision).  Immutable — derive children, never mutate."""

    trace_id: str
    span_id: str
    sampled: bool = True

    def child(self) -> "TraceContext":
        """A child context: same trace, fresh span id, inherited sampling.
        The caller records ``self.span_id`` as the child's parent."""
        return TraceContext(self.trace_id, new_span_id(), self.sampled)

    def to_traceparent(self) -> str:
        flags = FLAG_SAMPLED if self.sampled else 0
        return f"00-{self.trace_id}-{self.span_id}-{flags:02x}"


def mint_context(sampled: bool = True) -> TraceContext:
    """A fresh root context (new trace id, new span id)."""
    trace_id = f"{_TRACE_PREFIX}{_SEQ() & 0xFFFFFFFFFFFFFFFF:016x}"
    return TraceContext(trace_id, new_span_id(), sampled)


def _is_hex(s: str) -> bool:
    return all(c in _HEX for c in s)


def parse_traceparent(header: Optional[str]) -> Optional[TraceContext]:
    """Parse an inbound ``traceparent`` header; None when malformed.

    Accepts the W3C shape ``version-traceid-spanid-flags`` with lowercase
    hex fields, rejects the all-zero ids and the invalid ``ff`` version.
    Unknown (non-``00``) versions parse leniently per spec as long as the
    four core fields are well-formed.
    """
    if not header or not isinstance(header, str):
        return None
    parts = header.strip().split("-")
    if len(parts) < 4:
        return None
    version, trace_id, span_id, flags = parts[0], parts[1], parts[2], parts[3]
    if len(version) != 2 or not _is_hex(version) or version == "ff":
        return None
    if len(trace_id) != 32 or not _is_hex(trace_id):
        return None
    if len(span_id) != 16 or not _is_hex(span_id):
        return None
    if len(flags) != 2 or not _is_hex(flags):
        return None
    if trace_id == "0" * 32 or span_id == "0" * 16:
        return None
    return TraceContext(
        trace_id, span_id, bool(int(flags, 16) & FLAG_SAMPLED)
    )
