"""The transport application: probe → admit → classify → coalesce → execute.

:class:`TransportApp` is the protocol-independent core of the serving
tier.  :class:`~repro.transport.server.TransportServer` parses HTTP and
hands request dicts here; tests drive this class directly so every
admission/coalescing/laning behaviour is assertable without sockets.

One request's life:

1. **Probe** — :meth:`QueryService.probe` resolves policy and canonical
   plan *without executing*; invalid requests map to 4xx before they cost
   a queue slot.
2. **Admit** — the tenant's token bucket; over-quota requests shed with
   429 + Retry-After.
3. **Classify** — hot iff the probe predicts a cache/delta serve or the
   planner's cost estimate is under the measured ``slo_hot_cutoff_s``
   boundary; otherwise cold.
4. **Coalesce** — an identical in-flight request (same policy, plan, and
   fingerprint-at-enqueue) means we await its future instead of executing.
5. **Execute** — the leader runs :meth:`QueryService.query` on its lane's
   thread pool (the engine is synchronous numpy/jax) and fans the result
   out.

Everything reports through the engine's own :class:`MetricsRegistry` —
queue-depth gauges, shed/coalesce counters, per-lane latency histograms —
so ``{"sink": "metrics"}`` already covers the transport tier.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, Optional, Tuple

from repro.core.views import AccessDenied
from repro.obs import QueryTrace, TraceStore
from repro.obs.context import mint_context, parse_traceparent
from repro.query import QueryPlanError
from repro.query.planner import load_calibration
from repro.serve import QueryService

from .admission import AdmissionController
from .coalesce import Coalescer
from .scheduler import TwoLaneScheduler

__all__ = [
    "TransportApp",
    "TransportConfig",
    "TransportResponse",
    "canonical_payload",
]

#: response fields that legitimately differ between a direct
#: ``QueryService.query`` call and a transport-served (possibly cached or
#: coalesced) execution of the same request
VOLATILE_FIELDS = ("wall_s", "from_cache", "backend", "trace", "trace_id")


def canonical_payload(payload: Dict) -> Dict:
    """The bit-identity view of a response: the payload minus execution
    provenance.  Transport guarantee: ``canonical_payload(transport) ==
    canonical_payload(service.query(request))`` for every request."""
    return {k: v for k, v in payload.items() if k not in VOLATILE_FIELDS}


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    rate: float = 200.0  # default per-tenant tokens/s
    burst: float = 400.0
    hot_workers: int = 4
    cold_workers: int = 2
    max_depth_hot: int = 256
    max_depth_cold: int = 32
    #: hot/cold boundary in seconds; None loads the measured value from
    #: BENCH_serve.json via load_calibration (static fallback inside)
    hot_cutoff_s: Optional[float] = None
    max_body_bytes: int = 8 * 1024 * 1024
    #: directory for the persisted trace ring (None = no trace store);
    #: the app shares the store with the engine so request traces and the
    #: engine executions under them land in the same ring
    trace_dir: Optional[str] = None
    trace_max_bytes: int = 16 * 1024 * 1024
    #: head-sample every Nth unremarkable trace; errors / sheds / over-SLO
    #: traces are always kept (tail-based sampling)
    trace_sample_every: int = 1
    #: latency above which a trace is always persisted; None loads the
    #: measured hot cutoff (a hot request slower than a cold scan is the
    #: one worth keeping)
    trace_slo_latency_s: Optional[float] = None


@dataclasses.dataclass
class TransportResponse:
    status: int
    payload: Dict
    headers: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return 200 <= self.status < 300


class TransportApp:
    def __init__(
        self,
        service: Optional[QueryService] = None,
        config: Optional[TransportConfig] = None,
    ):
        self.service = service or QueryService()
        self.config = config or TransportConfig()
        self.hot_cutoff_s = (
            float(self.config.hot_cutoff_s)
            if self.config.hot_cutoff_s is not None
            else float(load_calibration()["slo_hot_cutoff_s"])
        )
        metrics = self.service.engine.metrics
        self.metrics = metrics
        self.admission = AdmissionController(
            rate=self.config.rate, burst=self.config.burst
        )
        self.coalescer = Coalescer(metrics)
        self.scheduler = TwoLaneScheduler(
            metrics,
            hot_workers=self.config.hot_workers,
            cold_workers=self.config.cold_workers,
            max_depth_hot=self.config.max_depth_hot,
            max_depth_cold=self.config.max_depth_cold,
        )
        self._c_requests = {
            lane: metrics.counter(
                "transport_requests_total",
                "Requests served to completion, by lane",
                lane=lane,
            )
            for lane in ("hot", "cold")
        }
        self._c_shed = {
            reason: metrics.counter(
                "transport_shed_total",
                "Requests shed with 429, by cause",
                reason=reason,
            )
            for reason in ("quota", "queue")
        }
        self._h_latency = {
            lane: metrics.histogram(
                "request_latency_seconds",
                "End-to-end transport latency, by lane",
                lane=lane,
            )
            for lane in ("hot", "cold")
        }
        #: request-trace query-id sequence (separate from the engine's)
        self._rid = itertools.count(1).__next__
        self.trace_store: Optional[TraceStore] = None
        if self.config.trace_dir is not None:
            slo_s = (
                self.config.trace_slo_latency_s
                if self.config.trace_slo_latency_s is not None
                else self.hot_cutoff_s
            )
            self.trace_store = TraceStore(
                self.config.trace_dir,
                max_bytes=self.config.trace_max_bytes,
                sample_every=self.config.trace_sample_every,
                slo_latency_s=slo_s,
                metrics=metrics,
            )
            # one shared ring: engine roots (direct Q/engine use) and
            # transport request traces mine together
            self.service.engine.trace_store = self.trace_store

    # -- classification -------------------------------------------------------
    def classify(self, probe) -> str:
        """hot = predicted cache/delta/graph serve or a scan the planner
        prices under the measured boundary; cold = everything else."""
        if probe.cached or probe.delta_hint:
            return "hot"
        return "hot" if probe.estimated_cost_s <= self.hot_cutoff_s else "cold"

    # -- error mapping --------------------------------------------------------
    @staticmethod
    def _error_status(exc: BaseException) -> Optional[int]:
        if isinstance(exc, KeyError):
            return 404
        if isinstance(exc, AccessDenied):
            return 403
        if isinstance(exc, (QueryPlanError, ValueError, TypeError)):
            return 400
        return None

    @classmethod
    def _error_response(cls, exc: BaseException) -> TransportResponse:
        status = cls._error_status(exc)
        if status is None:
            raise exc
        detail = exc.args[0] if exc.args else str(exc)
        return TransportResponse(
            status, {"error": type(exc).__name__, "detail": str(detail)}
        )

    @staticmethod
    def _shed(retry_after_s: float) -> TransportResponse:
        retry = max(retry_after_s, 0.001)
        return TransportResponse(
            429,
            {"error": "Shed", "retry_after_s": retry},
            headers={"Retry-After": f"{retry:.3f}"},
        )

    # -- request traces -------------------------------------------------------
    def _begin_request_trace(
        self, request: Dict, traceparent: Optional[str]
    ) -> QueryTrace:
        """Mint this request's trace node.  A well-formed inbound
        ``traceparent`` makes the request a child of the caller's trace; a
        malformed or absent one mints a fresh root — never an error."""
        rtr = QueryTrace(
            self._rid(), str(request.get("sink", "?")), "transport"
        )
        ctx = parse_traceparent(traceparent) if traceparent else None
        if ctx is not None:
            rtr.bind_child_of(ctx)
        else:
            rtr.bind_root(mint_context())
        return rtr

    def _trace_headers(self, rtr: QueryTrace, headers: Dict[str, str]) -> None:
        ctx = rtr.context
        if ctx is not None:
            headers["X-Trace-Id"] = rtr.trace_id
            headers["traceparent"] = ctx.to_traceparent()

    def _close_trace(self, rtr: QueryTrace, error: bool = False) -> None:
        rtr.finish()
        if self.trace_store is not None:
            self.trace_store.offer(rtr, error=error)

    def _fail(self, rtr: QueryTrace, exc: BaseException) -> TransportResponse:
        self._close_trace(rtr, error=True)
        resp = self._error_response(exc)
        self._trace_headers(rtr, resp.headers)
        return resp

    # -- the serving endpoint -------------------------------------------------
    async def handle(
        self,
        request: Dict,
        tenant: str = "default",
        traceparent: Optional[str] = None,
    ) -> TransportResponse:
        """Serve one query request dict for ``tenant``.

        ``traceparent`` (the W3C header value, when the caller sent one)
        roots this request's trace under the caller's; the response echoes
        the request's own context back via ``traceparent`` / ``X-Trace-Id``
        headers, and the payload's ``trace_id`` names the producing engine
        execution (the leader's, for coalesced followers)."""
        t0 = time.perf_counter()
        rtr = self._begin_request_trace(request, traceparent)
        i_span = rtr.begin("probe")
        try:
            probe = self.service.probe(request)
        except (KeyError, AccessDenied, QueryPlanError, ValueError,
                TypeError) as exc:
            rtr.end(i_span)
            return self._fail(rtr, exc)
        rtr.end(i_span)

        i_span = rtr.begin("admit")
        wait = self.admission.admit(tenant)
        rtr.end(i_span)
        if wait is not None:
            self._c_shed["quota"].inc()
            rtr.notes["shed"] = "quota"
            self._close_trace(rtr, error=True)
            resp = self._shed(wait)
            self._trace_headers(rtr, resp.headers)
            return resp

        lane = self.classify(probe)
        rtr.notes["lane"] = lane
        headers = {"X-Lane": lane, "X-Coalesced": "0"}

        group_fut = None
        if probe.coalescable:
            existing = self.coalescer.join(probe.group_key)
            if existing is not None:
                # read the leader's id *now*: the group may settle and
                # vanish across the await below
                leader_tid = self.coalescer.leader_of(probe.group_key)
                if leader_tid is not None:
                    rtr.links["coalesced_into"] = leader_tid
                headers["X-Coalesced"] = "1"
                i_span = rtr.begin("await_leader")
                kind, value = await existing
                rtr.end(i_span)
                if kind == "err":  # the leader's failure fans out too
                    return self._fail(rtr, value)
                return self._finish(value, lane, headers, t0, rtr)
            # no await between join-miss, open, and submit: the loop cannot
            # interleave another handler here, so the group is never raced
            group_fut = self.coalescer.open(probe.group_key, rtr.trace_id)

        # the engine executes as a child span of this request: queue_wait
        # and execute spans land in rtr from the worker thread, and the
        # engine's own QueryTrace binds child-of rtr.context
        exec_fut, retry = self.scheduler.try_submit(
            lane, probe.estimated_cost_s,
            self.service.query, request, rtr.context,
            trace=rtr,
        )
        if exec_fut is None:
            if group_fut is not None:
                # nothing can have joined: no await ran since open()
                self.coalescer.settle(
                    probe.group_key, ("err", RuntimeError("leader shed"))
                )
            self._c_shed["queue"].inc()
            rtr.notes["shed"] = "queue"
            self._close_trace(rtr, error=True)
            resp = self._shed(retry)
            self._trace_headers(rtr, resp.headers)
            return resp

        try:
            payload = await exec_fut
        except BaseException as exc:
            if group_fut is not None:
                self.coalescer.settle(probe.group_key, ("err", exc))
            return self._fail(rtr, exc)
        if group_fut is not None:
            self.coalescer.settle(probe.group_key, ("ok", payload))
        return self._finish(payload, lane, headers, t0, rtr)

    def _finish(
        self,
        payload: Dict,
        lane: str,
        headers: Dict[str, str],
        t0: float,
        rtr: Optional[QueryTrace] = None,
    ) -> TransportResponse:
        self._c_requests[lane].inc()
        self._h_latency[lane].observe(
            time.perf_counter() - t0,
            trace_id=None if rtr is None else rtr.trace_id,
        )
        if rtr is not None:
            self._trace_headers(rtr, headers)
            self._close_trace(rtr)
        return TransportResponse(200, payload, headers=headers)

    # -- the append endpoint --------------------------------------------------
    async def append(
        self, request: Dict, tenant: str = "default"
    ) -> TransportResponse:
        """Live-append a batch of events.  Runs on the cold lane (it writes
        column files); never coalesced.  The fingerprint move it causes
        splits any concurrent coalescing groups automatically — that is the
        point of keying groups on fingerprint-at-enqueue."""
        wait = self.admission.admit(tenant)
        if wait is not None:
            self._c_shed["quota"].inc()
            return self._shed(wait)
        exec_fut, retry = self.scheduler.try_submit(
            "cold", 0.05, self.service.append, request
        )
        if exec_fut is None:
            self._c_shed["queue"].inc()
            return self._shed(retry)
        t0 = time.perf_counter()
        try:
            payload = await exec_fut
        except BaseException as exc:
            return self._error_response(exc)
        self._c_requests["cold"].inc()
        self._h_latency["cold"].observe(time.perf_counter() - t0)
        return TransportResponse(200, payload, headers={"X-Lane": "cold"})

    # -- readiness ------------------------------------------------------------
    def readiness(self) -> Tuple[bool, Dict]:
        """Probe the serving path's load-bearing pieces; ``(ready,
        report)``.  Degraded pieces land in ``report["reasons"]`` so the
        503 body says *why* — a saturated lane, an unreachable registry, a
        broken log registration."""
        checks: Dict[str, object] = {}
        reasons = []
        try:
            self.service.engine.metrics.to_dict()
            checks["engine_metrics"] = "ok"
        except Exception as exc:  # registry gauge callbacks may raise
            checks["engine_metrics"] = f"{type(exc).__name__}: {exc}"
            reasons.append("engine_metrics")
        try:
            names = self.service.logs()
            checks["logs"] = {"registered": len(names)}
        except Exception as exc:
            checks["logs"] = f"{type(exc).__name__}: {exc}"
            reasons.append("logs")
        try:
            graphs = self.service.engine.graphs
            checks["graph_store"] = {"resident": len(graphs)}
        except Exception as exc:
            checks["graph_store"] = f"{type(exc).__name__}: {exc}"
            reasons.append("graph_store")
        for lane in ("hot", "cold"):
            depth = self.scheduler.depth(lane)
            cap = self.config.max_depth_hot if lane == "hot" \
                else self.config.max_depth_cold
            saturated = depth >= cap
            checks[f"lane_{lane}"] = {"depth": depth, "max_depth": cap}
            if saturated:
                reasons.append(f"lane_{lane}_saturated")
        report = {"ready": not reasons, "checks": checks}
        if reasons:
            report["reasons"] = reasons
        return not reasons, report

    def close(self) -> None:
        self.scheduler.close()
        if self.trace_store is not None:
            self.trace_store.close()
