"""The transport application: probe → admit → classify → coalesce → execute.

:class:`TransportApp` is the protocol-independent core of the serving
tier.  :class:`~repro.transport.server.TransportServer` parses HTTP and
hands request dicts here; tests drive this class directly so every
admission/coalescing/laning behaviour is assertable without sockets.

One request's life:

1. **Probe** — :meth:`QueryService.probe` resolves policy and canonical
   plan *without executing*; invalid requests map to 4xx before they cost
   a queue slot.
2. **Admit** — the tenant's token bucket; over-quota requests shed with
   429 + Retry-After.
3. **Classify** — hot iff the probe predicts a cache/delta serve or the
   planner's cost estimate is under the measured ``slo_hot_cutoff_s``
   boundary; otherwise cold.
4. **Coalesce** — an identical in-flight request (same policy, plan, and
   fingerprint-at-enqueue) means we await its future instead of executing.
5. **Execute** — the leader runs :meth:`QueryService.query` on its lane's
   thread pool (the engine is synchronous numpy/jax) and fans the result
   out.

Everything reports through the engine's own :class:`MetricsRegistry` —
queue-depth gauges, shed/coalesce counters, per-lane latency histograms —
so ``{"sink": "metrics"}`` already covers the transport tier.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

from repro.core.views import AccessDenied
from repro.query import QueryPlanError
from repro.query.planner import load_calibration
from repro.serve import QueryService

from .admission import AdmissionController
from .coalesce import Coalescer
from .scheduler import TwoLaneScheduler

__all__ = [
    "TransportApp",
    "TransportConfig",
    "TransportResponse",
    "canonical_payload",
]

#: response fields that legitimately differ between a direct
#: ``QueryService.query`` call and a transport-served (possibly cached or
#: coalesced) execution of the same request
VOLATILE_FIELDS = ("wall_s", "from_cache", "backend", "trace")


def canonical_payload(payload: Dict) -> Dict:
    """The bit-identity view of a response: the payload minus execution
    provenance.  Transport guarantee: ``canonical_payload(transport) ==
    canonical_payload(service.query(request))`` for every request."""
    return {k: v for k, v in payload.items() if k not in VOLATILE_FIELDS}


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    rate: float = 200.0  # default per-tenant tokens/s
    burst: float = 400.0
    hot_workers: int = 4
    cold_workers: int = 2
    max_depth_hot: int = 256
    max_depth_cold: int = 32
    #: hot/cold boundary in seconds; None loads the measured value from
    #: BENCH_serve.json via load_calibration (static fallback inside)
    hot_cutoff_s: Optional[float] = None
    max_body_bytes: int = 8 * 1024 * 1024


@dataclasses.dataclass
class TransportResponse:
    status: int
    payload: Dict
    headers: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return 200 <= self.status < 300


class TransportApp:
    def __init__(
        self,
        service: Optional[QueryService] = None,
        config: Optional[TransportConfig] = None,
    ):
        self.service = service or QueryService()
        self.config = config or TransportConfig()
        self.hot_cutoff_s = (
            float(self.config.hot_cutoff_s)
            if self.config.hot_cutoff_s is not None
            else float(load_calibration()["slo_hot_cutoff_s"])
        )
        metrics = self.service.engine.metrics
        self.metrics = metrics
        self.admission = AdmissionController(
            rate=self.config.rate, burst=self.config.burst
        )
        self.coalescer = Coalescer(metrics)
        self.scheduler = TwoLaneScheduler(
            metrics,
            hot_workers=self.config.hot_workers,
            cold_workers=self.config.cold_workers,
            max_depth_hot=self.config.max_depth_hot,
            max_depth_cold=self.config.max_depth_cold,
        )
        self._c_requests = {
            lane: metrics.counter("transport_requests_total", lane=lane)
            for lane in ("hot", "cold")
        }
        self._c_shed = {
            reason: metrics.counter("transport_shed_total", reason=reason)
            for reason in ("quota", "queue")
        }
        self._h_latency = {
            lane: metrics.histogram("request_latency_seconds", lane=lane)
            for lane in ("hot", "cold")
        }

    # -- classification -------------------------------------------------------
    def classify(self, probe) -> str:
        """hot = predicted cache/delta/graph serve or a scan the planner
        prices under the measured boundary; cold = everything else."""
        if probe.cached or probe.delta_hint:
            return "hot"
        return "hot" if probe.estimated_cost_s <= self.hot_cutoff_s else "cold"

    # -- error mapping --------------------------------------------------------
    @staticmethod
    def _error_status(exc: BaseException) -> Optional[int]:
        if isinstance(exc, KeyError):
            return 404
        if isinstance(exc, AccessDenied):
            return 403
        if isinstance(exc, (QueryPlanError, ValueError, TypeError)):
            return 400
        return None

    @classmethod
    def _error_response(cls, exc: BaseException) -> TransportResponse:
        status = cls._error_status(exc)
        if status is None:
            raise exc
        detail = exc.args[0] if exc.args else str(exc)
        return TransportResponse(
            status, {"error": type(exc).__name__, "detail": str(detail)}
        )

    @staticmethod
    def _shed(retry_after_s: float) -> TransportResponse:
        retry = max(retry_after_s, 0.001)
        return TransportResponse(
            429,
            {"error": "Shed", "retry_after_s": retry},
            headers={"Retry-After": f"{retry:.3f}"},
        )

    # -- the serving endpoint -------------------------------------------------
    async def handle(
        self, request: Dict, tenant: str = "default"
    ) -> TransportResponse:
        """Serve one query request dict for ``tenant``."""
        t0 = time.perf_counter()
        try:
            probe = self.service.probe(request)
        except (KeyError, AccessDenied, QueryPlanError, ValueError,
                TypeError) as exc:
            return self._error_response(exc)

        wait = self.admission.admit(tenant)
        if wait is not None:
            self._c_shed["quota"].inc()
            return self._shed(wait)

        lane = self.classify(probe)
        headers = {"X-Lane": lane, "X-Coalesced": "0"}

        group_fut = None
        if probe.coalescable:
            existing = self.coalescer.join(probe.group_key)
            if existing is not None:
                headers["X-Coalesced"] = "1"
                kind, value = await existing
                if kind == "err":  # the leader's failure fans out too
                    return self._error_response(value)
                return self._finish(value, lane, headers, t0)
            # no await between join-miss, open, and submit: the loop cannot
            # interleave another handler here, so the group is never raced
            group_fut = self.coalescer.open(probe.group_key)

        exec_fut, retry = self.scheduler.try_submit(
            lane, probe.estimated_cost_s, self.service.query, request
        )
        if exec_fut is None:
            if group_fut is not None:
                # nothing can have joined: no await ran since open()
                self.coalescer.settle(
                    probe.group_key, ("err", RuntimeError("leader shed"))
                )
            self._c_shed["queue"].inc()
            return self._shed(retry)

        try:
            payload = await exec_fut
        except BaseException as exc:
            if group_fut is not None:
                self.coalescer.settle(probe.group_key, ("err", exc))
            return self._error_response(exc)
        if group_fut is not None:
            self.coalescer.settle(probe.group_key, ("ok", payload))
        return self._finish(payload, lane, headers, t0)

    def _finish(
        self, payload: Dict, lane: str, headers: Dict[str, str], t0: float
    ) -> TransportResponse:
        self._c_requests[lane].inc()
        self._h_latency[lane].observe(time.perf_counter() - t0)
        return TransportResponse(200, payload, headers=headers)

    # -- the append endpoint --------------------------------------------------
    async def append(
        self, request: Dict, tenant: str = "default"
    ) -> TransportResponse:
        """Live-append a batch of events.  Runs on the cold lane (it writes
        column files); never coalesced.  The fingerprint move it causes
        splits any concurrent coalescing groups automatically — that is the
        point of keying groups on fingerprint-at-enqueue."""
        wait = self.admission.admit(tenant)
        if wait is not None:
            self._c_shed["quota"].inc()
            return self._shed(wait)
        exec_fut, retry = self.scheduler.try_submit(
            "cold", 0.05, self.service.append, request
        )
        if exec_fut is None:
            self._c_shed["queue"].inc()
            return self._shed(retry)
        t0 = time.perf_counter()
        try:
            payload = await exec_fut
        except BaseException as exc:
            return self._error_response(exc)
        self._c_requests["cold"].inc()
        self._h_latency["cold"].observe(time.perf_counter() - t0)
        return TransportResponse(200, payload, headers={"X-Lane": "cold"})

    def close(self) -> None:
        self.scheduler.close()
