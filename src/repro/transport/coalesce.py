"""In-flight request coalescing — thousands of identical dashboards, one
engine execution.

Groups are keyed by :attr:`repro.serve.RequestProbe.group_key` = (tenant
policy, canonical plan, **source fingerprint observed at enqueue time**).
The fingerprint in the key is the correctness linchpin against live
appends: a leader that started executing against fingerprint F keeps
collecting only waiters who also observed F.  The moment an append moves
the log to F′, new arrivals probe F′, miss the in-flight F group, and
start their own execution against the new bytes — a stale result is never
fanned out past the data it was computed from.

The table is **event-loop confined**: every mutation happens on the
transport's loop (handler coroutines and executor-completion callbacks),
so there is deliberately no lock here — one less ordering edge under
``REPRO_LOCKDEP=1``.
"""

from __future__ import annotations

import asyncio
from typing import Dict, Optional, Tuple

from repro.obs import MetricsRegistry

__all__ = ["Coalescer"]

GroupKey = Tuple[str, str, str]


class Coalescer:
    def __init__(self, metrics: MetricsRegistry):
        self._groups: Dict[GroupKey, asyncio.Future] = {}
        self._leaders: Dict[GroupKey, Optional[str]] = {}
        self._c_groups = metrics.counter(
            "transport_coalesce_groups_total",
            "Coalescing groups opened (one leader execution each)",
        )
        self._c_fanout = metrics.counter(
            "transport_coalesce_fanout_total",
            "Requests served by awaiting another request's execution",
        )

    def __len__(self) -> int:
        return len(self._groups)

    def join(self, key: GroupKey) -> Optional[asyncio.Future]:
        """The in-flight future for ``key``, counting this caller as a
        fanned-out waiter — or None when no group is open."""
        fut = self._groups.get(key)
        if fut is not None:
            self._c_fanout.inc()
        return fut

    def open(
        self, key: GroupKey, trace_id: Optional[str] = None
    ) -> asyncio.Future:
        """Open a new group led by the caller; the returned future fans the
        leader's result out to every subsequent :meth:`join`.  The leader's
        ``trace_id`` is retained so followers can link ``coalesced_into``
        in their own traces."""
        fut = asyncio.get_running_loop().create_future()
        self._groups[key] = fut
        self._leaders[key] = trace_id
        self._c_groups.inc()
        return fut

    def leader_of(self, key: GroupKey) -> Optional[str]:
        """The open group leader's trace id (None when unknown or no
        group).  Read it right after :meth:`join` — the group may settle
        and vanish across any ``await``."""
        return self._leaders.get(key)

    def settle(self, key: GroupKey, outcome) -> None:
        """Resolve and close ``key``'s group with ``outcome`` — an
        app-level ``("ok", payload)`` / ``("err", exc)`` pair, always
        delivered via ``set_result`` so a group nobody joined never logs an
        un-retrieved exception.  The group is removed *before* the future
        resolves: a request arriving after settlement opens a fresh group
        (and will find the result in the engine cache anyway)."""
        fut = self._groups.pop(key, None)
        self._leaders.pop(key, None)
        if fut is None or fut.done():
            return
        fut.set_result(outcome)
