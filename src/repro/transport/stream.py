"""NDJSON streaming for large query payloads.

Alignment and per-trace-fitness responses carry one entry per trace — for
a 10M-event log that is a payload a browser should not have to buffer.
:func:`iter_ndjson` flattens a query-result dict into newline-delimited
JSON: one ``meta`` line carrying every scalar field and naming the
streamed list fields, then one line per list element, then a terminal
``{"end": true}`` marker so a client can distinguish completion from a
dropped connection.  :func:`reassemble_ndjson` is the exact inverse —
``reassemble_ndjson(iter_ndjson(p)) == p`` — which is what the transport
tests lean on for the bit-identity guarantee.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, Iterator, List

__all__ = ["iter_ndjson", "reassemble_ndjson"]


def iter_ndjson(payload: Dict[str, Any]) -> Iterator[str]:
    """Yield NDJSON lines (each ``\\n``-terminated) for ``payload``.

    Every top-level list field is streamed element-by-element; everything
    else rides the leading meta line.  Key order within each streamed
    field is preserved, so reassembly is exact."""
    streams: List[str] = [
        k for k, v in payload.items() if isinstance(v, list)
    ]
    meta = {k: v for k, v in payload.items() if k not in streams}
    yield json.dumps({"meta": meta, "streams": streams}) + "\n"
    for key in streams:
        for item in payload[key]:
            yield json.dumps({"key": key, "item": item}) + "\n"
    yield json.dumps({"end": True}) + "\n"


def reassemble_ndjson(lines: Iterable[str]) -> Dict[str, Any]:
    """Inverse of :func:`iter_ndjson`.  Raises ValueError on a truncated
    stream (missing ``{"end": true}``) or a malformed line."""
    it = iter(lines)
    try:
        head = json.loads(next(it))
    except StopIteration:
        raise ValueError("empty NDJSON stream")
    if "meta" not in head or "streams" not in head:
        raise ValueError("NDJSON stream missing meta header")
    payload: Dict[str, Any] = dict(head["meta"])
    for key in head["streams"]:
        payload[key] = []
    ended = False
    for line in it:
        line = line.strip()
        if not line:
            continue
        obj = json.loads(line)
        if obj.get("end") is True:
            ended = True
            break
        if "key" not in obj:
            raise ValueError(f"malformed NDJSON line: {line[:80]}")
        payload[obj["key"]].append(obj["item"])
    if not ended:
        raise ValueError("truncated NDJSON stream (no end marker)")
    return payload
