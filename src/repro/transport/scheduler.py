"""SLO-aware two-lane scheduling — cold scans never block warm traffic.

The engine's latency distribution is sharply bimodal: a cache, delta, or
graph serve answers in microseconds-to-milliseconds, while a cold
streaming scan of a large memmap log takes hundreds of milliseconds.  A
single work queue head-of-line-blocks the former behind the latter the
moment a few cold scans arrive together.  The scheduler therefore runs two
thread pools — requests classified *hot* by the planner probe
(:meth:`repro.query.QueryEngine.probe`) go to a wide hot pool, predicted
cold scans to a narrow cold pool — and bounds each lane's depth, shedding
with a computed Retry-After instead of queueing unboundedly.

Lock discipline: ``_depth`` is guarded by ``make_lock("TransportScheduler")``
because the ``transport_queue_depth`` gauges read it from the metrics
thread.  That read creates a MetricsRegistry → TransportScheduler ordering
edge, so code here must never touch a counter or histogram while holding
the scheduler lock (the reverse edge would deadlock under
``REPRO_LOCKDEP=1``).
"""

from __future__ import annotations

import asyncio
from concurrent.futures import ThreadPoolExecutor
from time import perf_counter
from typing import Callable, Optional, Tuple

from repro.analysis.lockdep import make_lock
from repro.obs import MetricsRegistry

__all__ = ["TwoLaneScheduler"]

LANES = ("hot", "cold")


class TwoLaneScheduler:
    def __init__(
        self,
        metrics: MetricsRegistry,
        hot_workers: int = 4,
        cold_workers: int = 2,
        max_depth_hot: int = 256,
        max_depth_cold: int = 32,
    ):
        self._pools = {
            "hot": ThreadPoolExecutor(
                max_workers=hot_workers, thread_name_prefix="transport-hot"
            ),
            "cold": ThreadPoolExecutor(
                max_workers=cold_workers, thread_name_prefix="transport-cold"
            ),
        }
        self._workers = {"hot": hot_workers, "cold": cold_workers}
        self._max_depth = {"hot": max_depth_hot, "cold": max_depth_cold}
        self._depth = {"hot": 0, "cold": 0}  # guarded by _lock
        self._lock = make_lock("TransportScheduler")
        self._h_wait = {
            lane: metrics.histogram(
                "transport_queue_wait_seconds",
                "Lane queue wait: submission to worker pickup",
                lane=lane,
            )
            for lane in LANES
        }
        for lane in LANES:
            metrics.gauge(
                "transport_queue_depth",
                lambda lane=lane: float(self.depth(lane)),
                "Queued plus running work per lane",
                lane=lane,
            )

    def depth(self, lane: str) -> int:
        with self._lock:
            return self._depth[lane]

    def try_submit(
        self, lane: str, est_cost_s: float, fn: Callable, *args, trace=None
    ) -> Tuple[Optional[asyncio.Future], Optional[float]]:
        """Run ``fn(*args)`` on ``lane``'s pool, bounded by the lane depth.

        Returns ``(future, None)`` when admitted — the asyncio future
        resolves with ``fn``'s result — or ``(None, retry_after_s)`` when
        the lane is full and the request must be shed.  Depth counts
        queued *plus* running work, so the Retry-After estimate
        ``depth × est_cost / workers`` approximates the lane's drain time.

        ``trace`` (a :class:`~repro.obs.QueryTrace`) receives a
        ``queue_wait:<lane>`` span — submit-to-pickup — and an ``execute``
        span, both recorded *on the worker thread* so the submitting
        coroutine (which is suspended awaiting the future until after the
        worker finishes) never races the span slab.  The wait also feeds
        the ``transport_queue_wait_seconds`` histogram with the trace id
        as exemplar.
        """
        with self._lock:
            depth = self._depth[lane]
            if depth >= self._max_depth[lane]:
                admitted = False
            else:
                admitted = True
                self._depth[lane] = depth + 1
        if not admitted:
            per_req = max(est_cost_s, 1e-3)
            return None, depth * per_req / max(self._workers[lane], 1)

        t_submit = perf_counter()
        h_wait = self._h_wait[lane]

        def _run():
            t_start = perf_counter()
            if trace is not None:
                trace.add_span(f"queue_wait:{lane}", t_submit,
                               t_start - t_submit)
            h_wait.observe(
                t_start - t_submit,
                trace_id=None if trace is None else trace.trace_id,
            )
            try:
                return fn(*args)
            finally:
                if trace is not None:
                    trace.add_span("execute", t_start,
                                   perf_counter() - t_start)

        loop = asyncio.get_running_loop()
        fut = loop.run_in_executor(self._pools[lane], _run)
        fut.add_done_callback(lambda _f: self._done(lane))
        return fut, None

    def _done(self, lane: str) -> None:
        with self._lock:
            self._depth[lane] -= 1

    def close(self) -> None:
        for pool in self._pools.values():
            pool.shutdown(wait=True)
