"""Stdlib-only asyncio HTTP/1.1 front end for :class:`TransportApp`.

No web framework — ``asyncio.start_server`` plus a small request parser is
all the wire needs, which keeps the serving tier importable anywhere the
engine is.  Endpoints:

* ``POST /query`` — one request dict in, one JSON response out; headers
  carry ``X-Lane`` (hot/cold), ``X-Coalesced`` and, on 429,
  ``Retry-After``.
* ``POST /query/stream`` — same request, NDJSON chunked response (one meta
  line, one line per list element, ``{"end": true}``): alignment payloads
  with one entry per trace never buffer server-side.
* ``POST /append`` — live event append.
* ``GET /metrics`` — Prometheus text exposition (engine + kernel
  registries, transport series included).
* ``GET /stream/metrics`` / ``GET /stream/forensics`` — live NDJSON feeds
  of the introspection sinks (``?interval=0.5&count=10``).
* ``GET /slo`` — objective verdicts, error budgets, and burn-rate alerts
  (the ``{"sink": "slo"}`` introspection over HTTP).
* ``GET /healthz`` — liveness.
* ``GET /readyz`` — readiness: probes engine registry, log registry,
  graph store, and scheduler-lane saturation; 503 + JSON reasons when
  degraded.

The tenant identity is the ``X-Tenant`` header (default ``"default"``) —
admission quotas key on it.  An inbound ``traceparent`` header roots the
request's distributed trace under the caller's; responses echo the
request's own context back (``traceparent`` / ``X-Trace-Id``).
"""

from __future__ import annotations

import asyncio
import json
from typing import Dict, Optional, Tuple
from urllib.parse import parse_qs, urlsplit

from .app import TransportApp, TransportResponse
from .stream import iter_ndjson

__all__ = ["TransportServer"]

_MAX_HEADER_BYTES = 64 * 1024


class _BadRequest(Exception):
    pass


async def _read_request(
    reader: asyncio.StreamReader, max_body: int
) -> Optional[Tuple[str, str, Dict[str, str], bytes]]:
    """Parse one HTTP/1.1 request; None on a cleanly closed connection."""
    try:
        line = await reader.readline()
    except (ConnectionResetError, asyncio.IncompleteReadError):
        return None
    if not line:
        return None
    parts = line.decode("latin-1").rstrip("\r\n").split(" ")
    if len(parts) != 3:
        raise _BadRequest("malformed request line")
    method, target, _version = parts
    headers: Dict[str, str] = {}
    total = len(line)
    while True:
        line = await reader.readline()
        total += len(line)
        if total > _MAX_HEADER_BYTES:
            raise _BadRequest("headers too large")
        if line in (b"\r\n", b"\n", b""):
            break
        key, _, value = line.decode("latin-1").partition(":")
        headers[key.strip().lower()] = value.strip()
    length = int(headers.get("content-length", "0") or "0")
    if length > max_body:
        raise _BadRequest(f"body exceeds {max_body} bytes")
    body = await reader.readexactly(length) if length else b""
    return method, target, headers, body


def _write_response(
    writer: asyncio.StreamWriter, resp: TransportResponse
) -> None:
    body = json.dumps(resp.payload).encode()
    head = [f"HTTP/1.1 {resp.status} {_reason(resp.status)}"]
    head.append("Content-Type: application/json")
    head.append(f"Content-Length: {len(body)}")
    for k, v in resp.headers.items():
        head.append(f"{k}: {v}")
    writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + body)


def _reason(status: int) -> str:
    return {
        200: "OK", 400: "Bad Request", 403: "Forbidden", 404: "Not Found",
        405: "Method Not Allowed", 413: "Payload Too Large",
        429: "Too Many Requests", 500: "Internal Server Error",
        503: "Service Unavailable",
    }.get(status, "Unknown")


class TransportServer:
    def __init__(
        self,
        app: Optional[TransportApp] = None,
        host: str = "127.0.0.1",
        port: int = 0,
    ):
        self.app = app or TransportApp()
        self.host = host
        self.port = port  # 0 = ephemeral; resolved after start()
        self._server: Optional[asyncio.AbstractServer] = None

    # -- lifecycle ------------------------------------------------------------
    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._connection, self.host, self.port
        )
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        self.app.close()

    @property
    def address(self) -> str:
        return f"http://{self.host}:{self.port}"

    async def serve_forever(self) -> None:
        if self._server is None:
            await self.start()
        await self._server.serve_forever()

    # -- connection handling --------------------------------------------------
    async def _connection(
        self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter
    ) -> None:
        try:
            while True:
                try:
                    req = await _read_request(
                        reader, self.app.config.max_body_bytes
                    )
                except _BadRequest as exc:
                    _write_response(
                        writer,
                        TransportResponse(
                            400, {"error": "BadRequest", "detail": str(exc)}
                        ),
                    )
                    await writer.drain()
                    break
                if req is None:
                    break
                method, target, headers, body = req
                keep_alive = (
                    headers.get("connection", "keep-alive").lower()
                    != "close"
                )
                await self._dispatch(writer, method, target, headers, body)
                await writer.drain()
                if not keep_alive:
                    break
        except (ConnectionResetError, asyncio.IncompleteReadError,
                BrokenPipeError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _dispatch(
        self,
        writer: asyncio.StreamWriter,
        method: str,
        target: str,
        headers: Dict[str, str],
        body: bytes,
    ) -> None:
        url = urlsplit(target)
        path = url.path
        tenant = headers.get("x-tenant", "default")
        traceparent = headers.get("traceparent")
        try:
            if path == "/healthz" and method == "GET":
                _write_response(
                    writer, TransportResponse(200, {"ok": True})
                )
            elif path == "/readyz" and method == "GET":
                ready, report = self.app.readiness()
                _write_response(
                    writer, TransportResponse(200 if ready else 503, report)
                )
            elif path == "/metrics" and method == "GET":
                self._write_prometheus(writer)
            elif path == "/slo" and method == "GET":
                _write_response(
                    writer,
                    await self.app.handle(
                        {"sink": "slo"}, tenant, traceparent
                    ),
                )
            elif path == "/query" and method == "POST":
                request = self._body_json(body)
                _write_response(
                    writer, await self.app.handle(request, tenant, traceparent)
                )
            elif path == "/append" and method == "POST":
                request = self._body_json(body)
                _write_response(
                    writer, await self.app.append(request, tenant)
                )
            elif path == "/query/stream" and method == "POST":
                request = self._body_json(body)
                resp = await self.app.handle(request, tenant, traceparent)
                if not resp.ok:
                    _write_response(writer, resp)
                else:
                    await self._write_ndjson(
                        writer, iter_ndjson(resp.payload), resp.headers
                    )
            elif path in ("/stream/metrics", "/stream/forensics") and (
                method == "GET"
            ):
                await self._live_stream(
                    writer, path.rsplit("/", 1)[1], url.query, tenant
                )
            else:
                _write_response(
                    writer,
                    TransportResponse(
                        405 if path in (
                            "/query", "/append", "/query/stream",
                            "/metrics", "/healthz", "/readyz", "/slo",
                        ) else 404,
                        {"error": "NoSuchEndpoint", "detail": target},
                    ),
                )
        except _BadRequest as exc:
            _write_response(
                writer,
                TransportResponse(
                    400, {"error": "BadRequest", "detail": str(exc)}
                ),
            )

    @staticmethod
    def _body_json(body: bytes) -> Dict:
        try:
            request = json.loads(body.decode() or "{}")
        except (json.JSONDecodeError, UnicodeDecodeError) as exc:
            raise _BadRequest(f"invalid JSON body: {exc}")
        if not isinstance(request, dict):
            raise _BadRequest("request body must be a JSON object")
        return request

    def _write_prometheus(self, writer: asyncio.StreamWriter) -> None:
        from repro.obs import kernel_registry, prometheus_text

        text = prometheus_text(
            self.app.service.engine.metrics, kernel_registry()
        ).encode()
        writer.write(
            (
                "HTTP/1.1 200 OK\r\n"
                "Content-Type: text/plain; version=0.0.4\r\n"
                f"Content-Length: {len(text)}\r\n\r\n"
            ).encode()
            + text
        )

    async def _write_ndjson(
        self,
        writer: asyncio.StreamWriter,
        lines,
        extra_headers: Dict[str, str],
    ) -> None:
        head = [
            "HTTP/1.1 200 OK",
            "Content-Type: application/x-ndjson",
            "Transfer-Encoding: chunked",
        ]
        for k, v in extra_headers.items():
            head.append(f"{k}: {v}")
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode())
        for line in lines:
            chunk = line.encode()
            writer.write(f"{len(chunk):x}\r\n".encode() + chunk + b"\r\n")
            await writer.drain()
        writer.write(b"0\r\n\r\n")

    async def _live_stream(
        self,
        writer: asyncio.StreamWriter,
        sink: str,
        query: str,
        tenant: str,
    ) -> None:
        """Poll the introspection sink every ``interval`` seconds, one JSON
        line per snapshot — a live dashboard feed off the engine's own
        metrics/telemetry."""
        params = parse_qs(query)
        try:
            interval = float(params.get("interval", ["0.5"])[0])
            count = int(params.get("count", ["10"])[0])
        except ValueError:
            raise _BadRequest("interval/count must be numeric")
        interval = min(max(interval, 0.01), 60.0)
        count = min(max(count, 1), 10_000)

        async def snapshots():
            for i in range(count):
                resp = await self.app.handle({"sink": sink}, tenant)
                yield json.dumps(
                    {"seq": i, "status": resp.status, "body": resp.payload}
                ) + "\n"
                if i + 1 < count:
                    await asyncio.sleep(interval)
            yield json.dumps({"end": True}) + "\n"

        head = (
            "HTTP/1.1 200 OK\r\n"
            "Content-Type: application/x-ndjson\r\n"
            "Transfer-Encoding: chunked\r\n\r\n"
        )
        writer.write(head.encode())
        async for line in snapshots():
            chunk = line.encode()
            writer.write(f"{len(chunk):x}\r\n".encode() + chunk + b"\r\n")
            await writer.drain()
        writer.write(b"0\r\n\r\n")
