"""``repro.transport`` — the async serving tier over :class:`QueryService`.

The paper's thesis is that the *store* does the mining; this package is
what makes the store a shared service: an asyncio HTTP/JSON layer that
keeps thousands of concurrent dashboards from melting the engine.

* **Admission control** (:mod:`.admission`) — per-tenant token-bucket rate
  limits; over-limit requests shed with 429 + Retry-After instead of
  queueing.
* **Request coalescing** (:mod:`.coalesce`) — in-flight requests dedup'd
  by (tenant policy, canonical plan, source fingerprint observed at
  enqueue): N identical concurrent queries execute once, everyone shares
  the result.
* **SLO-aware two-lane scheduling** (:mod:`.scheduler`) — predicted
  cache/delta/graph serves (~µs–ms) ride the hot lane, predicted cold
  scans (~100s of ms) the cold lane, so a burst of cold scans never
  head-of-line-blocks warm dashboard traffic.  The hot/cold boundary is
  the measured ``slo_hot_cutoff_s`` from ``BENCH_serve.json`` via
  :func:`repro.query.planner.load_calibration`.
* **Streaming responses** (:mod:`.stream`, :mod:`.server`) — NDJSON
  chunked streaming for large payloads and the live ``metrics`` /
  ``forensics`` endpoints.

All of it reports through the engine's own :class:`MetricsRegistry`, so
``{"sink": "metrics"}`` and the Prometheus exposition cover the transport
tier with no second registry.
"""

from .admission import AdmissionController, TokenBucket
from .app import TransportApp, TransportConfig, TransportResponse, canonical_payload
from .coalesce import Coalescer
from .scheduler import TwoLaneScheduler
from .server import TransportServer
from .stream import iter_ndjson, reassemble_ndjson

__all__ = [
    "AdmissionController",
    "TokenBucket",
    "TransportApp",
    "TransportConfig",
    "TransportResponse",
    "TransportServer",
    "Coalescer",
    "TwoLaneScheduler",
    "canonical_payload",
    "iter_ndjson",
    "reassemble_ndjson",
]
