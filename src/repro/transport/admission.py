"""Admission control — per-tenant token buckets with shed-don't-queue.

A tenant's dashboard refreshing at 1 Hz costs one token per request; a
runaway client paying no attention to Retry-After drains its bucket and is
shed with 429 before its requests consume a queue slot, an executor
thread, or an engine lock.  Buckets refill continuously at ``rate``
tokens/s up to ``burst``; the controller is shared-lock cheap (one
``make_lock`` guards the bucket map *and* every bucket's level — the same
one-lock-per-registry pattern as :class:`repro.obs.MetricsRegistry`).
"""

from __future__ import annotations

import time
from typing import Dict, Optional

from repro.analysis.lockdep import make_lock

__all__ = ["TokenBucket", "AdmissionController"]


class TokenBucket:
    """Continuous-refill token bucket.  Not thread-safe on its own: the
    owning :class:`AdmissionController` serializes access (its lock also
    covers bucket state)."""

    __slots__ = ("rate", "burst", "tokens", "stamp")

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.stamp = float(now)

    def take(self, now: float, n: float = 1.0) -> float:
        """Take ``n`` tokens.  Returns 0.0 when admitted, else the seconds
        until the bucket will hold ``n`` tokens (the Retry-After value)."""
        elapsed = max(now - self.stamp, 0.0)
        self.tokens = min(self.burst, self.tokens + elapsed * self.rate)
        self.stamp = now
        if self.tokens >= n:
            self.tokens -= n
            return 0.0
        if self.rate <= 0.0:
            return float("inf")
        return (n - self.tokens) / self.rate


class AdmissionController:
    """Per-tenant buckets, created on first sight with the default rate.

    ``admit(tenant)`` returns ``None`` when the request may proceed, else
    the Retry-After seconds the 429 should carry.  ``set_quota`` pins a
    specific rate/burst for one tenant (e.g. a paid tier)."""

    def __init__(self, rate: float = 200.0, burst: float = 400.0):
        self.rate = float(rate)
        self.burst = float(burst)
        self._buckets: Dict[str, TokenBucket] = {}
        self._lock = make_lock("TransportAdmission")

    def set_quota(self, tenant: str, rate: float, burst: float) -> None:
        now = time.monotonic()
        with self._lock:
            self._buckets[tenant] = TokenBucket(rate, burst, now)

    def admit(self, tenant: str, cost: float = 1.0) -> Optional[float]:
        now = time.monotonic()
        with self._lock:
            bucket = self._buckets.get(tenant)
            if bucket is None:
                bucket = self._buckets[tenant] = TokenBucket(
                    self.rate, self.burst, now
                )
            wait = bucket.take(now, cost)
        return None if wait == 0.0 else wait

    def tenants(self) -> int:
        with self._lock:
            return len(self._buckets)
