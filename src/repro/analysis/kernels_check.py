"""Static Pallas kernel resource checker.

Walks each kernel module's ``pl.pallas_call`` **BlockSpecs symbolically**
(no JAX import, no execution): the block shapes are AST expressions over
the block-size parameters (``block_e``, ``block_a``, ...), so for any
concrete assignment of those parameters the checker can

* bound the **VMEM working set** per grid step — Σ over operand/output
  blocks of ``prod(block_shape) × dtype_bytes``, plus each kernel's
  declared in-kernel scratch term (the one-hot / DP-front tiles the body
  materializes, the same formulas ``pick_blocks`` budgets against);
* flag **tile misalignment** against the MXU/VPU tiling rules — lane
  (minor) dimension a multiple of 128, sublane a multiple of 8/16/32 for
  4/2/1-byte dtypes.  Whole-array broadcast operands (constant index maps,
  like the ``(1, 2)`` window) are exempt; under-sized power-of-two tiles
  (the align kernel's small variant blocks) are *warnings* — Mosaic pads
  them — while oversized unaligned tiles are hard errors.

:func:`validate_blocks` is the assertion layer ``pick_blocks`` calls: it
raises :class:`KernelResourceError` when a block assignment breaks the
VMEM limit or a hard alignment rule.  :func:`build_report` evaluates every
kernel at representative operating points for the committed
``BENCH_analysis.json`` artifact.
"""

from __future__ import annotations

import ast
import dataclasses
import functools
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "KernelResourceError",
    "KERNEL_TABLE",
    "analyze_kernel",
    "estimate_call",
    "validate_blocks",
    "build_report",
]

#: per-chip VMEM (v5e); the hard ceiling validate_blocks asserts against
VMEM_LIMIT_BYTES = 16 << 20
#: the soft budget pick_blocks tunes toward (headroom for double buffering)
VMEM_BUDGET_BYTES = 8 << 20

DTYPE_BYTES = {
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "bool": 1, "bool_": 1,
    "float64": 8, "int64": 8,
}
#: minimum sublane multiple by dtype width (TPU packs narrow dtypes deeper)
MIN_SUBLANE = {4: 8, 2: 16, 1: 32, 8: 8}
LANE = 128


class KernelResourceError(RuntimeError):
    """A block assignment violates the VMEM bound or a hard tiling rule."""


# ---------------------------------------------------------------------------
# Kernel registry: where each kernel lives, operand dtypes per call site
# (not recoverable from BlockSpecs), and the in-kernel scratch formula the
# body materializes beyond its declared blocks.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CallSpec:
    name: str
    in_dtypes: Tuple[str, ...]
    scratch: str  # bytes, symbolic in the same env as the block shapes


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    rel: str  # kernel module, relative to the repro package root
    calls: Tuple[CallSpec, ...]


KERNEL_TABLE: Dict[str, KernelSpec] = {
    "dfg_count": KernelSpec(
        rel="kernels/dfg_count/kernel.py",
        calls=(
            # two (BE, BA) f32 one-hot tiles feed the MXU contraction
            CallSpec("plain", ("int32", "int32", "bool"),
                     "2 * 4 * block_e * block_a"),
            CallSpec("diced",
                     ("int32", "int32", "bool",
                      "float32", "float32", "float32"),
                     "2 * 4 * block_e * block_a"),
        ),
    ),
    "segment_count": KernelSpec(
        rel="kernels/segment_count/kernel.py",
        calls=(
            # one (BN, BS) f32 one-hot tile
            CallSpec("main", ("int32", "bool"), "4 * block_n * block_s"),
        ),
    ),
    "align_dp": KernelSpec(
        rel="kernels/align_dp/kernel.py",
        calls=(
            # DP front + one-hot + gathered M column, each (BV, S) f32
            CallSpec("main",
                     ("int32", "int32", "float32", "float32", "float32"),
                     "3 * 4 * block_v * s"),
        ),
    ),
}


def _pkg_root() -> Path:
    return Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# AST extraction
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpecShape:
    dims: Tuple[str, ...]  # symbolic dim expressions (unparsed AST)
    const_index_map: bool  # whole-array broadcast operand


@dataclasses.dataclass(frozen=True)
class CallSite:
    in_specs: Tuple[SpecShape, ...]
    out_specs: Tuple[SpecShape, ...]
    out_dtype: str
    lineno: int


def _is_blockspec(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "BlockSpec"
    )


def _spec_shape(call: ast.Call) -> SpecShape:
    if not call.args or not isinstance(call.args[0], (ast.Tuple, ast.List)):
        raise KernelResourceError(
            f"BlockSpec at line {call.lineno} has no literal shape tuple"
        )
    dims = tuple(ast.unparse(e) for e in call.args[0].elts)
    const_map = False
    if len(call.args) > 1 and isinstance(call.args[1], ast.Lambda):
        body = call.args[1].body
        if isinstance(body, (ast.Tuple, ast.List)):
            const_map = all(isinstance(e, ast.Constant) for e in body.elts)
        else:
            const_map = isinstance(body, ast.Constant)
    return SpecShape(dims=dims, const_index_map=const_map)


def _resolve_spec(node: ast.AST, symbols: Dict[str, ast.Call]) -> ast.Call:
    if isinstance(node, ast.Name) and node.id in symbols:
        return symbols[node.id]
    if _is_blockspec(node):
        return node
    raise KernelResourceError(
        f"cannot resolve BlockSpec reference {ast.unparse(node)!r}"
    )


@functools.lru_cache(maxsize=None)
def analyze_kernel(path: str) -> Tuple[CallSite, ...]:
    """All ``pl.pallas_call`` sites in ``path``, in order of appearance,
    with their block shapes extracted symbolically."""
    tree = ast.parse(Path(path).read_text(), filename=str(path))
    sites: List[CallSite] = []
    for fn in [n for n in tree.body if isinstance(n, ast.FunctionDef)]:
        symbols: Dict[str, ast.Call] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _is_blockspec(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        symbols[t.id] = node.value
        for node in ast.walk(fn):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "pallas_call"
            ):
                continue
            kwargs = {k.arg: k.value for k in node.keywords if k.arg}
            raw_in = kwargs.get("in_specs")
            raw_out = kwargs.get("out_specs")
            if raw_in is None or raw_out is None:
                continue
            in_elts = (
                raw_in.elts if isinstance(raw_in, (ast.Tuple, ast.List))
                else [raw_in]
            )
            out_elts = (
                raw_out.elts if isinstance(raw_out, (ast.Tuple, ast.List))
                else [raw_out]
            )
            out_dtype = "float32"
            shape = kwargs.get("out_shape")
            if (
                isinstance(shape, ast.Call)
                and len(shape.args) > 1
                and isinstance(shape.args[1], ast.Attribute)
            ):
                out_dtype = shape.args[1].attr
            sites.append(CallSite(
                in_specs=tuple(
                    _spec_shape(_resolve_spec(e, symbols)) for e in in_elts
                ),
                out_specs=tuple(
                    _spec_shape(_resolve_spec(e, symbols)) for e in out_elts
                ),
                out_dtype=out_dtype,
                lineno=node.lineno,
            ))
    sites.sort(key=lambda s: s.lineno)
    return tuple(sites)


# ---------------------------------------------------------------------------
# Symbolic evaluation
# ---------------------------------------------------------------------------


def _eval(node: ast.AST, env: Dict[str, int]) -> int:
    if isinstance(node, ast.Expression):
        return _eval(node.body, env)
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return int(node.value)
    if isinstance(node, ast.Name):
        if node.id not in env:
            raise KernelResourceError(
                f"unresolved symbol {node.id!r}; pass it in the env"
            )
        return int(env[node.id])
    if isinstance(node, ast.BinOp):
        lhs, rhs = _eval(node.left, env), _eval(node.right, env)
        if isinstance(node.op, ast.Add):
            return lhs + rhs
        if isinstance(node.op, ast.Sub):
            return lhs - rhs
        if isinstance(node.op, ast.Mult):
            return lhs * rhs
        if isinstance(node.op, ast.FloorDiv):
            return lhs // rhs
        if isinstance(node.op, ast.Mod):
            return lhs % rhs
        if isinstance(node.op, ast.Pow):
            return lhs ** rhs
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_eval(node.operand, env)
    raise KernelResourceError(f"cannot evaluate {ast.unparse(node)!r}")


def _eval_expr(expr: str, env: Dict[str, int]) -> int:
    return _eval(ast.parse(expr, mode="eval"), env)


def _check_tiling(
    label: str, dims: Sequence[int], dtype: str
) -> Tuple[List[str], List[str]]:
    """(errors, warnings) for one evaluated block shape."""
    errors: List[str] = []
    warnings: List[str] = []
    if not dims:
        return errors, warnings
    itemsize = DTYPE_BYTES.get(dtype, 4)
    min_sub = MIN_SUBLANE.get(itemsize, 8)
    lane = dims[-1]
    if lane % LANE != 0:
        if lane < LANE and lane > 0 and (lane & (lane - 1)) == 0:
            warnings.append(
                f"{label}: lane dim {lane} < {LANE} — Mosaic pads the tile "
                f"({lane}/{LANE} lanes used)"
            )
        else:
            errors.append(
                f"{label}: lane dim {lane} is not a multiple of {LANE}"
            )
    if len(dims) >= 2:
        sub = dims[-2]
        if sub % min_sub != 0:
            if sub < min_sub and sub > 0 and (sub & (sub - 1)) == 0:
                warnings.append(
                    f"{label}: sublane dim {sub} < {min_sub} ({dtype}) — "
                    "Mosaic pads the tile"
                )
            else:
                errors.append(
                    f"{label}: sublane dim {sub} is not a multiple of "
                    f"{min_sub} ({dtype})"
                )
    return errors, warnings


def estimate_call(
    kernel_name: str,
    call_index: int,
    env: Dict[str, int],
    *,
    pkg_root: Optional[Path] = None,
) -> Dict[str, object]:
    """VMEM bound + tiling findings for one pallas_call under ``env``."""
    spec = KERNEL_TABLE[kernel_name]
    root = pkg_root or _pkg_root()
    sites = analyze_kernel(str(root / spec.rel))
    if len(sites) != len(spec.calls):
        raise KernelResourceError(
            f"{kernel_name}: expected {len(spec.calls)} pallas_call sites "
            f"in {spec.rel}, found {len(sites)}"
        )
    site = sites[call_index]
    call = spec.calls[call_index]
    if len(site.in_specs) != len(call.in_dtypes):
        raise KernelResourceError(
            f"{kernel_name}/{call.name}: {len(site.in_specs)} in_specs but "
            f"{len(call.in_dtypes)} declared operand dtypes"
        )

    operands = []
    errors: List[str] = []
    warnings: List[str] = []
    total = 0
    for i, (s, dtype) in enumerate(zip(site.in_specs, call.in_dtypes)):
        dims = [_eval_expr(d, env) for d in s.dims]
        nbytes = DTYPE_BYTES.get(dtype, 4)
        for d in dims:
            nbytes *= d
        total += nbytes
        operands.append({
            "operand": f"in[{i}]", "block": dims, "dtype": dtype,
            "bytes": nbytes,
        })
        if not s.const_index_map:  # broadcast operands live padded once
            e, w = _check_tiling(f"{call.name} in[{i}]", dims, dtype)
            errors += e
            warnings += w
    for i, s in enumerate(site.out_specs):
        dims = [_eval_expr(d, env) for d in s.dims]
        nbytes = DTYPE_BYTES.get(site.out_dtype, 4)
        for d in dims:
            nbytes *= d
        total += nbytes
        operands.append({
            "operand": f"out[{i}]", "block": dims, "dtype": site.out_dtype,
            "bytes": nbytes,
        })
        if not s.const_index_map:
            e, w = _check_tiling(f"{call.name} out[{i}]", dims, site.out_dtype)
            errors += e
            warnings += w
    scratch = _eval_expr(call.scratch, env)
    total += scratch
    return {
        "call": call.name,
        "env": dict(sorted(env.items())),
        "operands": operands,
        "scratch_bytes": scratch,
        "vmem_bytes": total,
        "errors": errors,
        "warnings": warnings,
    }


# ---------------------------------------------------------------------------
# The assertion layer pick_blocks calls
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def _validate_cached(
    kernel_name: str, env_items: Tuple[Tuple[str, int], ...],
    vmem_limit_bytes: int,
) -> Tuple[Dict[str, object], ...]:
    env = dict(env_items)
    spec = KERNEL_TABLE[kernel_name]
    reports = []
    for idx in range(len(spec.calls)):
        rep = estimate_call(kernel_name, idx, env)
        if rep["errors"]:
            raise KernelResourceError(
                f"{kernel_name}/{rep['call']}: " + "; ".join(rep["errors"])
            )
        if rep["vmem_bytes"] > vmem_limit_bytes:
            raise KernelResourceError(
                f"{kernel_name}/{rep['call']}: VMEM bound "
                f"{rep['vmem_bytes']} B exceeds the {vmem_limit_bytes} B "
                f"limit for blocks {rep['env']}"
            )
        reports.append(rep)
    return tuple(reports)


def validate_blocks(
    kernel_name: str,
    *,
    vmem_limit_bytes: int = VMEM_LIMIT_BYTES,
    **env: int,
) -> Tuple[Dict[str, object], ...]:
    """Assert a concrete block assignment is resourceable; returns the
    per-call reports.  Raises :class:`KernelResourceError` on a VMEM-limit
    overrun or a hard tile-misalignment."""
    return _validate_cached(
        kernel_name, tuple(sorted(env.items())), int(vmem_limit_bytes)
    )


# ---------------------------------------------------------------------------
# Committed report (BENCH_analysis.json)
# ---------------------------------------------------------------------------


def _scenario_envs(kernel_name: str) -> List[Tuple[str, Dict[str, int]]]:
    """Representative operating points, using each kernel's own
    ``pick_blocks`` so the report describes what actually runs."""
    if kernel_name == "dfg_count":
        from repro.kernels.dfg_count.ops import pick_blocks

        out = []
        for a in (64, 512, 2048):
            be, ba = pick_blocks(a)
            out.append((f"A={a}", {"block_e": be, "block_a": ba}))
        return out
    if kernel_name == "segment_count":
        from repro.kernels.segment_count.ops import pick_blocks

        out = []
        for s in (256, 4096):
            bn, bs = pick_blocks(s)
            out.append((f"S={s}", {"block_n": bn, "block_s": bs}))
        return out
    if kernel_name == "align_dp":
        from repro.kernels.align_dp.ops import _pad_lane, pick_blocks

        out = []
        for v, l, s in ((50, 40, 30), (1000, 600, 400)):
            out.append((
                f"V={v},L={l},S={s}",
                {
                    "block_v": pick_blocks(v),
                    "lp": _pad_lane(l),
                    "s": _pad_lane(s),
                },
            ))
        return out
    raise KeyError(kernel_name)


def build_report() -> Dict[str, object]:
    """Per-kernel VMEM bounds at representative operating points — the
    committed ``BENCH_analysis.json`` artifact (deterministic: no
    timestamps, no host state)."""
    kernels: Dict[str, object] = {}
    for name, spec in sorted(KERNEL_TABLE.items()):
        scenarios = []
        for label, env in _scenario_envs(name):
            calls = [
                estimate_call(name, idx, env)
                for idx in range(len(spec.calls))
            ]
            scenarios.append({
                "name": label,
                "calls": calls,
                "max_vmem_bytes": max(c["vmem_bytes"] for c in calls),
            })
        kernels[name] = {
            "source": f"src/repro/{spec.rel}",
            "scenarios": scenarios,
        }
    return {
        "generated_by": "python -m repro.analysis --kernel-report",
        "vmem_limit_bytes": VMEM_LIMIT_BYTES,
        "vmem_budget_bytes": VMEM_BUDGET_BYTES,
        "kernels": kernels,
    }
