"""AST-lint framework for the engine's invariants.

The reproduction's correctness story — count-preserving rewrites, complete
cache keys, backend bit-identity, lock discipline — lives in frozen plan
dataclasses and a handful of conventions (``with self._lock`` scopes,
``_locked``-suffix helpers, ``# guarded by <lock>`` annotations).  This
module is the machinery that checks those conventions on every commit:

* :class:`Finding` — one structured violation with a **stable identity**
  (rule + file + message, *not* the line number, so baselines survive
  unrelated edits);
* a rule registry (:func:`rule`) — each rule is a function
  ``fn(project) -> Iterable[Finding]`` over a parsed :class:`Project`;
* a committed JSON **baseline** of grandfathered findings with one-line
  justifications — the CI gate fails only on findings *not* in it;
* the ``python -m repro.analysis`` CLI (see ``__main__``).

Rules themselves live in :mod:`repro.analysis.rules`; the runtime lock
sanitizer in :mod:`repro.analysis.lockdep`; the Pallas resource checker in
:mod:`repro.analysis.kernels_check`.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence

__all__ = [
    "Finding",
    "Rule",
    "rule",
    "registered_rules",
    "Project",
    "run_rules",
    "load_baseline",
    "save_baseline",
    "split_findings",
]


# ---------------------------------------------------------------------------
# Findings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structured violation.

    ``identity()`` deliberately excludes the line number: a baseline entry
    must keep matching its finding while unrelated edits shift the file."""

    rule: str
    path: str  # posix path relative to the project root
    line: int
    message: str

    def identity(self) -> str:
        blob = f"{self.rule}|{self.path}|{self.message}"
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {
            "id": self.identity(),
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
        }


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    doc: str
    fn: Callable[["Project"], Iterable[Finding]]


_RULES: Dict[str, Rule] = {}


def rule(name: str, doc: str = ""):
    """Register a rule function under ``name`` (decorator)."""

    def deco(fn):
        _RULES[name] = Rule(name, doc or (fn.__doc__ or "").strip(), fn)
        return fn

    return deco


def registered_rules() -> Dict[str, Rule]:
    """Name → rule, loading the built-in rule modules on first use."""
    from . import rules  # noqa: F401  (imports register via @rule)

    return dict(_RULES)


# ---------------------------------------------------------------------------
# Project context
# ---------------------------------------------------------------------------


class Project:
    """Lazily-parsed view of one source tree.

    ``root`` is the repo root; the *package root* (the directory holding
    ``query/``, ``kernels/``, ...) is ``root/src/repro`` when that exists,
    else ``root`` itself — which is what lets the test fixtures under
    ``tests/analysis_fixtures/`` mimic the real layout with three files."""

    def __init__(self, root):
        self.root = Path(root).resolve()
        pkg = self.root / "src" / "repro"
        self.pkg_root = pkg if pkg.is_dir() else self.root
        self._sources: Dict[Path, str] = {}
        self._trees: Dict[Path, ast.Module] = {}

    # -- files ---------------------------------------------------------------
    def pkg_path(self, rel: str) -> Path:
        return self.pkg_root / rel

    def has(self, rel: str) -> bool:
        return self.pkg_path(rel).is_file()

    def iter_pkg(self, pattern: str) -> List[Path]:
        return sorted(p for p in self.pkg_root.glob(pattern) if p.is_file())

    def rel(self, path: Path) -> str:
        path = Path(path).resolve()
        try:
            return path.relative_to(self.root).as_posix()
        except ValueError:
            return path.as_posix()

    # -- parsing -------------------------------------------------------------
    def source(self, path: Path) -> str:
        path = Path(path)
        if path not in self._sources:
            self._sources[path] = path.read_text()
        return self._sources[path]

    def tree(self, path: Path) -> ast.Module:
        path = Path(path)
        if path not in self._trees:
            self._trees[path] = ast.parse(
                self.source(path), filename=str(path)
            )
        return self._trees[path]

    # -- findings ------------------------------------------------------------
    def finding(self, rule_name: str, path: Path, node, message: str) -> Finding:
        line = getattr(node, "lineno", node if isinstance(node, int) else 0)
        return Finding(rule_name, self.rel(path), int(line), message)


def run_rules(
    project: Project, names: Optional[Sequence[str]] = None
) -> List[Finding]:
    """Run the named rules (default: all registered) over ``project``."""
    rules = registered_rules()
    if names is None:
        selected = list(rules.values())
    else:
        unknown = sorted(set(names) - set(rules))
        if unknown:
            raise KeyError(f"unknown rules: {unknown}")
        selected = [rules[n] for n in names]
    findings: List[Finding] = []
    for r in selected:
        findings.extend(r.fn(project))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule, f.message))


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


def load_baseline(path) -> Dict[str, Dict[str, object]]:
    """Identity → entry.  A missing file is an empty baseline."""
    path = Path(path)
    if not path.is_file():
        return {}
    data = json.loads(path.read_text())
    return dict(data.get("findings", {}))


def save_baseline(
    path,
    findings: Sequence[Finding],
    justification: str = "grandfathered",
) -> None:
    entries = {
        f.identity(): {
            "rule": f.rule,
            "path": f.path,
            "message": f.message,
            "justification": justification,
        }
        for f in findings
    }
    payload = {"version": 1, "findings": dict(sorted(entries.items()))}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def split_findings(
    findings: Sequence[Finding], baseline: Dict[str, Dict[str, object]]
):
    """``(new, known, stale_ids)``: findings not in the baseline, findings
    covered by it, and baseline entries that no longer fire (candidates for
    deletion — the gate reports them so baselines only shrink)."""
    new: List[Finding] = []
    known: List[Finding] = []
    seen = set()
    for f in findings:
        fid = f.identity()
        if fid in baseline:
            known.append(f)
            seen.add(fid)
        else:
            new.append(f)
    stale = sorted(set(baseline) - seen)
    return new, known, stale
