"""lock-discipline: protected state is only touched under its lock.

The engine's concurrency contract is conventional, not typed: a class owns
a lock attribute, every mutation of the state that lock protects happens
inside ``with self.<lock>:``, helpers that require the caller to hold the
lock carry a ``_locked`` suffix, and nothing slow runs while holding a
lock.  This rule machine-checks the convention per class:

* **lock attributes** — anything assigned ``threading.Lock()`` /
  ``RLock()`` / ``make_lock(...)``, or used as ``with self.<attr>:`` where
  the name looks like a lock (``*lock*`` / ``*_mu``);
* **protected attributes** — inferred as every ``self`` attribute mutated
  inside a ``with self.<lock>:`` block anywhere in the class, plus any
  attribute whose ``__init__`` assignment carries a ``# guarded by <lock>``
  comment (the explicit spelling for state whose *only* mutation site is
  the suspect one — inference alone cannot see those);
* **findings** — mutations of protected attributes outside a lock scope
  (``__init__``, ``__del__`` and ``*_locked`` methods are exempt),
  blocking calls (``open``, ``sleep``, ``wait``, subprocess/filesystem)
  while holding a lock, and statically inverted acquisition orders between
  nested ``with`` scopes.

Messages carry class.method + attribute, not line numbers, so baseline
identities survive unrelated edits.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..astutil import mutation_targets
from ..framework import Finding, Project, rule

RULE = "lock-discipline"

LOCK_FACTORIES = (
    "threading.Lock", "threading.RLock", "make_lock", "lockdep.make_lock",
)
_LOCKISH = re.compile(r"(^|_)(lock|mu|mutex)($|_)|lock", re.IGNORECASE)
_GUARDED = re.compile(r"self\.(\w+)[^#\n]*#\s*guarded by (\w+)")

#: calls that block or do I/O — forbidden while holding an engine lock
BLOCKING_NAMES = {"open", "input"}
BLOCKING_ATTRS = {"sleep", "wait"}
BLOCKING_DOTTED = {
    "os.makedirs", "os.remove", "os.replace", "os.rename", "os.fsync",
    "np.save", "np.load", "numpy.save", "numpy.load",
    "json.dump", "json.load",
    "time.sleep",
}
BLOCKING_PREFIXES = ("subprocess.", "shutil.", "requests.", "urllib.")

EXEMPT_METHODS = ("__init__", "__del__")


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _with_lock_attr(expr: ast.AST, lock_attrs: Set[str]) -> Optional[str]:
    """``with self.<attr>:`` → attr, when attr is a known/lockish lock."""
    if (
        isinstance(expr, ast.Attribute)
        and isinstance(expr.value, ast.Name)
        and expr.value.id == "self"
    ):
        if expr.attr in lock_attrs or _LOCKISH.search(expr.attr):
            return expr.attr
    return None


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    attrs: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fname = _dotted(node.value.func)
            if fname in LOCK_FACTORIES:
                for t in node.targets:
                    if (
                        isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                    ):
                        attrs.add(t.attr)
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                got = _with_lock_attr(item.context_expr, attrs)
                if got:
                    attrs.add(got)
    return attrs


def _annotated_guards(cls: ast.ClassDef, source_lines: List[str]) -> Set[str]:
    """Attributes annotated ``# guarded by <lock>`` inside the class."""
    end = getattr(cls, "end_lineno", None) or cls.lineno
    out: Set[str] = set()
    for line in source_lines[cls.lineno - 1:end]:
        m = _GUARDED.search(line)
        if m:
            out.add(m.group(1))
    return out


_SIMPLE = (ast.Assign, ast.AnnAssign, ast.AugAssign, ast.Delete, ast.Expr,
           ast.Return, ast.Raise, ast.Assert)


class _ClassScan:
    def __init__(self, cls_name: str, lock_attrs: Set[str]):
        self.cls_name = cls_name
        self.lock_attrs = lock_attrs
        #: (attr, method, line, held) for every self-attr mutation
        self.mutations: List[Tuple[str, str, int, frozenset]] = []
        #: (outer, inner, method, line) nested lock acquisitions
        self.nestings: List[Tuple[str, str, str, int]] = []
        #: (dotted_call, method, line, lock) blocking calls under a lock
        self.blocking: List[Tuple[str, str, int, str]] = []

    def scan_method(self, method: ast.FunctionDef) -> None:
        self._scan(method.body, frozenset(), method.name)

    def _scan(self, stmts: Iterable[ast.stmt], held: frozenset, m: str) -> None:
        for s in stmts:
            if isinstance(s, (ast.With, ast.AsyncWith)):
                got = set()
                for item in s.items:
                    attr = _with_lock_attr(item.context_expr, self.lock_attrs)
                    if attr:
                        got.add(attr)
                        for h in held:
                            if h != attr:
                                self.nestings.append((h, attr, m, s.lineno))
                    elif held:
                        # `with open(...)` while holding a lock is itself I/O
                        self._scan_blocking(item.context_expr, held, m)
                self._scan(s.body, held | frozenset(got), m)
            elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                continue  # closures: conservative, out of scope
            elif isinstance(s, _SIMPLE):
                for attr, node in mutation_targets(s):
                    self.mutations.append((attr, m, node.lineno, held))
                if held:
                    self._scan_blocking(s, held, m)
            elif isinstance(s, ast.If):
                self._scan(s.body, held, m)
                self._scan(s.orelse, held, m)
            elif isinstance(s, (ast.For, ast.AsyncFor, ast.While)):
                self._scan(s.body, held, m)
                self._scan(s.orelse, held, m)
            elif isinstance(s, ast.Try):
                self._scan(s.body, held, m)
                for h in s.handlers:
                    self._scan(h.body, held, m)
                self._scan(s.orelse, held, m)
                self._scan(s.finalbody, held, m)
            elif hasattr(s, "body") and isinstance(getattr(s, "body"), list):
                self._scan(s.body, held, m)  # match statements etc.

    def _scan_blocking(self, stmt: ast.stmt, held: frozenset, m: str) -> None:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            bad = False
            if isinstance(node.func, ast.Name) and node.func.id in BLOCKING_NAMES:
                bad = True
            elif isinstance(node.func, ast.Attribute):
                if node.func.attr in BLOCKING_ATTRS:
                    bad = True
                elif dotted is not None and (
                    dotted in BLOCKING_DOTTED
                    or dotted.startswith(BLOCKING_PREFIXES)
                ):
                    bad = True
            if bad:
                self.blocking.append(
                    (dotted or "open", m, node.lineno, sorted(held)[0])
                )


@rule(
    RULE,
    "lock-protected state is only mutated under its lock; no blocking "
    "calls or inverted acquisition orders while holding one",
)
def check_lock_discipline(project: Project):
    #: (cls, outer) → (inner, path, method) for the global inversion check
    order_edges: Dict[Tuple[str, str], List[Tuple[str, str, str]]] = {}
    findings: List[Finding] = []
    emitted: Set[str] = set()

    def emit(path, line, message):
        f = Finding(RULE, project.rel(path), line, message)
        if f.identity() not in emitted:
            emitted.add(f.identity())
            findings.append(f)

    for path in project.iter_pkg("**/*.py"):
        try:
            tree = project.tree(path)
        except SyntaxError:
            continue
        source_lines = project.source(path).splitlines()
        for cls in [n for n in tree.body if isinstance(n, ast.ClassDef)]:
            lock_attrs = _lock_attrs(cls)
            if not lock_attrs:
                continue
            scan = _ClassScan(cls.name, lock_attrs)
            methods = [
                n for n in cls.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            ]
            for method in methods:
                scan.scan_method(method)

            # protected = inferred (mutated under some lock) + annotated
            protected: Set[str] = set()
            for attr, _m, _line, held in scan.mutations:
                if held and attr not in lock_attrs:
                    protected.add(attr)
            protected |= _annotated_guards(cls, source_lines) - lock_attrs

            for attr, m, line, held in scan.mutations:
                if attr not in protected or held:
                    continue
                if m in EXEMPT_METHODS or m.endswith("_locked"):
                    continue
                emit(
                    path, line,
                    f"{cls.name}.{m}: mutation of lock-protected attribute "
                    f"'{attr}' outside a 'with self.<lock>' scope",
                )
            for dotted, m, line, lock in scan.blocking:
                emit(
                    path, line,
                    f"{cls.name}.{m}: blocking call {dotted}() while "
                    f"holding 'self.{lock}'",
                )
            for outer, inner, m, line in scan.nestings:
                order_edges.setdefault((cls.name, outer), []).append(
                    (inner, project.rel(path), m)
                )

    for (cls_name, outer), inners in sorted(order_edges.items()):
        for inner, rel_path, m in inners:
            rev = order_edges.get((cls_name, inner), [])
            if any(i == outer for i, _p, _m in rev):
                emit(
                    project.root / rel_path, 1,
                    f"{cls_name}: inconsistent lock order — both "
                    f"'{outer}' → '{inner}' and '{inner}' → '{outer}' "
                    "nestings exist (potential deadlock)",
                )
    return findings
