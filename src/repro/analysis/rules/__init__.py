"""Built-in engine-invariant lint rules.

Importing this package registers every rule with the framework registry
(:func:`repro.analysis.framework.registered_rules` does it lazily).

Rule catalog:

* ``backend-coverage`` — every ``*Sink`` class declared in ``query/ast.py``
  must be handled (or explicitly rejected) by an ``isinstance`` dispatch in
  *both* ``query/planner.py`` and ``query/execute.py``; new sinks cannot
  silently fall through to a wrong backend.
* ``cache-key-completeness`` — plan/op dataclasses in ``query/ast.py`` must
  be ``frozen=True`` and must not grow non-field attributes; every field
  must flow into the canonical ``_payload`` fingerprint.
* ``lock-discipline`` — attributes mutated under ``with self.<lock>`` (or
  annotated ``# guarded by <lock>``) are lock-protected: mutating them
  outside the lock, blocking calls while holding a lock, and statically
  inverted acquisition orders are findings.
* ``rng-time-hygiene`` — no ambient state (``time.time``, ``datetime.now``,
  ``random``/``np.random``, ``os.environ``) inside kernel bodies or the
  fingerprint/plan-key code paths.
"""

from . import backends  # noqa: F401
from . import cache_key  # noqa: F401
from . import hygiene  # noqa: F401
from . import locks  # noqa: F401
