"""cache-key-completeness: every plan field flows into the plan key.

The plan/result cache is keyed on ``LogicalPlan.key()`` — a hash of the
canonical payload.  A plan node carrying state that does *not* reach the
payload makes two semantically different plans collide on one cache entry:
the single worst class of bug this engine can have, and invisible to tests
that never construct the colliding pair.  Three checks on ``query/ast.py``:

1. every plan dataclass (op / sink / the plan itself) is ``frozen=True`` —
   mutable plan nodes can change after their key was computed;
2. no method grows a **non-field attribute** on a plan dataclass (via
   ``self.x = …``, ``object.__setattr__``, or ``setattr``) — such state is
   invisible to ``dataclasses.asdict`` and therefore unkeyed.  Private
   underscore attributes on the *source algebra* classes (resolution
   memos) are exempt because sources are keyed by data fingerprint, not by
   the plan payload;
3. the canonical payload covers every field: if ``_payload`` (or ``key``)
   is written in terms of ``dataclasses.asdict``/``astuple`` all fields
   flow by construction; if it reads attributes explicitly, the read set
   must cover every field of every plan dataclass.
"""

from __future__ import annotations

import ast
from typing import List, Set

from ..astutil import (
    annotated_fields,
    dataclass_decoration,
    dataclass_is_frozen,
    dotted_name,
)
from ..framework import Finding, Project, rule

AST_FILE = "query/ast.py"
RULE = "cache-key-completeness"


def plan_dataclasses(tree: ast.Module) -> List[ast.ClassDef]:
    return [
        n
        for n in tree.body
        if isinstance(n, ast.ClassDef) and dataclass_decoration(n) is not None
    ]


def _setattr_names(cls: ast.ClassDef) -> List[tuple]:
    """(attr, line) for every attribute written on ``self`` anywhere in the
    class's methods, through any spelling."""
    out = []
    for method in cls.body:
        if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(method):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (
                    node.targets
                    if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    if (
                        isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                    ):
                        out.append((t.attr, node.lineno))
            elif isinstance(node, ast.Call):
                fname = dotted_name(node.func)
                if fname in ("object.__setattr__", "setattr") and (
                    len(node.args) >= 2
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id == "self"
                    and isinstance(node.args[1], ast.Constant)
                ):
                    out.append((str(node.args[1].value), node.lineno))
    return out


def _payload_reads(tree: ast.Module):
    """``(uses_asdict, attribute_read_set)`` for the canonicalization
    function — ``_payload`` if defined, else ``key``."""
    fn = None
    for cls in tree.body:
        if not isinstance(cls, ast.ClassDef):
            continue
        for method in cls.body:
            if isinstance(method, ast.FunctionDef) and method.name == "_payload":
                fn = method
                break
        if fn is None:
            for method in cls.body:
                if isinstance(method, ast.FunctionDef) and method.name == "key":
                    fn = method
    if fn is None:
        return None
    uses_asdict = False
    reads: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname in (
                "dataclasses.asdict", "asdict",
                "dataclasses.astuple", "astuple",
            ):
                uses_asdict = True
        if isinstance(node, ast.Attribute):
            reads.add(node.attr)
    return uses_asdict, reads


@rule(
    RULE,
    "plan/op dataclasses are frozen, grow no unkeyed attributes, and every "
    "field reaches the canonical payload",
)
def check_cache_keys(project: Project):
    if not project.has(AST_FILE):
        return
    path = project.pkg_path(AST_FILE)
    tree = project.tree(path)
    rel = project.rel(path)
    classes = plan_dataclasses(tree)
    if not classes:
        return

    payload = _payload_reads(tree)
    for cls in classes:
        dec = dataclass_decoration(cls)
        if not dataclass_is_frozen(dec):
            yield Finding(
                RULE, rel, cls.lineno,
                f"plan dataclass {cls.name} is not frozen=True; mutable "
                "plan nodes can change after their cache key is computed",
            )
        fields = set(annotated_fields(cls))
        for attr, line in _setattr_names(cls):
            if attr in fields or attr.startswith("_"):
                continue  # field normalization / private memo
            yield Finding(
                RULE, rel, line,
                f"unkeyed plan field: {cls.name}.{attr} is assigned in a "
                "method but is not a dataclass field, so it never reaches "
                "the canonical payload (cache-key collision)",
            )
        if payload is not None:
            uses_asdict, reads = payload
            if not uses_asdict:
                for f in sorted(fields - reads):
                    yield Finding(
                        RULE, rel, cls.lineno,
                        f"field {cls.name}.{f} does not flow into the "
                        "canonical payload (the payload function reads "
                        "attributes explicitly and never reads it)",
                    )
