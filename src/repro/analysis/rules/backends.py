"""backend-coverage: every sink is dispatched by every physical backend.

The planner and the executor both branch on the sink's class.  A new sink
added to ``query/ast.py`` that neither file mentions would fall through
``isinstance`` ladders silently — historically the exact spot correctness
regressions hide when backends multiply.  The rule:

* collects every ``*Sink`` class defined in ``query/ast.py``;
* resolves the module's sink *aliases* — tuple aliases like
  ``TOPOLOGY_SINKS = (DFGSink, ...)`` and the ``Sink = Union[...]`` type —
  so dispatch through an alias (or a ``+``-concatenation of aliases)
  covers all members;
* scans ``query/planner.py`` and ``query/execute.py`` for the names
  referenced by ``isinstance(..., X)`` second arguments;
* reports every sink missing from either file.

Handling and *explicit rejection* look identical to this rule — both are an
``isinstance`` mention — which is exactly the invariant: the backend must
*decide* about every sink, not ignore it.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set

from ..astutil import flatten_name_refs
from ..framework import Finding, Project, rule

AST_FILE = "query/ast.py"
BACKEND_FILES = ("query/planner.py", "query/execute.py")


def sink_classes(tree: ast.Module) -> List[str]:
    return [
        n.name
        for n in tree.body
        if isinstance(n, ast.ClassDef) and n.name.endswith("Sink")
    ]


def sink_aliases(tree: ast.Module, sinks: Set[str]) -> Dict[str, Set[str]]:
    """Module-level names that stand for groups of sink classes: tuple/list
    aliases, ``Union[...]`` aliases, and ``+``-concatenations of either."""
    aliases: Dict[str, Set[str]] = {}

    def resolve(node: ast.AST) -> Set[str]:
        out: Set[str] = set()
        if isinstance(node, (ast.Tuple, ast.List)):
            for e in node.elts:
                out |= resolve(e)
        elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
            out |= resolve(node.left) | resolve(node.right)
        elif isinstance(node, ast.Subscript):
            # Union[A, B, ...]
            out |= resolve(node.slice)
        elif isinstance(node, ast.Name):
            if node.id in sinks:
                out.add(node.id)
            elif node.id in aliases:
                out |= aliases[node.id]
        return out

    for node in tree.body:
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
        ):
            members = resolve(node.value)
            if members:
                aliases[node.targets[0].id] = members
    return aliases


def covered_sinks(
    tree: ast.Module, sinks: Set[str], aliases: Dict[str, Set[str]]
) -> Set[str]:
    """Sink classes mentioned by any ``isinstance`` dispatch in ``tree``
    (directly, through an alias, or through a local re-aliasing of one)."""
    local = dict(aliases)
    local.update(sink_aliases(tree, sinks))  # file-local regroupings
    covered: Set[str] = set()
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in ("isinstance", "issubclass")
            and len(node.args) == 2
        ):
            for name in flatten_name_refs(node.args[1]):
                if name in sinks:
                    covered.add(name)
                elif name in local:
                    covered |= local[name] & sinks
    return covered


@rule(
    "backend-coverage",
    "every Sink class is handled or explicitly rejected by every physical "
    "backend dispatcher",
)
def check_backend_coverage(project: Project):
    if not project.has(AST_FILE):
        return
    ast_path = project.pkg_path(AST_FILE)
    ast_tree = project.tree(ast_path)
    sinks = set(sink_classes(ast_tree))
    if not sinks:
        return
    aliases = sink_aliases(ast_tree, sinks)
    for rel in BACKEND_FILES:
        if not project.has(rel):
            continue
        path = project.pkg_path(rel)
        covered = covered_sinks(project.tree(path), sinks, aliases)
        for sink in sorted(sinks - covered):
            yield Finding(
                "backend-coverage",
                project.rel(path),
                1,
                f"sink {sink} (declared in {AST_FILE}) is neither handled "
                f"nor explicitly rejected by any isinstance dispatch in "
                f"{rel}",
            )
