"""rng-time-hygiene: no ambient state in kernel or fingerprint paths.

Kernels must be pure functions of their operands (bit-identity against the
oracle is the whole correctness story) and fingerprints must be pure
functions of the data they summarize (a cache key that reads the clock or
the environment invalidates — or worse, *fails* to invalidate — on its
own).  This rule bans calls that smuggle ambient state into those paths:
wall/monotonic clocks, RNGs, environment reads.

Scope: ``kernels/*/kernel.py`` and ``kernels/*/ops.py`` (kernel bodies and
their wrappers — ``kernels/timing.py`` instruments *around* calls and is
deliberately out of scope), plus ``query/cache.py`` and ``query/ast.py``
(the two fingerprint/plan-key modules).
"""

from __future__ import annotations

import ast
from typing import List, Optional

from ..framework import Finding, Project, rule

RULE = "rng-time-hygiene"

SCOPE_GLOBS = (
    "kernels/*/kernel.py",
    "kernels/*/ops.py",
    "query/cache.py",
    "query/ast.py",
)

BANNED_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
    "os.getenv", "os.environ.get", "os.urandom",
    "uuid.uuid1", "uuid.uuid4",
}
BANNED_PREFIXES = (
    "random.", "np.random.", "numpy.random.", "jax.random.", "secrets.",
)


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@rule(
    RULE,
    "no clocks, RNGs, or environment reads inside kernel bodies or the "
    "fingerprint/plan-key code paths",
)
def check_hygiene(project: Project):
    for glob in SCOPE_GLOBS:
        for path in project.iter_pkg(glob):
            tree = project.tree(path)
            rel_in_pkg = path.relative_to(project.pkg_root).as_posix()
            for node in ast.walk(tree):
                banned = None
                if isinstance(node, ast.Call):
                    dotted = _dotted(node.func)
                    if dotted is not None and (
                        dotted in BANNED_CALLS
                        or dotted.startswith(BANNED_PREFIXES)
                    ):
                        banned = f"call to {dotted}()"
                elif isinstance(node, ast.Subscript):
                    if _dotted(node.value) == "os.environ":
                        banned = "os.environ[...] read"
                if banned is not None:
                    yield Finding(
                        RULE, project.rel(path), node.lineno,
                        f"{banned} in {rel_in_pkg} — ambient state is "
                        "banned in kernel and fingerprint code paths",
                    )
