"""Small AST helpers shared by the lint rules."""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

__all__ = [
    "dotted_name",
    "flatten_name_refs",
    "dataclass_decoration",
    "annotated_fields",
    "self_attr_root",
    "MUTATING_METHODS",
]

#: container methods that mutate their receiver in place — calling one on a
#: lock-protected attribute counts as a mutation for the lock rule
MUTATING_METHODS = frozenset({
    "append", "appendleft", "extend", "insert", "remove", "pop", "popleft",
    "popitem", "clear", "update", "setdefault", "add", "discard",
    "move_to_end", "rotate",
})


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def flatten_name_refs(node: ast.AST) -> List[str]:
    """Class-name references in an ``isinstance`` second argument: a bare
    Name, an Attribute tail (``mod.DFGSink`` → ``DFGSink``), a Tuple/List of
    them, or a ``+`` concatenation of alias tuples."""
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        return [node.attr]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in node.elts:
            out.extend(flatten_name_refs(e))
        return out
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        return flatten_name_refs(node.left) + flatten_name_refs(node.right)
    return []


def dataclass_decoration(cls: ast.ClassDef) -> Optional[ast.AST]:
    """The ``dataclass`` / ``dataclasses.dataclass`` decorator node (bare or
    called), or None."""
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted_name(target)
        if name in ("dataclass", "dataclasses.dataclass"):
            return dec
    return None


def dataclass_is_frozen(dec: ast.AST) -> bool:
    if not isinstance(dec, ast.Call):
        return False
    for kw in dec.keywords:
        if kw.arg == "frozen":
            return isinstance(kw.value, ast.Constant) and kw.value.value is True
    return False


def annotated_fields(cls: ast.ClassDef) -> List[str]:
    """Dataclass field names: class-body annotated assignments (skipping
    ClassVar annotations is unnecessary here — the plan nodes use none)."""
    out = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            out.append(node.target.id)
    return out


def self_attr_root(node: ast.AST) -> Optional[str]:
    """The root attribute of a ``self.<attr>…`` access: ``self.x`` → x,
    ``self.x[k]`` → x, ``self.x.y`` → x.  None for non-self targets."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
        ):
            return node.attr
        node = node.value
    return None


def mutation_targets(stmt: ast.AST) -> Iterator[Tuple[str, ast.AST]]:
    """Yield ``(self_attr, node)`` for every mutation of a ``self``
    attribute inside ``stmt`` (without descending into nested function or
    class definitions): assignments, augmented assignments, deletions, and
    in-place container-method calls."""
    for node in _walk_shallow(stmt):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for t in targets:
                for leaf in _unpack(t):
                    root = self_attr_root(leaf)
                    if root is not None:
                        yield root, node
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                root = self_attr_root(t)
                if root is not None:
                    yield root, node
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in MUTATING_METHODS:
                root = self_attr_root(node.func.value)
                if root is not None:
                    yield root, node
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            # object.__setattr__(self, "x", v) handled by the caller; here
            # cover setattr(self, "x", v)
            if (
                node.func.id == "setattr"
                and node.args
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id == "self"
                and len(node.args) >= 2
                and isinstance(node.args[1], ast.Constant)
            ):
                yield str(node.args[1].value), node


def _unpack(target: ast.AST) -> Iterator[ast.AST]:
    if isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            yield from _unpack(e)
    else:
        yield target


def _walk_shallow(stmt: ast.AST) -> Iterator[ast.AST]:
    """ast.walk that does not descend into nested def/class bodies."""
    stack = [stmt]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(
                child,
                (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda),
            ) and child is not stmt:
                continue
            stack.append(child)
