"""Runtime lock-order sanitizer — "lockdep-lite" for the engine's locks.

The engine has a handful of independent locks (QueryEngine memos,
QueryCache, GraphStore registry, MetricsRegistry, EventCollector,
QueryService registry + per-log append locks).  Deadlocks between them
would only ever manifest under concurrency the unit tests may not hit, so
— like the kernel's lockdep — this module catches *ordering* violations on
any single-threaded pass through the code:

* every lock site constructs its lock via :func:`make_lock(name)`;
* with ``REPRO_LOCKDEP=1`` in the environment the factory returns a
  wrapping lock that records, per thread, the stack of held locks and
  grows a global acquired-before graph over lock *names*;
* acquiring ``B`` while holding ``A`` adds the edge ``A → B``; if ``B``
  can already reach ``A`` in the graph, some other code path acquires
  them in the opposite order and :class:`LockOrderError` is raised —
  whether or not the two paths ever ran concurrently;
* re-acquiring a lock instance already held by the current thread raises
  immediately (a plain ``threading.Lock`` would deadlock).

Same-*name* different-instance pairs (e.g. the per-log append locks) are
exempt from ordering edges: they form a family whose members are never
nested.  Without the env var, :func:`make_lock` returns a plain
``threading.Lock`` — zero overhead in production.
"""

from __future__ import annotations

import os
import threading
from typing import Dict, List, Set, Tuple

__all__ = [
    "LockOrderError",
    "LockdepLock",
    "make_lock",
    "lockdep_enabled",
    "reset",
    "order_edges",
    "held_locks",
]


class LockOrderError(RuntimeError):
    """Inconsistent lock acquisition order (potential deadlock)."""


def lockdep_enabled() -> bool:
    return os.environ.get("REPRO_LOCKDEP", "") == "1"


# acquired-before graph over lock names; guarded by its own plain lock
_graph_mu = threading.Lock()
_edges: Dict[str, Set[str]] = {}
_tls = threading.local()


def _stack() -> List["LockdepLock"]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _reaches(src: str, dst: str) -> bool:
    """Is ``dst`` reachable from ``src`` in the acquired-before graph?
    Caller holds ``_graph_mu``."""
    seen = set()
    frontier = [src]
    while frontier:
        n = frontier.pop()
        if n == dst:
            return True
        if n in seen:
            continue
        seen.add(n)
        frontier.extend(_edges.get(n, ()))
    return False


class LockdepLock:
    """A ``threading.Lock`` recording per-thread hold stacks and global
    acquisition order; drop-in for the subset of the Lock API the engine
    uses (``with``, ``acquire``/``release``, ``locked``)."""

    __slots__ = ("name", "_inner")

    def __init__(self, name: str):
        self.name = name
        self._inner = threading.Lock()

    def _before_acquire(self) -> None:
        held = _stack()
        for h in held:
            if h is self:
                raise LockOrderError(
                    f"recursive acquisition of lock {self.name!r} "
                    "(non-reentrant; this would deadlock)"
                )
        with _graph_mu:
            for h in held:
                if h.name == self.name:
                    continue  # same-name family members are never ordered
                if _reaches(self.name, h.name):
                    raise LockOrderError(
                        f"lock order inversion: acquiring {self.name!r} "
                        f"while holding {h.name!r}, but "
                        f"{self.name!r} → … → {h.name!r} was recorded on "
                        "another code path"
                    )
                _edges.setdefault(h.name, set()).add(self.name)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._before_acquire()
        got = self._inner.acquire(blocking, timeout)
        if got:
            _stack().append(self)
        return got

    def release(self) -> None:
        st = _stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] is self:
                del st[i]
                break
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"LockdepLock({self.name!r})"


def make_lock(name: str):
    """The engine's lock constructor: plain ``threading.Lock`` normally, a
    :class:`LockdepLock` under ``REPRO_LOCKDEP=1``."""
    if lockdep_enabled():
        return LockdepLock(name)
    return threading.Lock()


def reset() -> None:
    """Clear the global order graph (test isolation)."""
    with _graph_mu:
        _edges.clear()


def order_edges() -> Set[Tuple[str, str]]:
    """Snapshot of the recorded acquired-before edges."""
    with _graph_mu:
        return {(a, b) for a, bs in _edges.items() for b in bs}


def held_locks() -> List[str]:
    """Names of locks held by the current thread (innermost last)."""
    return [l.name for l in _stack()]
