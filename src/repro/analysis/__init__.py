"""repro.analysis — engine-invariant static analysis + runtime sanitizers.

Three layers keep the engine's correctness contracts machine-checked:

* the AST lint (:mod:`.framework` + :mod:`.rules`) — backend coverage,
  cache-key completeness, lock discipline, RNG/time hygiene — run as
  ``python -m repro.analysis`` (CI gates on ``--fail-on-new`` against the
  committed ``analysis_baseline.json``);
* the Pallas resource checker (:mod:`.kernels_check`) — symbolic VMEM
  bounds and tile-alignment checks over the kernels' BlockSpecs, asserted
  by every ``pick_blocks`` and reported into ``BENCH_analysis.json``;
* the runtime lock-order sanitizer (:mod:`.lockdep`) — under
  ``REPRO_LOCKDEP=1`` every engine lock records acquisition order and
  inversions fail fast.

This module keeps imports lazy: :mod:`.lockdep` and
:mod:`.kernels_check` are stdlib-only so the engine (which imports them at
module load) never pulls the lint framework in.
"""

from .lockdep import LockOrderError, make_lock  # stdlib-only, engine-facing

__all__ = [
    "LockOrderError",
    "make_lock",
    "Finding",
    "Project",
    "run_rules",
    "KernelResourceError",
    "validate_blocks",
]


def __getattr__(name):  # lazy: the lint stack is CLI/test-facing
    if name in ("Finding", "Project", "run_rules"):
        from . import framework

        return getattr(framework, name)
    if name in ("KernelResourceError", "validate_blocks"):
        from . import kernels_check

        return getattr(kernels_check, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
