"""``python -m repro.analysis`` — the lint CLI (also the
``repro-analysis`` console script).

Exit status: 0 when every finding is covered by the baseline, 1 when new
findings exist (the CI gate), 2 on usage errors.

Examples::

    python -m repro.analysis                      # lint, gate on baseline
    python -m repro.analysis --json               # machine-readable
    python -m repro.analysis --write-baseline     # grandfather the current
                                                  # findings (justify them!)
    python -m repro.analysis --kernel-report BENCH_analysis.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .framework import (
    Project,
    load_baseline,
    registered_rules,
    run_rules,
    save_baseline,
    split_findings,
)

BASELINE_NAME = "analysis_baseline.json"


def find_root(start: Path) -> Path:
    """Walk up from ``start`` to the directory holding ``src/repro``."""
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    return cur


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro-analysis",
        description="engine-invariant static analysis for the repro tree",
    )
    parser.add_argument(
        "--root", type=Path, default=None,
        help="project root (default: nearest ancestor with src/repro)",
    )
    parser.add_argument(
        "--baseline", type=Path, default=None,
        help=f"baseline file (default: <root>/{BASELINE_NAME})",
    )
    parser.add_argument(
        "--fail-on-new", action="store_true",
        help="exit 1 on findings not in the baseline (this is also the "
        "default behavior; the flag makes CI intent explicit)",
    )
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="write the current findings to the baseline file and exit 0",
    )
    parser.add_argument(
        "--rules", default=None,
        help="comma-separated subset of rules to run",
    )
    parser.add_argument("--json", action="store_true", dest="as_json")
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    parser.add_argument(
        "--kernel-report", type=Path, default=None, metavar="PATH",
        help="write the Pallas kernel VMEM/tiling report to PATH and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for name, r in sorted(registered_rules().items()):
            print(f"{name}: {r.doc}")
        return 0

    if args.kernel_report is not None:
        from .kernels_check import build_report

        report = build_report()
        args.kernel_report.write_text(json.dumps(report, indent=2) + "\n")
        worst = max(
            sc["max_vmem_bytes"]
            for k in report["kernels"].values()
            for sc in k["scenarios"]
        )
        print(
            f"wrote {args.kernel_report} — worst-case VMEM bound "
            f"{worst} B of {report['vmem_limit_bytes']} B"
        )
        return 0

    root = args.root or find_root(Path.cwd())
    project = Project(root)
    names = args.rules.split(",") if args.rules else None
    try:
        findings = run_rules(project, names)
    except KeyError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    baseline_path = args.baseline or (project.root / BASELINE_NAME)
    if args.write_baseline:
        save_baseline(baseline_path, findings, justification="grandfathered")
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, known, stale = split_findings(findings, baseline)

    if args.as_json:
        print(json.dumps({
            "new": [f.to_dict() for f in new],
            "baselined": [f.to_dict() for f in known],
            "stale_baseline_ids": stale,
        }, indent=2))
    else:
        for f in new:
            print(f.format())
        if known:
            print(f"({len(known)} baselined finding(s) suppressed)")
        for fid in stale:
            entry = baseline[fid]
            print(
                f"stale baseline entry {fid} "
                f"({entry.get('rule')}: {entry.get('message')}) — "
                "the finding no longer fires; delete it"
            )
        if not new:
            print(
                f"analysis clean: {len(findings)} finding(s), all baselined"
                if findings else "analysis clean: no findings"
            )
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
