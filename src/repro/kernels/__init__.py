"""Pallas TPU kernels for compute hot-spots the paper optimizes.

The paper's single hot loop is the in-store DFG computation (its Cypher
MATCH); :mod:`repro.kernels.dfg_count` is the TPU-native version (one-hot
MXU accumulation + fused WHERE-clause dicing).  :mod:`repro.kernels.
segment_count` covers the graph tier's node-degree histograms and
:mod:`repro.kernels.align_dp` the conformance tier's alignment DP.
"""

from . import align_dp, dfg_count, segment_count

__all__ = ["align_dp", "dfg_count", "segment_count"]
