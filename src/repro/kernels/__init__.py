"""Pallas TPU kernels for compute hot-spots the paper optimizes.

The paper's single hot loop is the in-store DFG computation (its Cypher
MATCH); :mod:`repro.kernels.dfg_count` is the TPU-native version (one-hot
MXU accumulation + fused WHERE-clause dicing).
"""

from . import dfg_count

__all__ = ["dfg_count"]
