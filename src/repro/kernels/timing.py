"""Wall-clock timing hook for the Pallas kernel entry points.

Each public op (``dfg_count``, ``dfg_count_diced``, ``segment_count``,
``align_dp``) is wrapped once at import time; every call lands in the
process-global :func:`repro.obs.kernel_registry` as a
``kernel_seconds{kernel=<name>}`` histogram.  Kernels are process-wide
jitted callables shared by every engine, so their timings live in the
global registry rather than any per-engine one — the engine merges both
in ``metrics_snapshot()``.

The wrapper blocks on the device result (``block_until_ready``) so the
histogram records true wall time, not async dispatch time; callers
consume the result synchronously anyway, so nothing is serialized that
was not already.  The first observation of a jitted kernel includes its
compile time — that *is* the wall time the triggering query paid.
"""

from __future__ import annotations

import functools
from time import perf_counter

from repro.obs.metrics import kernel_registry

__all__ = ["timed_kernel"]


def timed_kernel(name: str, fn):
    """Wrap a kernel entry point; records wall seconds per call."""
    hist = kernel_registry().histogram("kernel_seconds", kernel=name)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        t0 = perf_counter()
        out = fn(*args, **kwargs)
        ready = getattr(out, "block_until_ready", None)
        if ready is not None:
            out = ready()
        hist.observe(perf_counter() - t0)
        return out

    wrapper.__wrapped_kernel__ = fn
    return wrapper
