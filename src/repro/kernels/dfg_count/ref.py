"""Pure-jnp oracle for the dfg_count Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["dfg_count_ref", "dfg_count_diced_ref"]


@functools.partial(jax.jit, static_argnames=("num_activities",))
def dfg_count_ref(
    src: jax.Array, dst: jax.Array, valid: jax.Array, *, num_activities: int
) -> jax.Array:
    psi = jnp.zeros((num_activities, num_activities), dtype=jnp.int32)
    v = valid.astype(jnp.int32)
    # clip ids so padded/garbage rows can't index OOB (they carry v == 0)
    s = jnp.clip(src, 0, num_activities - 1)
    d = jnp.clip(dst, 0, num_activities - 1)
    # rows with ids outside range contribute 0
    in_range = (src >= 0) & (src < num_activities) & (dst >= 0) & (dst < num_activities)
    return psi.at[s, d].add(v * in_range.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("num_activities",))
def dfg_count_diced_ref(
    src: jax.Array,
    dst: jax.Array,
    valid: jax.Array,
    ts_src: jax.Array,
    ts_dst: jax.Array,
    window: jax.Array,
    *,
    num_activities: int,
) -> jax.Array:
    t0, t1 = window[0], window[1]
    v = (
        valid
        & (ts_src >= t0) & (ts_src < t1)
        & (ts_dst >= t0) & (ts_dst < t1)
    )
    return dfg_count_ref(src, dst, v, num_activities=num_activities)
