"""Pallas TPU kernel for DFG counting (Algorithm 1's hot loop).

GPU/graph-DB intuition would scatter-add each directly-follows pair into
``Ψ[src, dst]`` — scatters serialize on TPU.  The TPU-native formulation
builds one-hot tiles **in VMEM** from the integer id blocks and accumulates

    Ψ[i·BA:(i+1)·BA, j·BA:(j+1)·BA] += OneHot_src(block)ᵀ · OneHot_dst(block)

on the MXU.  Grid ``(A/BA_src, A/BA_dst, E/BE)`` with the event dimension
innermost (fastest-varying) so each output tile stays resident while the
event stream flows through; the tile is zeroed at the first event block
(standard Pallas accumulation pattern).

VMEM working set per step (BE=1024, BA=128, f32):
  2 one-hots 1024×128×4 B = 1 MiB + out tile 64 KiB  « 16 MiB v5e VMEM.
MXU alignment: BE multiple of 8 (sublane), BA multiple of 128 (lane).

The fused **dicing** variant additionally streams the pair timestamps and
applies ``t0 ≤ t < t1`` in-register — the paper's WHERE clause at zero extra
HBM traffic (no filtered copy is ever materialized).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dfg_kernel", "dfg_dice_kernel", "dfg_count_pallas"]


def dfg_kernel(src_ref, dst_ref, valid_ref, out_ref, *, block_a: int):
    """One grid step: accumulate a (BA, BA) tile over one event block."""
    i = pl.program_id(0)  # src-activity tile
    j = pl.program_id(1)  # dst-activity tile
    e = pl.program_id(2)  # event block (innermost)

    @pl.when(e == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    src = src_ref[...]  # (BE,) int32
    dst = dst_ref[...]
    valid = valid_ref[...]

    a0 = i * block_a
    b0 = j * block_a
    cols = jax.lax.broadcasted_iota(jnp.int32, (src.shape[0], block_a), 1)
    oh_src = (src[:, None] == (a0 + cols)) & valid[:, None]
    oh_dst = dst[:, None] == (b0 + cols)
    out_ref[...] += jax.lax.dot_general(
        oh_src.astype(jnp.float32),
        oh_dst.astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over events
        preferred_element_type=jnp.float32,
    )


def dfg_dice_kernel(
    src_ref, dst_ref, valid_ref, ts_src_ref, ts_dst_ref, win_ref, out_ref,
    *, block_a: int
):
    """Fused dicing: valid &= (t0 <= t_src) & (t_src < t1) & same for dst.

    Paper semantics — both endpoints of the pair must be inside the window."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    src = src_ref[...]
    dst = dst_ref[...]
    t0 = win_ref[0, 0]
    t1 = win_ref[0, 1]
    ts_s = ts_src_ref[...]
    ts_d = ts_dst_ref[...]
    valid = (
        valid_ref[...]
        & (ts_s >= t0) & (ts_s < t1)
        & (ts_d >= t0) & (ts_d < t1)
    )

    a0 = i * block_a
    b0 = j * block_a
    cols = jax.lax.broadcasted_iota(jnp.int32, (src.shape[0], block_a), 1)
    oh_src = (src[:, None] == (a0 + cols)) & valid[:, None]
    oh_dst = dst[:, None] == (b0 + cols)
    out_ref[...] += jax.lax.dot_general(
        oh_src.astype(jnp.float32),
        oh_dst.astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dfg_count_pallas(
    src: jax.Array,
    dst: jax.Array,
    valid: jax.Array,
    *,
    num_activities_padded: int,
    block_e: int,
    block_a: int,
    interpret: bool,
    ts_src: jax.Array | None = None,
    ts_dst: jax.Array | None = None,
    window: jax.Array | None = None,
) -> jax.Array:
    """Raw pallas_call wrapper.  All shapes must be pre-padded:
    len(src) % block_e == 0, num_activities_padded % block_a == 0."""
    e_total = src.shape[0]
    a_pad = num_activities_padded
    grid = (a_pad // block_a, a_pad // block_a, e_total // block_e)

    ev_spec = pl.BlockSpec((block_e,), lambda i, j, e: (e,))
    out_spec = pl.BlockSpec((block_a, block_a), lambda i, j, e: (i, j))
    out_shape = jax.ShapeDtypeStruct((a_pad, a_pad), jnp.float32)

    if window is None:
        kern = functools.partial(dfg_kernel, block_a=block_a)
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[ev_spec, ev_spec, ev_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(src, dst, valid)

    win_spec = pl.BlockSpec((1, 2), lambda i, j, e: (0, 0))
    kern = functools.partial(dfg_dice_kernel, block_a=block_a)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[ev_spec, ev_spec, ev_spec, ev_spec, ev_spec, win_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(src, dst, valid, ts_src, ts_dst, window)
