from . import ops, ref
from .ops import dfg_count, dfg_count_diced, pick_blocks
from .ref import dfg_count_diced_ref, dfg_count_ref

__all__ = [
    "ops", "ref",
    "dfg_count", "dfg_count_diced", "pick_blocks",
    "dfg_count_ref", "dfg_count_diced_ref",
]
