"""Jitted public wrappers around the dfg_count Pallas kernel.

Handles padding (events to BE, activities to BA), backend selection
(interpret mode on CPU — kernel body runs in Python for validation; compiled
Mosaic on TPU), and block-size auto-tuning from a VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.analysis.kernels_check import validate_blocks

from .kernel import dfg_count_pallas

__all__ = ["dfg_count", "dfg_count_diced", "pick_blocks"]


def pick_blocks(
    num_activities: int, vmem_budget_bytes: int = 8 << 20
) -> tuple[int, int]:
    """Choose (block_e, block_a).

    block_a: lane-aligned tile of the activity axis (≤512 keeps the output
    tile small); block_e: as large as the VMEM budget allows for the two
    one-hot tiles (f32) — bigger BE amortizes the output-tile revisits.
    """
    block_a = 128
    while block_a < 512 and block_a < num_activities:
        block_a *= 2
    block_a = min(block_a, 512)
    # 2 one-hot tiles of (BE, BA) f32 + out (BA, BA) f32 within budget
    be = (vmem_budget_bytes - 4 * block_a * block_a) // (2 * 4 * block_a)
    block_e = max(512, min(4096, int(be) // 512 * 512))
    # static resource check: BlockSpec VMEM bound + MXU/VPU tile alignment
    validate_blocks("dfg_count", block_e=block_e, block_a=block_a)
    return block_e, block_a


def _pad_inputs(src, dst, valid, block_e):
    n = src.shape[0]
    pad = (-n) % block_e
    if n == 0:
        pad = block_e
    if pad:
        src = jnp.pad(src, (0, pad))
        dst = jnp.pad(dst, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    return src, dst, valid


@functools.partial(
    jax.jit,
    static_argnames=("num_activities", "block_e", "block_a", "interpret"),
)
def dfg_count(
    src: jax.Array,
    dst: jax.Array,
    valid: jax.Array,
    *,
    num_activities: int,
    block_e: int | None = None,
    block_a: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """DFG count matrix (num_activities², int32) from pair columns."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    auto_e, auto_a = pick_blocks(num_activities)
    block_e = block_e or auto_e
    block_a = block_a or auto_a
    a_pad = max(block_a, -(-num_activities // block_a) * block_a)

    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    valid = valid.astype(jnp.bool_)
    # padded ids land outside [0, A): mark them invalid via the id compare
    # (padded src/dst are 0 — rely on the valid mask added by padding=False)
    src, dst, valid = _pad_inputs(src, dst, valid, block_e)

    out = dfg_count_pallas(
        src, dst, valid,
        num_activities_padded=a_pad,
        block_e=block_e,
        block_a=block_a,
        interpret=interpret,
    )
    return out[:num_activities, :num_activities].astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("num_activities", "block_e", "block_a", "interpret"),
)
def dfg_count_diced(
    src: jax.Array,
    dst: jax.Array,
    valid: jax.Array,
    ts_src: jax.Array,
    ts_dst: jax.Array,
    window: jax.Array,  # shape (2,): [t0, t1)
    *,
    num_activities: int,
    block_e: int | None = None,
    block_a: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused WHERE-clause dicing + counting (paper §4, Experiment 2)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    auto_e, auto_a = pick_blocks(num_activities)
    block_e = block_e or auto_e
    block_a = block_a or auto_a
    a_pad = max(block_a, -(-num_activities // block_a) * block_a)

    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    valid = valid.astype(jnp.bool_)
    ts_src = ts_src.astype(jnp.float32)
    ts_dst = ts_dst.astype(jnp.float32)
    n = src.shape[0]
    pad = (-n) % block_e or (block_e if n == 0 else 0)
    if pad:
        src = jnp.pad(src, (0, pad))
        dst = jnp.pad(dst, (0, pad))
        valid = jnp.pad(valid, (0, pad))
        ts_src = jnp.pad(ts_src, (0, pad))
        ts_dst = jnp.pad(ts_dst, (0, pad))

    out = dfg_count_pallas(
        src, dst, valid,
        num_activities_padded=a_pad,
        block_e=block_e,
        block_a=block_a,
        interpret=interpret,
        ts_src=ts_src,
        ts_dst=ts_dst,
        window=window.astype(jnp.float32).reshape(1, 2),
    )
    return out[:num_activities, :num_activities].astype(jnp.int32)

# Timing hook: every call lands in the process-global kernel registry as
# kernel_seconds{kernel=...} (see repro.kernels.timing).
from ..timing import timed_kernel

dfg_count = timed_kernel("dfg_count", dfg_count)
dfg_count_diced = timed_kernel("dfg_count_diced", dfg_count_diced)
