"""Jitted public wrapper + numpy reference for the align_dp Pallas kernel.

Handles padding (variants to BV, layers/states to lane multiples) and
backend selection (numpy fallback on CPU — the kernel body is additionally
interpret-validated against it in the tests; compiled Mosaic on TPU),
mirroring :mod:`repro.kernels.segment_count.ops`.

All costs are small integers carried in f32 (exact below 2²⁴), so the
pallas and numpy paths agree bit for bit.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.analysis.kernels_check import validate_blocks

from .kernel import BIG_COST

__all__ = ["align_dp", "align_dp_numpy", "pick_blocks", "BIG_COST"]


def pick_blocks(num_variants: int) -> int:
    """Variant block: one MXU-height tile; small inputs shrink to one
    sublane-aligned block instead of padding 128-wide."""
    bv = 8
    while bv < 128 and bv < num_variants:
        bv *= 2
    return bv


def _pad_lane(n: int, lane: int = 128) -> int:
    return max(lane, -(-n // lane) * lane)


def align_dp_numpy(
    seqs: np.ndarray,
    lens: np.ndarray,
    m: np.ndarray,
    d0: np.ndarray,
    endcost: np.ndarray,
) -> np.ndarray:
    """Reference layered DP, vectorized across variants (f32 throughout so
    it is the kernel's bit-exact oracle)."""
    v, lp = seqs.shape
    s = m.shape[0]
    d = np.broadcast_to(d0.astype(np.float32), (v, s)).copy()
    cols = np.arange(s, dtype=np.int64)[None, :]
    for i in range(lp):
        a = seqs[:, i].astype(np.int64)
        mcol = m.T[a].astype(np.float32)  # (V, S): M[s, a_v]
        sync = (d + mcol).min(axis=1)
        onehot = cols == a[:, None]
        nd = np.minimum(
            d + np.float32(1.0),
            np.where(onehot, sync[:, None], np.float32(BIG_COST)),
        )
        d = np.where((lens > i)[:, None], nd, d)
    return (d + endcost.astype(np.float32)[None, :]).min(axis=1)


def align_dp(
    seqs: np.ndarray,
    lens: np.ndarray,
    m: np.ndarray,
    d0: np.ndarray,
    endcost: np.ndarray,
    *,
    backend: str = "auto",
    block_v: int | None = None,
    interpret: bool | None = None,
) -> np.ndarray:
    """Per-variant alignment cost for the layered DFG-alignment DP.

    ``seqs`` (V, L) int32 activity ids (padding rows masked via ``lens``),
    ``m`` (S, A≤S) the model-move+sync cost closure, ``d0`` / ``endcost``
    (S,) the virtual-START/END folds.  ``backend``: ``auto`` (numpy on CPU,
    pallas on TPU) | ``numpy`` | ``pallas``.
    """
    seqs = np.ascontiguousarray(seqs, dtype=np.int32)
    lens = np.ascontiguousarray(lens, dtype=np.int32)
    v, l = seqs.shape
    s_real, a_real = m.shape

    if backend == "auto":
        import jax

        backend = "numpy" if jax.default_backend() == "cpu" else "pallas"

    sp = _pad_lane(max(s_real, a_real))
    mp = np.full((sp, sp), BIG_COST, dtype=np.float32)
    mp[:s_real, :a_real] = m
    d0p = np.full((sp,), BIG_COST, dtype=np.float32)
    d0p[:s_real] = d0
    endp = np.full((sp,), BIG_COST, dtype=np.float32)
    endp[:s_real] = endcost

    if backend == "numpy":
        if v == 0:
            return np.zeros((0,), dtype=np.float32)
        return align_dp_numpy(seqs, lens, mp, d0p, endp)
    if backend != "pallas":
        raise ValueError(f"unknown align_dp backend {backend!r}")

    import jax
    import jax.numpy as jnp

    from .kernel import align_dp_pallas

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bv = block_v or pick_blocks(v)
    vp = max(bv, -(-v // bv) * bv)
    lp = _pad_lane(l)
    # static resource check on the concrete block assignment (pick_blocks
    # alone cannot: the VMEM bound also depends on the padded L / S axes)
    validate_blocks("align_dp", block_v=bv, lp=lp, s=sp)
    seqs_p = np.zeros((vp, lp), dtype=np.int32)
    seqs_p[:v, :l] = seqs
    lens_p = np.zeros((vp,), dtype=np.int32)
    lens_p[:v] = lens

    run = functools.partial(
        align_dp_pallas, block_v=bv, interpret=bool(interpret)
    )
    out = run(
        jnp.asarray(seqs_p), jnp.asarray(lens_p),
        jnp.asarray(np.ascontiguousarray(mp.T)),
        jnp.asarray(d0p[None, :]), jnp.asarray(endp[None, :]),
    )
    return np.asarray(out)[:v]

# Timing hook: every call lands in the process-global kernel registry as
# kernel_seconds{kernel=align_dp} (see repro.kernels.timing).
from ..timing import timed_kernel

align_dp = timed_kernel("align_dp", align_dp)
