"""Pallas TPU kernel for the DFG-alignment banded DP.

:mod:`repro.conformance.align` reduces a trace-to-model alignment to a
layered DP over the model's state space: for every consumed event the
(V, S) cost front takes one of two moves —

* **log move** (skip the event): ``d += 1`` elementwise;
* **model moves + sync**: land on the event's state at
  ``min_s d[s] + M[s, a]`` (``M`` pre-folds any number of model moves
  followed by one synchronous move via an APSP closure).

Each layer is a one-hot gather of ``M``'s column — an MXU contraction
(``OneHot(a) · Mᵀ``) — followed by a lane-axis min-reduce: the same
"scatter → dense one-hot matmul" reformulation as the dfg_count and
segment_count kernels ("Pallas where it pays").  The kernel walks all L
layers for one variant block with the cost front resident in registers/VMEM
(the band), so HBM traffic is the padded sequence block plus M once.

VMEM working set per step (BV=128, S≤512, L≤1024, f32/int32):
  seqs 128×1024×4 B = 512 KiB + Mᵀ 512×512×4 B = 1 MiB + front 256 KiB
  « 16 MiB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["align_dp_kernel", "align_dp_pallas", "BIG_COST"]

#: "unreachable" sentinel — large enough that no real alignment cost ever
#: reaches it, small enough that f32 sums of a few of them stay finite
BIG_COST = 1e9


def align_dp_kernel(
    seqs_ref, lens_ref, mt_ref, d0_ref, end_ref, out_ref, *, num_layers: int
):
    """One grid step: run the full layered DP for one variant block."""
    seqs = seqs_ref[...]  # (BV, L) int32 — padded activity ids
    lens = lens_ref[...]  # (BV,) int32
    mt = mt_ref[...]  # (S, S) f32 — Mᵀ, padded states carry BIG_COST
    d0 = d0_ref[...]  # (1, S) f32
    end = end_ref[...]  # (1, S) f32

    bv = seqs.shape[0]
    s = mt.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (bv, s), 1)

    def layer(i, d):
        a = jax.lax.dynamic_slice_in_dim(seqs, i, 1, axis=1)  # (BV, 1)
        onehot = (cols == a).astype(jnp.float32)  # (BV, S)
        # Mcol[v, s] = M[s, a_v]  via  OneHot(a) · Mᵀ on the MXU
        mcol = jnp.dot(onehot, mt, preferred_element_type=jnp.float32)
        sync = jnp.min(d + mcol, axis=1, keepdims=True)  # (BV, 1)
        nd = jnp.minimum(
            d + 1.0,
            jnp.where(onehot > 0, sync, BIG_COST),
        )
        active = (lens > i)[:, None]
        return jnp.where(active, nd, d)

    d = jnp.broadcast_to(d0, (bv, s))
    d = jax.lax.fori_loop(0, num_layers, layer, d)
    out_ref[...] = jnp.min(d + end, axis=1)[None, :]  # (1, BV)


def align_dp_pallas(
    seqs: jax.Array,
    lens: jax.Array,
    mt: jax.Array,
    d0: jax.Array,
    endcost: jax.Array,
    *,
    block_v: int,
    interpret: bool,
) -> jax.Array:
    """Raw pallas_call wrapper.  All shapes must be pre-padded:
    seqs (Vp, Lp) with Vp % block_v == 0, state axis lane-aligned."""
    vp, lp = seqs.shape
    s = mt.shape[0]
    grid = (vp // block_v,)

    kernel = functools.partial(align_dp_kernel, num_layers=lp)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, lp), lambda v: (v, 0)),
            pl.BlockSpec((block_v,), lambda v: (v,)),
            pl.BlockSpec((s, s), lambda v: (0, 0)),
            pl.BlockSpec((1, s), lambda v: (0, 0)),
            pl.BlockSpec((1, s), lambda v: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_v), lambda v: (0, v)),
        out_shape=jax.ShapeDtypeStruct((1, vp), jnp.float32),
        interpret=interpret,
    )(seqs, lens, mt, d0, endcost)
    return out[0]
