from .ops import BIG_COST, align_dp, align_dp_numpy

__all__ = ["align_dp", "align_dp_numpy", "BIG_COST"]
