"""Pallas TPU kernel for segment counting (node-degree histograms).

The graph builder needs per-segment cardinalities everywhere a CSR index is
assembled: events per Activity node (`:OF_TYPE` degree, the process-map node
significance), events per Case node (`:BELONGS_TO` degree), run lengths of
sorted edge keys.  On CPU that is ``np.bincount``; on TPU a scatter-add
serializes, so — exactly like :mod:`repro.kernels.dfg_count` — the kernel
reformulates the histogram as a dense one-hot contraction on the MXU:

    counts[j·BS:(j+1)·BS] += Σ_block OneHot(ids)ᵀ · 1

Grid ``(S/BS, N/BN)`` with the id-block dimension innermost so each count
tile stays resident in VMEM while the id stream flows through; the tile is
zeroed at the first block (standard Pallas accumulation pattern).

VMEM working set per step (BN=2048, BS=512, f32):
  one-hot 2048×512×4 B = 4 MiB + out tile 2 KiB  « 16 MiB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_count_kernel", "segment_count_pallas"]


def segment_count_kernel(ids_ref, valid_ref, out_ref, *, block_s: int):
    """One grid step: accumulate a (1, BS) count tile over one id block."""
    j = pl.program_id(0)  # segment tile
    b = pl.program_id(1)  # id block (innermost)

    @pl.when(b == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]  # (BN,) int32
    valid = valid_ref[...]

    s0 = j * block_s
    cols = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_s), 1)
    onehot = (ids[:, None] == (s0 + cols)) & valid[:, None]
    out_ref[...] += jnp.sum(
        onehot.astype(jnp.float32), axis=0, keepdims=True
    )


def segment_count_pallas(
    ids: jax.Array,
    valid: jax.Array,
    *,
    num_segments_padded: int,
    block_n: int,
    block_s: int,
    interpret: bool,
) -> jax.Array:
    """Raw pallas_call wrapper.  All shapes must be pre-padded:
    len(ids) % block_n == 0, num_segments_padded % block_s == 0."""
    n_total = ids.shape[0]
    grid = (num_segments_padded // block_s, n_total // block_n)

    id_spec = pl.BlockSpec((block_n,), lambda j, b: (b,))
    out_spec = pl.BlockSpec((1, block_s), lambda j, b: (0, j))

    kernel = functools.partial(segment_count_kernel, block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[id_spec, id_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(
            (1, num_segments_padded), jnp.float32
        ),
        interpret=interpret,
    )(ids, valid)
    return out[0]
