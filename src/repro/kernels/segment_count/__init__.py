from .ops import segment_count

__all__ = ["segment_count"]
