"""Jitted public wrapper around the segment_count Pallas kernel.

Handles padding (ids to BN, segments to BS) and backend selection
(interpret mode on CPU — kernel body runs in Python for validation;
compiled Mosaic on TPU), mirroring :mod:`repro.kernels.dfg_count.ops`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.analysis.kernels_check import validate_blocks

from .kernel import segment_count_pallas

__all__ = ["segment_count", "pick_blocks"]


def pick_blocks(
    num_segments: int, vmem_budget_bytes: int = 8 << 20
) -> tuple[int, int]:
    """Choose (block_n, block_s): a lane-aligned segment tile (≤512) and the
    largest id block whose one-hot tile (f32) fits the VMEM budget."""
    block_s = 128
    while block_s < 512 and block_s < num_segments:
        block_s *= 2
    block_s = min(block_s, 512)
    bn = (vmem_budget_bytes - 4 * block_s) // (4 * block_s)
    block_n = max(512, min(4096, int(bn) // 512 * 512))
    # static resource check: BlockSpec VMEM bound + MXU/VPU tile alignment
    validate_blocks("segment_count", block_n=block_n, block_s=block_s)
    return block_n, block_s


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "block_n", "block_s", "interpret"),
)
def segment_count(
    ids: jax.Array,
    valid: jax.Array,
    *,
    num_segments: int,
    block_n: int | None = None,
    block_s: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Count occurrences of each segment id in ``[0, num_segments)``.

    Equivalent to ``jnp.bincount(ids[valid], length=num_segments)`` — the
    TPU-native histogram the graph builder uses for node degrees.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    auto_n, auto_s = pick_blocks(num_segments)
    block_n = block_n or auto_n
    block_s = block_s or auto_s
    s_pad = max(block_s, -(-num_segments // block_s) * block_s)

    ids = ids.astype(jnp.int32)
    valid = valid.astype(jnp.bool_)
    n = ids.shape[0]
    pad = (-n) % block_n or (block_n if n == 0 else 0)
    if pad:
        ids = jnp.pad(ids, (0, pad))
        valid = jnp.pad(valid, (0, pad))

    out = segment_count_pallas(
        ids, valid,
        num_segments_padded=s_pad,
        block_n=block_n,
        block_s=block_s,
        interpret=interpret,
    )
    return out[:num_segments].astype(jnp.int32)

# Timing hook: every call lands in the process-global kernel registry as
# kernel_seconds{kernel=segment_count} (see repro.kernels.timing).
from ..timing import timed_kernel

segment_count = timed_kernel("segment_count", segment_count)
