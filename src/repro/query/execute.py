"""Query execution: one engine, every physical backend.

``QueryEngine.run`` takes a fluent :class:`~repro.query.ast.Query` plus a
sink, canonicalizes the logical plan (:mod:`repro.query.optimize`), consults
the plan/result cache (:mod:`repro.query.cache`), picks a physical plan
(:mod:`repro.query.planner`), and dispatches to the repo's existing
execution primitives:

* ``dfg_numpy`` / ``dfg`` (scatter | onehot | pallas) on pair columns,
* the fused ``dfg_count_diced`` Pallas kernel when the window pushes into
  the kernel's WHERE clause,
* ``streaming_dfg`` over a :class:`MemmapLog` with the time window pushed
  to a row range via the chunk time index,
* ``distributed_dfg`` over a device mesh,
* the **delta** path: when a memmap source is *proven* (prefix-preserving
  fingerprint) to be an append-only extension of a cached scan, the cached
  :class:`StreamingDFGMiner` state resumes over just the appended suffix —
  or, when the plan's window lies inside the old range, the cached result
  is served with no scan at all (free rewrite).

Every path produces counts bit-identical to the corresponding direct
single-backend call — the equivalence tests pin this against the paper's
Algorithm 1 oracle.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.conformance import (
    AlignmentResult,
    StreamingModelDiscoverer,
    StreamingReplayer,
    align_arrays,
    replay_fitness_arrays,
)
from repro.core.conformance import ModelSpec, ReplayResult
from repro.core.dfg import dfg, dfg_numpy
from repro.core.dicing import dice_repository, pair_mask_for_window
from repro.core.discovery import DiscoveredModel, discover_dependency_graph
from repro.core.distributed import (
    distributed_dfg,
    merge_shard_counts,
    merge_shard_psis,
)
from repro.core.repository import EventRepository, concat_repositories
from repro.core.streaming import MemmapLog, StreamingDFGMiner, memmap_log_name
from repro.core.telemetry import EventCollector
from repro.core.variants import trace_variants, variant_filtered_repository
from repro.core.views import HIDDEN
from repro.graph import (
    GraphStore,
    csr_from_dense,
    derive_neighborhood,
    derive_process_map,
)
from repro.graph.build import EventGraph
from repro.graph.shard import ShardedLog, sharded_log_name
from repro.analysis.lockdep import make_lock
from repro.obs import MetricsRegistry, QueryTrace, kernel_registry
from repro.obs.context import TraceContext, mint_context
from repro.obs.trace import NullTrace

from .ast import (
    CONFORMANCE_SINKS,
    TOPOLOGY_SINKS,
    Activities,
    AlignmentsSink,
    ApplyView,
    CompareSink,
    DFGSink,
    FitnessSink,
    HistogramSink,
    LogicalPlan,
    NeighborhoodSink,
    ProcessMapSink,
    Query,
    QueryPlanError,
    Sink,
    TopVariants,
    UnionSource,
    VariantsSink,
    Window,
    is_barrier,
    union_activity_names,
)
from .cache import (
    QueryCache,
    ResumableState,
    fingerprint,
    parse_memmap_fingerprint,
    prefix_digest,
    realpath_of,
)
from .optimize import canonicalize, compose_views, distribute_over_union
from .planner import (
    PhysicalPlan,
    SourceInfo,
    estimate_cost_s,
    load_calibration,
    plan_physical,
    source_info,
)

_LOG = logging.getLogger("repro.obs")

__all__ = [
    "QueryResult",
    "CompareResult",
    "EngineStats",
    "PlanProbe",
    "QueryEngine",
    "default_engine",
    "set_default_engine",
    "memmap_activity_names",
    "memmap_log_name",
    "repository_from_memmap",
]


@dataclasses.dataclass(frozen=True)
class PlanProbe:
    """Read-only prediction of how one query would execute *right now* —
    the serving tier's SLO-classification input (:mod:`repro.transport`).

    ``fingerprint`` is the source fingerprint observed at probe time; the
    transport layer keys in-flight request coalescing on it, so an append
    that moves the fingerprint separates pre- and post-append waiters
    instead of fanning a stale execution out to both.  ``cached`` /
    ``delta_hint`` predict a ~µs–ms serve, ``estimated_cost_s`` is the
    planner's cold-scan prior for the predicted backend."""

    fingerprint: str
    plan_key: str
    backend: str
    cached: bool
    delta_hint: bool
    estimated_cost_s: float


@dataclasses.dataclass
class QueryResult:
    """What a terminal query call returns.

    ``value`` is the sink's payload (Ψ matrix, histogram vector, or
    :class:`TraceVariants`); ``names`` labels its activity axis where that
    makes sense (None for variants).
    """

    value: object
    names: Optional[List[str]]
    logical: LogicalPlan
    physical: PhysicalPlan
    from_cache: bool
    wall_s: float
    rewrites: Tuple[str, ...] = ()
    # per-query execution trace (repro.obs) — always attached; None only
    # when the engine was constructed with trace=False
    trace: Optional[QueryTrace] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    # trace id of the execution that produced this value.  Cached copies
    # scrub the producing run's spans but keep this id, so a cache hit's
    # trace (and any exemplar pointing at it) links back to the execution
    # that populated the cache.
    source_trace_id: Optional[str] = dataclasses.field(
        default=None, compare=False
    )


@dataclasses.dataclass
class EngineStats:
    """Point-in-time snapshot of the engine's counters.

    The live counters sit in the engine's lock-protected
    :class:`repro.obs.MetricsRegistry` (``engine.metrics``); every read of
    ``engine.stats`` rebuilds this dataclass from them, so concurrent
    ``run()`` calls can never lose increments the way the old bare-``int``
    attributes could."""

    queries: int = 0
    executions: int = 0  # backend runs (cache misses, incl. delta scans)
    cache_hits: int = 0
    delta_hits: int = 0  # append-only: resumed cached state over the suffix
    delta_free_hits: int = 0  # append-only + window inside old range: no scan
    rows_scanned: int = 0  # memmap rows fed to streaming/delta scans
    union_queries: int = 0  # multi-source (Q.logs) queries, incl. compare
    graph_queries: int = 0  # answered from the CSR event-knowledge graph
    conformance_queries: int = 0  # fitness / alignments sinks
    shard_queries: int = 0  # answered by the sharded-graph merge backend


@dataclasses.dataclass
class CompareResult:
    """What :meth:`Query.compare` returns (as ``QueryResult.value``).

    All matrices share one aligned (visible) activity axis ``names``.
    ``diffs[i] = psis[i] - psis[0]`` — the Ψ-drift of log ``i`` against the
    first (reference) log; ``fitness[i]`` is the replay fitness of log
    ``i``'s traces on the dependency graph discovered from the reference
    log (out-of-budget memmap branches replay in one streaming scan, so no
    branch reports None).  Windows/filters/views shape the Ψ matrices;
    fitness is a whole-log conformance signal.
    """

    log_names: Tuple[str, ...]
    names: List[str]
    psis: Tuple[np.ndarray, ...]
    diffs: Tuple[np.ndarray, ...]
    fitness: Tuple[float, ...]

    @property
    def diff(self) -> np.ndarray:
        """The two-log drift matrix (``psis[1] - psis[0]``)."""
        if len(self.psis) != 2:
            raise ValueError(
                f"diff is defined for exactly two logs (got "
                f"{len(self.psis)}); index diffs[] instead"
            )
        return self.diffs[1]

    def drift(self, i: int = 0, j: int = 1) -> np.ndarray:
        return self.psis[j] - self.psis[i]


def memmap_activity_names(log: MemmapLog) -> List[str]:
    """MemmapLog stores integer activity ids; the engine labels them the
    same way the mining CLI does."""
    return log.activity_labels()




def repository_from_memmap(
    log: MemmapLog, log_name: Optional[str] = None
) -> EventRepository:
    """Materialize an in-budget memmap log as a canonical EventRepository.

    Stays numeric end to end (no per-event Python strings): the columns are
    already int32/float64, so canonicalization is one lexsort + one unique.
    The planner's budget gate keeps this O(memory_budget_events).

    ``log_name`` (default: derived from the memmap path) becomes the
    repository's single ``log_names`` entry, so cross-log provenance
    survives materialization — unions/compares over several materialized
    memmaps keep telling their branches apart.

    A :class:`ShardedLog` materializes as the concatenation of its shards;
    the canonical lexsort restores the global trace-contiguous, time-sorted
    order (cases never span shards, so no case is ever split by it).
    """
    if isinstance(log, ShardedLog):
        parts = [s for _, s in log.present_shards()]
        default_name = sharded_log_name(log)
    else:
        parts = [log]
        default_name = memmap_log_name(log)
    acts, cases, times = [], [], []
    for part in parts:
        for a, c, t in part.iter_chunks():
            acts.append(a)
            cases.append(c)
            times.append(t)
    a = np.concatenate(acts) if acts else np.zeros((0,), np.int32)
    c = np.concatenate(cases) if cases else np.zeros((0,), np.int32)
    t = np.concatenate(times) if times else np.zeros((0,), np.float64)
    n = a.shape[0]
    # canonical order: trace-contiguous, time-sorted within trace, stable
    order = np.lexsort((np.arange(n), t, c))
    a, c, t = a[order], c[order], t[order]
    uniq_cases, trace_col = np.unique(c, return_inverse=True)
    return EventRepository(
        event_activity=a.astype(np.int32),
        event_trace=trace_col.astype(np.int32),
        event_time=t,
        trace_log=np.zeros(uniq_cases.shape[0], dtype=np.int32),
        activity_names=list(log.activity_labels()),
        trace_names=[f"case_{int(x)}" for x in uniq_cases],
        log_names=[log_name or default_name],
    )


# ---------------------------------------------------------------------------
# Collected per-plan execution state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Collected:
    repo: Optional[EventRepository]
    window: Optional[Window] = None
    keep: Optional[Tuple[str, ...]] = None
    view: Optional[ApplyView] = None


def _validate_keep(keep, names) -> None:
    unknown = set(keep) - set(names)
    if unknown:
        raise QueryPlanError(f"unknown activities in filter: {sorted(unknown)}")


def _collect(repo: Optional[EventRepository], logical: LogicalPlan) -> _Collected:
    """Apply materializing ops in order; fold pure predicates.

    Pure predicates (Window / paper-semantics Activities) are WHERE clauses
    evaluated at the sink; materializing ops (TopVariants, relink dicing)
    transform the store they are chained on.

    The folding here is not redundant with :func:`canonicalize`: the
    optimizer fuses predicates only *within* a barrier-free segment (it
    cannot reorder across barriers without proving commutation), while at
    execution time predicates from every segment land on the same sink and
    may be intersected — ``window(a,b) → top_variants(k) → window(c,d)``
    reaches here as two Window ops.
    """
    st = _Collected(repo=repo)
    for op in logical.ops:
        if isinstance(op, (TopVariants, Activities)) and is_barrier(op):
            if st.view is not None:
                # naive left-to-right semantics would materialize the
                # *projected* store; we don't relabel repositories, so
                # silently ranking/filtering raw activities instead would
                # break the bit-identical contract
                raise QueryPlanError(
                    "view() before a materializing op (top_variants / "
                    "relink) is not supported: apply the view last"
                )
        if isinstance(op, TopVariants):
            st.repo = variant_filtered_repository(st.repo, op.k)
        elif isinstance(op, Activities) and op.relink:
            _validate_keep(op.keep, st.repo.activity_names)
            st.repo = dice_repository(st.repo, activities=list(op.keep))
        elif isinstance(op, Window):
            st.window = op if st.window is None else st.window.intersect(op)
        elif isinstance(op, Activities):
            if st.view is not None:
                raise QueryPlanError(
                    "activities() after view() is not supported: filters "
                    "name raw activities; apply them before the view"
                )
            st.keep = (
                op.keep if st.keep is None
                else tuple(sorted(set(st.keep) & set(op.keep)))
            )
        elif isinstance(op, ApplyView):
            st.view = op if st.view is None else compose_views(st.view, op)
        else:
            raise QueryPlanError(f"unknown op {op!r}")
    return st


_SINK_LABELS: Dict[type, str] = {}


def _sink_label(sink: Sink) -> str:
    """Short metric label for a sink type (``DFGSink`` → ``dfg``), memoized
    per type so the hot path never formats strings."""
    t = type(sink)
    lbl = _SINK_LABELS.get(t)
    if lbl is None:
        lbl = t.__name__.lower()
        if lbl.endswith("sink"):
            lbl = lbl[:-4]
        _SINK_LABELS[t] = lbl
    return lbl


def _zero_outside(psi: np.ndarray, keep_ids: np.ndarray) -> np.ndarray:
    mask = np.zeros(psi.shape[0], dtype=bool)
    mask[keep_ids] = True
    out = psi.copy()
    out[~mask, :] = 0
    out[:, ~mask] = 0
    return out


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class _TraceScope:
    """Thread-local ambient trace parent (``QueryEngine.trace_scope``):
    while entered, root queries on this thread bind as children of the
    scoped :class:`TraceContext` instead of minting a fresh trace id."""

    __slots__ = ("_tls", "_ctx", "_prev")

    def __init__(self, tls, ctx: Optional[TraceContext]):
        self._tls = tls
        self._ctx = ctx
        self._prev: Optional[TraceContext] = None

    def __enter__(self) -> Optional[TraceContext]:
        self._prev = getattr(self._tls, "ctx", None)
        self._tls.ctx = self._ctx
        return self._ctx

    def __exit__(self, *exc) -> bool:
        self._tls.ctx = self._prev
        return False


class QueryEngine:
    """Plans, caches, and executes logical query plans in-store."""

    def __init__(
        self,
        *,
        mesh=None,
        tiny_pairs: Optional[int] = None,
        memory_budget_events: Optional[int] = None,
        fused_dicing: bool = True,
        cache: Optional[QueryCache] = None,
        repo_memo_size: int = 4,
        calibration_path: Optional[str] = None,
        graph_crossover: Optional[int] = None,
        replay_crossover: Optional[int] = None,
        sharded_crossover: Optional[int] = None,
        max_graphs: int = 8,
        graph_spill_dir: Optional[str] = None,
        metrics: Optional[MetricsRegistry] = None,
        trace: bool = True,
        trace_store=None,
        telemetry_max_events: Optional[int] = 1 << 16,
        drift_ratio: float = 16.0,
    ):
        self.mesh = mesh
        # thresholds left unset fall back to the measured calibration
        # (BENCH_query.json / BENCH_graph.json) when one exists, else the
        # static constants
        cal = load_calibration(calibration_path)
        self.tiny_pairs = (
            cal["tiny_pairs"] if tiny_pairs is None else tiny_pairs
        )
        self.memory_budget_events = (
            cal["memory_budget_events"]
            if memory_budget_events is None
            else memory_budget_events
        )
        # repeated topology queries on one source after which building the
        # event-knowledge graph amortizes (measured columnar↔graph
        # crossover from BENCH_graph.json when available)
        self.graph_crossover = (
            cal["graph_repeat_crossover"]
            if graph_crossover is None
            else graph_crossover
        )
        # memmap events above which one-pass streaming replay beats
        # materialize-then-replay for conformance sinks (measured crossover
        # from BENCH_conformance.json when available; explicit arg wins)
        self.replay_crossover = (
            cal["replay_streaming_crossover"]
            if replay_crossover is None
            else replay_crossover
        )
        # sharded log size below which a one-host concat-and-count beats
        # the K-way shard merge (measured from BENCH_shard.json when
        # available); fitted crossover *curves* from any committed bench
        # calibration upgrade the scalars at plan time
        self.sharded_crossover = (
            cal["sharded_single_crossover"]
            if sharded_crossover is None
            else sharded_crossover
        )
        self.calibration_curves = cal.get("curves") or {}
        # live counters sit in one lock-protected registry (the old
        # bare-int EngineStats attributes raced under concurrent run());
        # ``.stats`` rebuilds the dataclass as a point-in-time snapshot
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._c_queries = m.counter(
            "engine_queries_total", "Queries run (also the query-id sequence)"
        )
        self._c_executions = m.counter(
            "engine_executions_total",
            "Backend executions (cache misses, incl. delta scans)",
        )
        self._c_cache_hits = m.counter(
            "engine_cache_hits_total", "Queries served from the result cache"
        )
        self._c_delta_hits = m.counter(
            "engine_delta_hits_total",
            "Append-only queries resumed over just the suffix",
        )
        self._c_delta_free_hits = m.counter(
            "engine_delta_free_hits_total",
            "Append-only queries answered without any scan (window predates "
            "the append)",
        )
        self._c_rows = m.counter(
            "engine_rows_scanned_total",
            "Memmap rows fed to streaming/delta scans",
        )
        self._c_union = m.counter(
            "engine_union_queries_total",
            "Multi-source (Q.logs) queries, incl. compare",
        )
        self._c_graph = m.counter(
            "engine_graph_queries_total",
            "Queries answered from the CSR event-knowledge graph",
        )
        self._c_conformance = m.counter(
            "engine_conformance_queries_total",
            "Conformance (fitness / alignments) queries",
        )
        self._c_shard = m.counter(
            "engine_shard_queries_total",
            "Queries answered by the sharded-graph merge backend",
        )
        self._h_replay_chunk = m.histogram(
            "replay_chunk_seconds", "Streaming-replay chunk wall time"
        )
        self._h_delta_fraction = m.histogram(
            "delta_suffix_fraction",
            "Fraction of the log rescanned by a delta resume",
        )
        m.gauge(
            "engine_cache_hit_ratio", self._cache_hit_ratio,
            "Result-cache hits over total queries",
        )
        # always-on per-query tracing + self-mining forensics: every
        # finished trace batches its spans into a bounded collector, so
        # ``Q.log(engine.own_telemetry())`` mines the engine's own process
        self.trace_enabled = trace
        # optional repro.obs.store.TraceStore: every finished *root* trace
        # (and every errored one) is offered for tail-sampled persistence
        self.trace_store = trace_store
        self.drift_ratio = drift_ratio
        self.drift_min_s = 0.005
        self.telemetry = EventCollector(
            "engine", max_events=telemetry_max_events
        )
        m.gauge(
            "telemetry_events", lambda: float(len(self.telemetry)),
            "Span events resident in the forensics ring buffer",
        )
        m.gauge(
            "telemetry_dropped_events",
            lambda: float(self.telemetry.dropped),
            "Span events dropped by the bounded forensics ring",
        )
        # hot-path memo of query_latency_seconds{sink,backend} histograms
        self._lat_hists: Dict[Tuple[str, str], "Histogram"] = {}  # guarded by _lock
        self._tls = threading.local()
        # built graphs keyed by source fingerprint; appends extend the CSR
        # over the proven suffix instead of rebuilding
        self.graphs = GraphStore(
            max_graphs=max_graphs,
            memory_budget_events=self.memory_budget_events,
            metrics=self.metrics,
            spill_dir=graph_spill_dir,
        )
        # per-source topology-query (miss) counter feeding the crossover
        self._topo_seen: "OrderedDict[str, int]" = OrderedDict()  # guarded by _lock
        self._max_topo_seen = 512
        # the fused Pallas WHERE clause compares f32 timestamps; leave it on
        # unless your timestamps do not round-trip through f32
        self.fused_dicing = fused_dicing
        self.cache = cache if cache is not None else QueryCache()
        # physical plans depend only on (canonical plan, source shape), not
        # on data bytes — keying on SourceInfo instead of the fingerprint
        # avoids one stale entry per append; LRU-bounded like the cache
        self._plans: "OrderedDict[Tuple[str, SourceInfo], PhysicalPlan]" = (
            OrderedDict()
        )  # guarded by _lock
        self._max_plans = 512
        # materialized memmap repos keyed by source fingerprint: tenants
        # alternating over several in-budget logs each keep their load
        self.repo_memo_size = repo_memo_size
        self._repo_memo: "OrderedDict[str, EventRepository]" = OrderedDict()  # guarded by _lock
        # compare() fitness per composite union fingerprint (whole-log
        # signal: one entry serves every window/filter/view over the union)
        self._fitness_memo: "OrderedDict[str, Tuple]" = OrderedDict()  # guarded by _lock
        self._max_fitness_memo = 16
        # discovered default models per (source fp, non-window ops):
        # sliding-window conformance dashboards (and compare()'s reference
        # model) stop re-running discovery on unchanged data
        self._model_memo: "OrderedDict[Tuple, ModelSpec]" = OrderedDict()  # guarded by _lock
        self._max_model_memo = 16
        self._lock = make_lock("QueryEngine")

    @property
    def stats(self) -> EngineStats:
        """Point-in-time snapshot of the registry counters (the live
        values are in ``self.metrics``)."""
        return EngineStats(
            queries=self._c_queries.value,
            executions=self._c_executions.value,
            cache_hits=self._c_cache_hits.value,
            delta_hits=self._c_delta_hits.value,
            delta_free_hits=self._c_delta_free_hits.value,
            rows_scanned=self._c_rows.value,
            union_queries=self._c_union.value,
            graph_queries=self._c_graph.value,
            conformance_queries=self._c_conformance.value,
            shard_queries=self._c_shard.value,
        )

    def _cache_hit_ratio(self) -> float:
        q = self._c_queries.value
        return self._c_cache_hits.value / q if q else 0.0

    def metrics_snapshot(self, floor: int = 0) -> Dict[str, object]:
        """Engine registry + process-wide Pallas kernel timings, one flat
        dict.  ``floor`` applies the serving tier's k-anonymity floor
        (counts below it read as zero)."""
        snap = self.metrics.to_dict(floor=floor)
        snap.update(kernel_registry().to_dict(floor=floor))
        return snap

    # -- tracing / self-mining forensics -------------------------------------
    def trace_scope(self, ctx: Optional[TraceContext]):
        """Context manager binding ``ctx`` as the ambient trace parent for
        queries run on *this thread*: the next root query's trace becomes a
        child of ``ctx`` (same trace id), and its own sub-queries — union
        branches, per-shard sub-traces — inherit transitively through the
        trace stack.  This is how the transport tier stitches its request
        span tree into the engine's: one trace id end to end."""
        return _TraceScope(self._tls, ctx)

    def _trace_begin(self, qid: int, sink: Sink, source) -> QueryTrace:
        if isinstance(source, UnionSource):
            kind = "union"
        elif isinstance(source, ShardedLog):
            kind = "sharded"
        elif isinstance(source, MemmapLog):
            kind = "memmap"
        else:
            kind = "repository"
        cls = QueryTrace if self.trace_enabled else NullTrace
        tr = cls(qid, _sink_label(sink), kind)
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        if self.trace_enabled:
            # distributed identity: nested queries (union branches, shard
            # sub-queries) chain under their enclosing trace; a root query
            # chains under the ambient transport context when one is
            # scoped, else mints a fresh trace id
            if stack and stack[-1].trace_id is not None:
                tr.bind_child_of(stack[-1].context)
            else:
                ctx = getattr(self._tls, "ctx", None)
                if ctx is not None:
                    tr.bind_child_of(ctx)
                else:
                    tr.bind_root(mint_context())
        stack.append(tr)
        return tr

    def _current_trace(self) -> Optional[QueryTrace]:
        stack = getattr(self._tls, "stack", None)
        return stack[-1] if stack else None

    def _trace_abort(self, tr: QueryTrace) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack and stack[-1] is tr:
            stack.pop()

    def _note_rows(self, n: int) -> None:
        """Row-scan accounting: the global counter plus attribution to the
        query currently executing on this thread (union branches attribute
        to their own trace; helper scans to the enclosing query)."""
        if n <= 0:
            return
        self._c_rows.inc(n)
        tr = self._current_trace()
        if tr is not None:
            tr.rows_scanned += n

    def _trace_finish(
        self, tr: QueryTrace, result: Optional[QueryResult]
    ) -> None:
        self._trace_abort(tr)
        tr.finish()
        if result is not None:
            result.trace = tr if tr.enabled else None
        if not tr.enabled:
            return
        key = (tr.sink, tr.executed_backend or "unknown")
        hist = self._lat_hists.get(key)
        if hist is None:
            # memoized: the registry's get-or-create sorts label tuples
            # under its lock — too slow for the per-query hot path.  The
            # unlocked read above is the fast path; the insert is
            # double-checked under the engine lock so two racing threads
            # converge on one Histogram instead of leaking a divergent memo
            with self._lock:
                hist = self._lat_hists.get(key)
                if hist is None:
                    hist = self._lat_hists[key] = self.metrics.histogram(
                        "query_latency_seconds",
                        "Per-query wall time by sink and executed backend",
                        sink=key[0], backend=key[1],
                    )
        hist.observe(tr.total_s, trace_id=tr.trace_id)
        names, t0s, durs = tr.raw_spans()
        if names:
            self.telemetry.record_many(f"q{tr.query_id}", names, t0s, durs)
        self._check_drift(tr)
        # persist root traces only: a nested sub-trace (union branch, shard
        # sub-query) rides its parent's record as a branch
        if self.trace_store is not None and not getattr(
            self._tls, "stack", None
        ):
            self.trace_store.offer(tr)

    def _trace_error(self, tr: QueryTrace) -> None:
        """Error path: pop + finish the trace and persist it when a store
        is attached — errored traces are always kept (tail sampling)."""
        self._trace_abort(tr)
        if not tr.enabled:
            return
        tr.finish()
        if self.trace_store is not None and not getattr(
            self._tls, "stack", None
        ):
            self.trace_store.offer(tr, error=True)

    def _check_drift(self, tr: QueryTrace) -> None:
        """Calibration drift: the recorded cost contradicts the planner's
        prior for the chosen backend by more than ``drift_ratio`` — count
        it and emit one structured warning (feeds the crossover-curve
        recalibration)."""
        pred, act = tr.predicted_cost_s, tr.actual_cost_s
        if (
            pred is None or act is None or pred <= 0.0
            or max(pred, act) < self.drift_min_s
        ):
            return
        ratio = act / pred
        if 1.0 / self.drift_ratio < ratio < self.drift_ratio:
            return
        tr.drift = ratio
        backend = tr.executed_backend or "unknown"
        self.metrics.counter("planner_drift_total", backend=backend).inc()
        _LOG.warning(
            "planner_cost_drift %s",
            json.dumps({
                "query_id": tr.query_id,
                "sink": tr.sink,
                "backend": backend,
                "planned_backend": tr.planned_backend,
                "predicted_cost_s": pred,
                "actual_cost_s": act,
                "ratio": ratio,
                "rows_scanned": tr.rows_scanned,
            }, sort_keys=True),
        )

    def _observe_replay_chunk(self, seconds: float, rows: int) -> None:
        self._h_replay_chunk.observe(seconds)

    def own_telemetry(self) -> EventRepository:
        """The engine's own spans as a canonical event repository: each
        query is one case, each span one event.  Feed it back through
        ``Q.log`` and the engine mines its own process — cache hits,
        delta resumes, and full scans surface as distinct DFG variants."""
        return self.telemetry.to_repository()

    # -- public --------------------------------------------------------------
    def run(self, query: Query, sink: Sink) -> QueryResult:
        if isinstance(query.source, UnionSource):
            return self._run_union(query, sink)
        qid = self._c_queries.inc()
        if isinstance(sink, CONFORMANCE_SINKS):
            self._c_conformance.inc()
        tr = self._trace_begin(qid, sink, query.source)
        try:
            s = tr.begin("parse")
            info = source_info(query.source)
            logical, rewrites = canonicalize(
                query.logical_plan(sink), info.activity_names
            )
            key = (fingerprint(query.source), logical.key())
            tr.end(s)
            s = tr.begin("cache_probe")
            cached = self.cache.get(key)
            tr.end(s)
            if cached is not None:
                cached.from_cache = True
                self._c_cache_hits.inc()
                tr.from_cache = True
                tr.planned_backend = cached.physical.backend
                tr.executed_backend = "cache"
                if cached.source_trace_id:
                    # the hit's trace links back to the execution that
                    # populated the cache entry
                    tr.links["produced_by"] = cached.source_trace_id
                self._trace_finish(tr, cached)
                # report this hit's own latency (fingerprint + canonicalize
                # + lookup), not the wall time of the original execution
                cached.wall_s = tr.total_s
                return cached

            if logical.source == "memmap":
                delta = self._try_delta(
                    query.source, logical, key, tuple(rewrites), tr
                )
                if delta is not None:
                    self._trace_finish(tr, delta)
                    if delta.from_cache:  # free rewrite: hit-style latency
                        delta.wall_s = tr.total_s
                    return delta

            s = tr.begin("plan")
            graph_available = self._graph_available(
                query.source, key[0], logical
            )
            physical = self._plan_cached(logical, info, graph_available)
            tr.end(s)
            tr.planned_backend = physical.backend
            if not isinstance(sink, CONFORMANCE_SINKS):
                # conformance cost scales with variants x model size, which
                # a per-backend events/s prior cannot see — recording a
                # prediction there would make every replay look like drift
                tr.predicted_cost_s = estimate_cost_s(
                    physical.backend, info.num_events
                )

            s = tr.begin("scan")
            t0 = time.perf_counter()
            value, names, resume = self._execute(
                query.source, logical, physical, source_fp=key[0]
            )
            wall = time.perf_counter() - t0
            tr.end(s)
            self._c_executions.inc()
            tr.executed_backend = physical.backend
            tr.actual_cost_s = wall
            result = QueryResult(
                value=value, names=names, logical=logical, physical=physical,
                from_cache=False, wall_s=wall, rewrites=tuple(rewrites),
                source_trace_id=tr.trace_id,
            )
            s = tr.begin("sink")
            self.cache.put(
                key, result, resume=resume,
                source_hint=self._source_hint(query.source),
            )
            tr.end(s)
            self._trace_finish(tr, result)
            return result
        except BaseException:
            self._trace_error(tr)
            raise

    def _conformance_graph_ok(self, source) -> bool:
        """Conformance can use the graph tier only when the graph carries
        event tables — out-of-core sources build topology-only graphs
        (logs only grow, so an in-budget source was in budget at build)."""
        return not (
            isinstance(source, MemmapLog)
            and source.num_events > self.memory_budget_events
        )

    def _graph_available(self, source, fp: str, logical: LogicalPlan) -> bool:
        """The planner's amortization signal: is the event-knowledge graph
        of this source built (or provably extendable over an append), or has
        this source crossed the repeat-query count where building one pays?
        Counts only topology/conformance cache *misses* — every hit is
        already O(1), so repeats that matter are the ones that would
        rescan."""
        if not isinstance(
            logical.sink, TOPOLOGY_SINKS + CONFORMANCE_SINKS
        ) or logical.has_barrier():
            return False
        if isinstance(source, UnionSource):
            return False  # branches make their own per-source decision
        if isinstance(logical.sink, CONFORMANCE_SINKS):
            if not self._conformance_graph_ok(source):
                return False
        if isinstance(source, ShardedLog):
            # warm when every present shard's CSR is registered (either
            # tier) — then the K-way merge serves without any shard scan,
            # so even a below-crossover log should stay on sharded-graph
            if self._shards_warm(source):
                return True
        elif self.graphs.peek(fp) or self.graphs.has_extendable(source):
            return True
        with self._lock:
            n = self._topo_seen.get(fp, 0) + 1
            self._topo_seen[fp] = n
            self._topo_seen.move_to_end(fp)
            while len(self._topo_seen) > self._max_topo_seen:
                self._topo_seen.popitem(last=False)
        return n >= self.graph_crossover

    def _shards_warm(self, sharded: ShardedLog) -> bool:
        """Every present shard has a registered graph (memory or disk
        tier) built from the shard's current — or an appendable earlier —
        state."""
        shards = sharded.present_shards()
        return bool(shards) and all(
            self.graphs.has_extendable(s) for _, s in shards
        )

    def _plan_cached(
        self,
        logical: LogicalPlan,
        info: SourceInfo,
        graph_available: bool = False,
    ) -> PhysicalPlan:
        """LRU-memoized physical planning (plans depend only on the canonical
        plan + source shape + graph availability, never on data bytes)."""
        plan_key = (logical.key(), info, graph_available)
        with self._lock:
            physical = self._plans.get(plan_key)
            if physical is not None:
                self._plans.move_to_end(plan_key)
                return physical
        physical = plan_physical(
            logical, info,
            mesh=self.mesh,
            tiny_pairs=self.tiny_pairs,
            memory_budget_events=self.memory_budget_events,
            fused_dicing=self.fused_dicing,
            graph_available=graph_available,
            replay_crossover=self.replay_crossover,
            sharded_crossover=self.sharded_crossover,
            curves=self.calibration_curves,
        )
        with self._lock:
            self._plans[plan_key] = physical
            while len(self._plans) > self._max_plans:
                self._plans.popitem(last=False)
        return physical

    def explain(
        self,
        query: Query,
        sink: Optional[Sink] = None,
        after: Optional[object] = None,
    ) -> str:
        """Predicted plan for ``query``; with ``after=`` (a
        :class:`QueryResult` or :class:`repro.obs.QueryTrace` from a
        recorded run) the prediction is diffed against what actually
        executed — backend, cost, spans, rows."""
        if sink is None:
            sink = DFGSink()
        info = source_info(query.source)
        logical, rewrites = canonicalize(
            query.logical_plan(sink), info.activity_names
        )
        if isinstance(query.source, UnionSource):
            graph_available = False
        else:
            # the same signal run() would see, read-only: explain never
            # bumps the repeat counter, but must predict the next run
            fp = fingerprint(query.source)
            with self._lock:
                seen = self._topo_seen.get(fp, 0)
            sink_ok = isinstance(logical.sink, TOPOLOGY_SINKS) or (
                isinstance(logical.sink, CONFORMANCE_SINKS)
                and self._conformance_graph_ok(query.source)
            )
            warm = (
                self._shards_warm(query.source)
                if isinstance(query.source, ShardedLog)
                else (
                    self.graphs.peek(fp)
                    or self.graphs.has_extendable(query.source)
                )
            )
            graph_available = (
                sink_ok
                and not logical.has_barrier()
                and (warm or seen + 1 >= self.graph_crossover)
            )
        physical = plan_physical(
            logical, info,
            mesh=self.mesh,
            tiny_pairs=self.tiny_pairs,
            memory_budget_events=self.memory_budget_events,
            fused_dicing=self.fused_dicing,
            graph_available=graph_available,
            replay_crossover=self.replay_crossover,
            sharded_crossover=self.sharded_crossover,
            curves=self.calibration_curves,
        )
        lines = [
            f"logical : {logical.describe()}",
            f"rewrites: {', '.join(rewrites) if rewrites else '(none)'}",
            f"physical: {physical.describe()}",
            f"plan key: {logical.key()}",
        ]
        if after is not None:
            tr = after.trace if isinstance(after, QueryResult) else after
            lines.append("-- after: recorded trace --")
            if tr is None:
                lines.append(
                    "trace   : (none recorded — engine trace=False)"
                )
            else:
                exe = tr.executed_backend or "?"
                verdict = (
                    "matched prediction" if exe == physical.backend
                    else f"!= predicted {physical.backend}"
                )
                lines.append(f"executed: {exe} ({verdict})")
                pred, act = tr.predicted_cost_s, tr.actual_cost_s
                if pred is not None and act is not None and pred > 0:
                    drift = " [drift]" if tr.drift is not None else ""
                    lines.append(
                        f"cost    : predicted={pred:.6f}s "
                        f"actual={act:.6f}s ratio={act / pred:.2f}x{drift}"
                    )
                spans = ", ".join(
                    f"{sp.name}={sp.duration_s * 1e3:.3f}ms"
                    for sp in tr.spans
                )
                lines.append(
                    f"spans   : {spans} "
                    f"(coverage {tr.coverage() * 100:.1f}%)"
                )
                lines.append(
                    f"rows    : {tr.rows_scanned} scanned; "
                    f"cache={'hit' if tr.from_cache else 'miss'}"
                )
        return "\n".join(lines)

    def probe(self, query: Query, sink: Optional[Sink] = None) -> PlanProbe:
        """Cost/cache probe for the serving tier: predict — without
        executing, without mutating cache stats or the graph-crossover
        repeat counter — whether this query would be a cache hit, a delta
        resume, or a cold scan, which backend it would pick, and the
        planner's cost prior for that backend.

        :mod:`repro.transport` classifies requests hot (predicted
        cache/delta/graph serve) vs cold (full scan) from this, and keys
        request coalescing on the returned fingerprint + plan key."""
        if sink is None:
            sink = DFGSink()
        info = source_info(query.source)
        logical, _ = canonicalize(
            query.logical_plan(sink), info.activity_names
        )
        fp = fingerprint(query.source)
        plan_key = logical.key()
        cached = self.cache.probe((fp, plan_key))
        delta_hint = False
        if not cached and logical.source in ("memmap", "sharded"):
            delta_hint = self.cache.has_delta_hint(
                self._source_hint(query.source), plan_key
            )
        if isinstance(query.source, UnionSource):
            graph_available = False
        else:
            # same read-only availability signal explain() computes: never
            # bump the repeat counter from a probe
            with self._lock:
                seen = self._topo_seen.get(fp, 0)
            sink_ok = isinstance(logical.sink, TOPOLOGY_SINKS) or (
                isinstance(logical.sink, CONFORMANCE_SINKS)
                and self._conformance_graph_ok(query.source)
            )
            warm = (
                self._shards_warm(query.source)
                if isinstance(query.source, ShardedLog)
                else (
                    self.graphs.peek(fp)
                    or self.graphs.has_extendable(query.source)
                )
            )
            graph_available = (
                sink_ok
                and not logical.has_barrier()
                and (warm or seen + 1 >= self.graph_crossover)
            )
        physical = self._plan_cached(logical, info, graph_available)
        return PlanProbe(
            fingerprint=fp,
            plan_key=plan_key,
            backend=physical.backend,
            cached=cached,
            delta_hint=delta_hint,
            estimated_cost_s=estimate_cost_s(
                physical.backend, info.num_events
            ),
        )

    # -- union / compare (multi-source) --------------------------------------
    @staticmethod
    def _branch_names_of(source) -> List[str]:
        if isinstance(source, EventRepository):
            return list(source.activity_names)
        return memmap_activity_names(source)


    @staticmethod
    def _align_ids(branch_names: List[str], union_names: List[str]) -> np.ndarray:
        uidx = {n: i for i, n in enumerate(union_names)}
        return np.asarray([uidx[n] for n in branch_names], dtype=np.int64)

    def _run_union(self, query: Query, sink: Sink) -> QueryResult:
        """Execute a :class:`UnionSource` plan.

        Distributive sinks (DFG / histogram / compare) run one sub-query per
        branch through :meth:`run` itself — so every branch gets its own
        cache entry, its own cost-model choice, and its own append-aware
        delta path (an append to one log rescans only that log's suffix;
        the other branches are plain cache hits).  Branch results are then
        aligned onto the union activity vocabulary and merged; activity
        masks and views run once at the merge
        (:func:`~repro.query.optimize.distribute_over_union`).

        Non-distributive plans (variants sink, materializing ops) run on the
        canonical concatenated repository instead (budget-gated by the
        planner) — bit-identical by construction.
        """
        union: UnionSource = query.source
        qid = self._c_queries.inc()
        self._c_union.inc()
        if isinstance(sink, CONFORMANCE_SINKS):
            self._c_conformance.inc()
        tr = self._trace_begin(qid, sink, union)
        try:
            s = tr.begin("parse")
            # derived from unresolved branch metadata: a cache hit must not
            # pay an O(E) FromLogs materialization
            union_names = union_activity_names(union)
            logical, rewrites = canonicalize(
                query.logical_plan(sink), union_names
            )
            fp = fingerprint(union)
            key = (fp, logical.key())
            tr.end(s)
            s = tr.begin("cache_probe")
            cached = self.cache.get(key)
            tr.end(s)
            if cached is not None:
                cached.from_cache = True
                self._c_cache_hits.inc()
                tr.from_cache = True
                tr.planned_backend = cached.physical.backend
                tr.executed_backend = "cache"
                if cached.source_trace_id:
                    tr.links["produced_by"] = cached.source_trace_id
                self._trace_finish(tr, cached)
                cached.wall_s = tr.total_s
                return cached

            # miss: resolve the branches (FromLogs memoizes its L×T dice)
            s = tr.begin("plan")
            info = source_info(union)
            physical = self._plan_cached(logical, info)
            tr.end(s)
            tr.planned_backend = physical.backend

            s = tr.begin("merge")
            t0 = time.perf_counter()
            if physical.backend == "concat":
                value, names = self._execute_concat(union, info, logical, fp)
            else:
                st = _collect(None, logical)  # planner-guaranteed barrier-free
                if st.keep is not None:
                    _validate_keep(st.keep, union_names)
                empty = st.window is not None and st.window.empty
                if isinstance(logical.sink, CompareSink):
                    value, names = self._execute_compare(
                        union, logical, st, union_names, empty=empty,
                        union_fp=fp,
                    )
                elif isinstance(logical.sink, CONFORMANCE_SINKS):
                    value, names = self._execute_conformance_union(
                        union, logical, st, union_names
                    )
                else:
                    value, names = self._execute_union_merge(
                        union, logical, st, union_names, empty=empty
                    )
            wall = time.perf_counter() - t0
            tr.end(s)
            self._c_executions.inc()
            tr.executed_backend = physical.backend
            tr.actual_cost_s = wall
            result = QueryResult(
                value=value, names=names, logical=logical, physical=physical,
                from_cache=False, wall_s=wall, rewrites=tuple(rewrites),
                source_trace_id=tr.trace_id,
            )
            s = tr.begin("sink")
            self.cache.put(key, result)
            tr.end(s)
            self._trace_finish(tr, result)
            return result
        except BaseException:
            self._trace_error(tr)
            raise

    def _branch_raw(
        self,
        union: UnionSource,
        logical: LogicalPlan,
        branch_sink: Optional[Sink] = None,
    ):
        """Per-branch *raw* sink values (window pushed down, no mask/view),
        each via a full :meth:`run` so caching + delta apply per branch."""
        branch_ops, _merge = distribute_over_union(logical)
        if branch_sink is None:
            if isinstance(logical.sink, HistogramSink):
                branch_sink = HistogramSink()
            else:  # DFG, compare, and topology sinks all count per-branch Ψ
                branch_sink = DFGSink(backend=logical.sink.backend)
        out = []
        cur = self._current_trace()
        for branch in union.branches:
            src = branch.resolve()
            sub = self.run(Query(src, branch_ops, self), branch_sink)
            if cur is not None and cur.enabled and sub.trace is not None:
                cur.add_branch(branch.name, sub.trace)
            out.append((branch, src, sub.value))
        return out

    def _merged_psi(
        self, union: UnionSource, logical: LogicalPlan,
        union_names: List[str], *, empty: bool,
    ) -> np.ndarray:
        u = len(union_names)
        psi = np.zeros((u, u), dtype=np.int64)
        if not empty:
            for _branch, src, value in self._branch_raw(union, logical):
                ids = self._align_ids(self._branch_names_of(src), union_names)
                psi[np.ix_(ids, ids)] += value
        return psi

    def _merged_counts(
        self, union: UnionSource, logical: LogicalPlan,
        union_names: List[str], *, empty: bool,
    ) -> np.ndarray:
        counts = np.zeros(len(union_names), dtype=np.int64)
        if not empty:
            for _branch, src, value in self._branch_raw(
                union, logical, HistogramSink()
            ):
                ids = self._align_ids(self._branch_names_of(src), union_names)
                counts[ids] += value
        return counts

    def _execute_union_merge(
        self,
        union: UnionSource,
        logical: LogicalPlan,
        st: _Collected,
        union_names: List[str],
        *,
        empty: bool,
    ):
        if isinstance(logical.sink, DFGSink):
            psi = self._merged_psi(union, logical, union_names, empty=empty)
            return self._finish_streaming_dfg(psi, union_names, st)
        if isinstance(logical.sink, (ProcessMapSink, NeighborhoodSink)):
            # branch Ψ (and, for process maps, branch histograms) merge on
            # the union vocabulary; the derivation runs once at the merge.
            # A process map issues two sub-queries per branch (DFG +
            # histogram) — one extra cold scan per branch, deliberately:
            # both sub-results are plain single-log entries the cache and
            # the delta path reuse across every sink type, which a fused
            # Ψ+histogram branch sink would forfeit
            psi = self._merged_psi(union, logical, union_names, empty=empty)
            counts = (
                self._merged_counts(union, logical, union_names, empty=empty)
                if isinstance(logical.sink, ProcessMapSink)
                else np.zeros(len(union_names), dtype=np.int64)
            )
            return self._finish_topology(
                psi, counts, union_names, st, logical.sink
            )
        counts = self._merged_counts(union, logical, union_names, empty=empty)
        return self._finish_streaming_hist(counts, union_names, st)

    @staticmethod
    def _branch_conformance_ops(
        ops: Tuple, branch_names: List[str]
    ) -> Tuple:
        """Distribute conformance (sequence-semantics) ops into one branch:
        every op applies per event, but an activity filter may name
        union-level activities a branch has never seen — intersect it with
        the branch vocabulary so branch validation passes (the missing
        names could not have matched any of the branch's events anyway)."""
        out = []
        for op in ops:
            if isinstance(op, Activities):
                out.append(Activities(
                    tuple(sorted(set(op.keep) & set(branch_names))),
                    op.relink,
                ))
            else:
                out.append(op)
        return tuple(out)

    def _model_for_source(
        self, sink, ops: Tuple, src, st: _Collected
    ) -> ModelSpec:
        """Resolve the (default) model for one concrete source — the
        union, compare, and serve ``model_of`` paths' entry into the
        per-fingerprint model memo.  The memo key carries ``st``'s folded
        keep/view, so a view-governed resolution never aliases a raw one
        on the same source."""
        fp = fingerprint(src)

        def build():
            if isinstance(src, EventRepository):
                repo = src
            elif src.num_events <= self.memory_budget_events:
                repo = self._materialize(src, fp)
            else:
                names = memmap_activity_names(src)
                dest, out_names = self._transform_tables(st, names)
                return self._streaming_default_model(src, dest, out_names)
            names = list(repo.activity_names)
            dest, out_names = self._transform_tables(st, names)
            acts = repo.event_activity.astype(np.int64)
            traces = repo.event_trace
            if dest is not None:
                tacts = dest[acts]
                m = tacts >= 0
                acts, traces = tacts[m], traces[m]
            return self._model_from_arrays(acts, traces, out_names)

        return self._resolve_model(sink, self._model_key(ops, st), fp, build)

    def _execute_conformance_union(
        self,
        union: UnionSource,
        logical: LogicalPlan,
        st: _Collected,
        union_names: List[str],
    ):
        """Fitness/alignments over a union: one shared model (explicit, or
        the reference branch's discovered model — compare() semantics),
        then one sub-query per branch through :meth:`run` so each branch
        keeps its own cache entry and append-aware delta path.  Traces
        never span branches, so the merge concatenates the per-trace
        arrays in branch order and sums the censuses."""
        sink = logical.sink
        spec = (
            sink.model
            if sink.model is not None
            else self._model_for_source(
                sink, logical.ops, union.branches[0].resolve(), st
            )
        )
        pinned = dataclasses.replace(sink, model=spec)
        results = []
        cur = self._current_trace()
        for branch in union.branches:
            src = branch.resolve()
            ops = self._branch_conformance_ops(
                logical.ops, self._branch_names_of(src)
            )
            sub = self.run(Query(src, ops, self), pinned)
            if cur is not None and cur.enabled and sub.trace is not None:
                cur.add_branch(branch.name, sub.trace)
            results.append(sub.value)
        _dest_u, out_names = self._transform_tables(st, union_names)

        def cat(arrays, dtype):
            arrays = [a for a in arrays if a.shape[0]]
            return (
                np.concatenate(arrays) if arrays
                else np.zeros((0,), dtype=dtype)
            )

        census: Dict = {}
        for r in results:
            for edge, c in r.deviating_edges.items():
                census[edge] = census.get(edge, 0) + c
        if isinstance(sink, FitnessSink):
            tf = cat([r.trace_fitness for r in results], np.float64)
            value = ReplayResult(
                fitness=float(tf.mean()) if tf.shape[0] else 1.0,
                trace_fitness=tf,
                perfectly_fitting=sum(r.perfectly_fitting for r in results),
                deviating_edges=census,
            )
            return value, out_names
        fit = cat([r.trace_fitness for r in results], np.float64)
        value = AlignmentResult(
            fitness=float(fit.mean()) if fit.shape[0] else 1.0,
            trace_cost=cat([r.trace_cost for r in results], np.int64),
            trace_fitness=fit,
            variant_costs=cat([r.variant_costs for r in results], np.int64),
            perfectly_fitting=sum(r.perfectly_fitting for r in results),
            empty_cost=results[0].empty_cost,
            deviating_edges=census,
        )
        return value, out_names

    def _execute_compare(
        self,
        union: UnionSource,
        logical: LogicalPlan,
        st: _Collected,
        union_names: List[str],
        *,
        empty: bool,
        union_fp: str,
    ):
        u = len(union_names)
        aligned = []
        if empty:
            aligned = [np.zeros((u, u), np.int64) for _ in union.branches]
        else:
            for _branch, src, value in self._branch_raw(union, logical):
                psi = np.zeros((u, u), dtype=np.int64)
                ids = self._align_ids(self._branch_names_of(src), union_names)
                psi[np.ix_(ids, ids)] += value
                aligned.append(psi)

        vis_names: Optional[List[str]] = None
        psis = []
        for psi in aligned:
            v, names = self._finish_streaming_dfg(psi, union_names, st)
            psis.append(v)
            vis_names = names  # identical per branch: same union axis + view
        value = CompareResult(
            log_names=union.branch_names,
            names=list(vis_names),
            psis=tuple(psis),
            diffs=tuple(p - psis[0] for p in psis),
            # whole-log signal, independent of window/filter/view — served
            # from the per-fingerprint memo when the data hasn't changed
            fitness=self._compare_fitness(union, union_fp),
        )
        return value, list(vis_names)

    def _compare_fitness(
        self, union: UnionSource, union_fp: str
    ) -> Tuple[float, ...]:
        """Replay-fitness drift: every branch replayed against the dependency
        graph discovered from the first (reference) branch — in-budget
        branches columnar, out-of-budget memmap branches via the one-pass
        streaming replayer (never None).

        The value depends only on the union's data (never on the plan's
        window/filter/view), so it is memoized per composite fingerprint —
        a dashboard sliding its window re-uses the same tuple."""
        with self._lock:
            hit = self._fitness_memo.get(union_fp)
            if hit is not None:
                self._fitness_memo.move_to_end(union_fp)
                return hit
        fitness = self._compute_compare_fitness(union)
        with self._lock:
            self._fitness_memo[union_fp] = fitness
            while len(self._fitness_memo) > self._max_fitness_memo:
                self._fitness_memo.popitem(last=False)
        return fitness

    def _compute_compare_fitness(
        self, union: UnionSource
    ) -> Tuple[float, ...]:
        """Whole-log replay fitness of every branch against the reference
        branch's discovered model.  The model comes from the per-fingerprint
        model memo (discovery runs once per data generation), and each
        branch replays through :meth:`run` — in-budget branches
        materialize, out-of-budget memmap branches replay in one streaming
        scan, so no branch ever reports ``None``."""
        raw = _Collected(repo=None)  # whole-log, untransformed signal
        sink = FitnessSink()
        spec = self._model_for_source(
            sink, (), union.branches[0].resolve(), raw
        )
        pinned = FitnessSink(model=spec)
        out = []
        cur = self._current_trace()
        for branch in union.branches:
            src = branch.resolve()
            sub = self.run(Query(src, (), self), pinned)
            if cur is not None and cur.enabled and sub.trace is not None:
                cur.add_branch(branch.name, sub.trace)
            out.append(float(sub.value.fitness))
        return tuple(out)

    def _execute_concat(
        self,
        union: UnionSource,
        info: SourceInfo,
        logical: LogicalPlan,
        fp: str,
    ):
        """Non-distributive union plans run on the materialized canonical
        concatenation (memoized per composite fingerprint ``fp``)."""
        with self._lock:
            repo_u = self._repo_memo.get(fp)
            if repo_u is not None:
                self._repo_memo.move_to_end(fp)
        if repo_u is None:
            named = []
            for branch in union.branches:
                src = branch.resolve()
                if isinstance(src, MemmapLog):
                    src = self._materialize(
                        src, fingerprint(src), branch.name
                    )
                named.append((branch.name, src))
            repo_u = concat_repositories(
                named, activity_vocab=list(info.activity_names)
            )
            with self._lock:
                self._repo_memo[fp] = repo_u
                while len(self._repo_memo) > self.repo_memo_size:
                    self._repo_memo.popitem(last=False)
        # single-source execution on the concatenation, planned on its shape
        inner = LogicalPlan("repository", logical.ops, logical.sink)
        physical = self._plan_cached(inner, source_info(repo_u))
        value, names, _resume = self._execute(
            repo_u, inner, physical, source_fp=fp
        )
        return value, names

    # -- delta (append-aware) ------------------------------------------------
    @staticmethod
    def _source_hint(source) -> Optional[str]:
        """Stable identity for delta-candidate lookup.  Only a hint: a path
        reused for unrelated data fails the prefix-digest proof and falls
        back to a full execution."""
        if isinstance(source, (MemmapLog, ShardedLog)):
            return realpath_of(source)
        return None

    def _try_delta(
        self,
        log: MemmapLog,
        logical: LogicalPlan,
        key: Tuple[str, str],
        rewrites: Tuple[str, ...],
        tr: QueryTrace,
    ) -> Optional[QueryResult]:
        """Append-aware path for a cache miss on a memmap source.

        If the cache holds this plan's result for a *prefix* of ``log`` —
        proven by recomputing the prefix digest on the current bytes, never
        assumed from the path hint — then either:

        * the plan's row range lies entirely inside the proven prefix
          (window over old data): the cached result is the recompute, serve
          it without any scan; or
        * resume the cached streaming state (Ψ + per-case tails) over just
          the appended suffix — the carried ``last_by_case`` links the pairs
          that straddle the append boundary, so the result is bit-identical
          to a full rescan.
        """
        fp_new, plan_key = key
        if logical.has_barrier() or not isinstance(
            logical.sink, (DFGSink, HistogramSink, FitnessSink)
        ):
            return None
        if (
            isinstance(logical.sink, FitnessSink)
            and logical.sink.model is None
        ):
            # the default model is re-discovered from the *grown* log; the
            # cached state replayed against the old model would not be
            # bit-identical to a recompute — full replay instead
            return None
        hint = self._source_hint(log)
        cand = self.cache.delta_candidate(hint, plan_key)
        if cand is None:
            return None
        s = tr.begin("delta")
        try:
            old_fp, old_result, resume = cand
            old = parse_memmap_fingerprint(old_fp)
            if old is None or not 0 < old.num_events < log.num_events:
                return None
            if old.num_activities > log.num_activities:
                return None  # vocabulary shrank: not an append-only change
            if prefix_digest(log, old.num_events) != old.prefix:
                # rewritten / truncated-and-regrown: stop consulting this
                # hint
                self.cache.drop_hint(hint, plan_key)
                return None

            st = _collect(None, logical)  # barrier-free: no repo needed
            names = memmap_activity_names(log)
            if st.keep is not None:
                _validate_keep(st.keep, names)
            if st.window is not None and st.window.empty:
                return None  # the zero-result short-circuit is cheaper
            lo, hi = (
                log.rows_for_window(st.window.t0, st.window.t1)
                if st.window is not None
                else (0, log.num_events)
            )

            if (
                hi <= old.num_events
                and old.num_activities == log.num_activities
            ):
                # free rewrite: every row the plan can touch lies in the
                # proven prefix, so the cached result *is* the recompute,
                # bit for bit
                old_result.from_cache = True
                self._c_delta_free_hits.inc()
                tr.from_cache = True
                tr.planned_backend = "delta"
                tr.executed_backend = "delta_free"
                tr.delta_rows = (old.num_events, old.num_events)
                if old_result.source_trace_id:
                    tr.links["produced_by"] = old_result.source_trace_id
                # republish under the new fingerprint: the next run is a
                # plain hit
                self.cache.put(
                    key, old_result, resume=resume, source_hint=hint
                )
                return old_result

            if resume is None or resume.rows_end > old.num_events:
                return None
            if (
                isinstance(logical.sink, FitnessSink)
                and resume.replay is None
            ):
                return None
            start = max(resume.rows_end, lo)
            tr.planned_backend = "delta"
            tr.delta_rows = (start, hi)
            tr.predicted_cost_s = estimate_cost_s(
                "delta", max(hi - start, 0)
            )
            if log.num_events:
                self._h_delta_fraction.observe(
                    max(hi - start, 0) / log.num_events
                )
            t0 = time.perf_counter()
            value, out_names, new_resume = self._execute_delta(
                log, logical, st, resume, start, hi
            )
            wall = time.perf_counter() - t0
            tr.executed_backend = "delta"
            tr.actual_cost_s = wall
            physical = PhysicalPlan(
                backend="delta",
                row_range_window=(
                    (st.window.t0, st.window.t1)
                    if st.window is not None
                    else None
                ),
                activities_as_output_mask=st.keep is not None,
                delta_rows=(start, hi),
                notes=(f"resume@{start}", f"suffix_rows={hi - start}"),
            )
            self._c_executions.inc()
            self._c_delta_hits.inc()
            result = QueryResult(
                value=value, names=out_names, logical=logical,
                physical=physical, from_cache=False, wall_s=wall,
                rewrites=rewrites, source_trace_id=tr.trace_id,
            )
            self.cache.put(key, result, resume=new_resume, source_hint=hint)
            return result
        finally:
            tr.end(s)

    def _execute_delta(
        self,
        log: MemmapLog,
        logical: LogicalPlan,
        st: _Collected,
        resume: ResumableState,
        start: int,
        hi: int,
    ):
        names = memmap_activity_names(log)
        self._note_rows(max(hi - start, 0))
        if isinstance(logical.sink, FitnessSink):
            dest, out_names = self._transform_tables(st, names)
            rep = StreamingReplayer.restore(
                resume.replay, out_names, logical.sink.model,
                observer=self._observe_replay_chunk,
            )
            for a, c, t in log.iter_chunks(row_range=(start, hi)):
                rep.update(*self._apply_stream_transform(dest, a, c, t))
            new_resume = None
            if hi == log.num_events:
                new_resume = ResumableState(
                    rows_end=hi, num_activities=log.num_activities,
                    replay=rep.snapshot(),
                )
            return rep.finalize(), out_names, new_resume
        if isinstance(logical.sink, DFGSink):
            miner = StreamingDFGMiner.restore(
                resume.miner, num_activities=log.num_activities
            )
            for a, c, t in log.iter_chunks(row_range=(start, hi)):
                miner.update(a, c, t)
            new_resume = None
            if hi == log.num_events:
                new_resume = ResumableState(
                    rows_end=hi, num_activities=log.num_activities,
                    miner=miner.snapshot(),
                )
            value, out_names = self._finish_streaming_dfg(
                miner.finalize(), names, st
            )
            return value, out_names, new_resume
        counts = np.zeros(log.num_activities, dtype=np.int64)
        counts[: resume.num_activities] = resume.counts
        for a, _, _ in log.iter_chunks(row_range=(start, hi)):
            counts += np.bincount(a, minlength=log.num_activities)
        new_resume = None
        if hi == log.num_events:
            new_resume = ResumableState(
                rows_end=hi, num_activities=log.num_activities,
                counts=counts.copy(),
            )
        value, out_names = self._finish_streaming_hist(counts, names, st)
        return value, out_names, new_resume

    # -- execution -----------------------------------------------------------
    def _execute(
        self, source, logical: LogicalPlan, physical: PhysicalPlan,
        source_fp: Optional[str] = None,
    ):
        if not logical.has_barrier() and isinstance(
            logical.sink, (DFGSink, HistogramSink, ProcessMapSink,
                           NeighborhoodSink)
        ):
            pre = _collect(None, logical)
            if pre.window is not None and pre.window.empty:
                # an empty window can select no pair/event: zeros of the
                # right shape, without materializing or scanning anything
                value, names = self._empty_result(source, logical, pre)
                return value, names, None
        if physical.backend == "sharded-graph":
            return self._execute_sharded(source, logical, physical)
        if physical.backend == "graph":
            return self._execute_graph(source, logical, physical, source_fp)
        if physical.backend == "streaming":
            return self._execute_streaming(
                source, logical, physical, source_fp
            )
        repo = (
            self._materialize(source, source_fp)
            if logical.source in ("memmap", "sharded")
            else source
        )
        st = _collect(repo, logical)
        # full-scan backends read every event of the materialized repo;
        # chunked paths (streaming/delta) and the graph tier attribute
        # their own rows (a graph hit reads the CSR, not the log)
        self._note_rows(repo.num_events)
        if st.keep is not None:
            _validate_keep(st.keep, st.repo.activity_names)
        if isinstance(logical.sink, DFGSink):
            value, names = self._dfg_on_repo(st, logical, physical)
        elif isinstance(logical.sink, HistogramSink):
            value, names = self._histogram_on_repo(st)
        elif isinstance(logical.sink, VariantsSink):
            value, names = self._variants_on_repo(st, logical.sink)
        elif isinstance(logical.sink, (ProcessMapSink, NeighborhoodSink)):
            value, names = self._topology_on_repo(st, logical, physical)
        elif isinstance(logical.sink, CONFORMANCE_SINKS):
            value, names = self._conformance_on_repo(st, logical, source_fp)
        else:
            raise QueryPlanError(f"unknown sink {logical.sink!r}")
        return value, names, None

    def _empty_result(self, source, logical: LogicalPlan, st: _Collected):
        names = (
            list(source.activity_labels())
            if logical.source in ("memmap", "sharded")
            else list(source.activity_names)
        )
        if st.keep is not None:
            _validate_keep(st.keep, names)
        a = len(names)
        if isinstance(logical.sink, (ProcessMapSink, NeighborhoodSink)):
            return self._finish_topology(
                np.zeros((a, a), dtype=np.int64),
                np.zeros(a, dtype=np.int64),
                names, st, logical.sink,
            )
        if isinstance(logical.sink, DFGSink):
            return self._finish_streaming_dfg(
                np.zeros((a, a), dtype=np.int64), names, st
            )
        return self._finish_streaming_hist(
            np.zeros(a, dtype=np.int64), names, st
        )

    def _materialize(
        self,
        log: MemmapLog,
        fp: Optional[str],
        log_name: Optional[str] = None,
    ) -> EventRepository:
        if fp is not None:
            with self._lock:
                repo = self._repo_memo.get(fp)
                if repo is not None:
                    self._repo_memo.move_to_end(fp)
                    if log_name is not None and repo.log_names != [log_name]:
                        # same bytes, different branch name: share the
                        # columns, fix the provenance
                        repo = dataclasses.replace(repo, log_names=[log_name])
                    return repo
        repo = repository_from_memmap(log, log_name)
        if fp is not None:
            with self._lock:
                self._repo_memo[fp] = repo
                while len(self._repo_memo) > self.repo_memo_size:
                    self._repo_memo.popitem(last=False)
        return repo

    def _dfg_on_repo(
        self, st: _Collected, logical: LogicalPlan, physical: PhysicalPlan
    ):
        repo = st.repo
        names = list(repo.activity_names)
        src, dst, valid = repo.df_pairs()
        window_fused = physical.fused_dicing and st.window is not None

        if st.window is not None and not window_fused:
            valid = valid & pair_mask_for_window(repo, (st.window.t0, st.window.t1))
        keep_ids = None
        if st.keep is not None:
            keep_ids = np.asarray(
                [names.index(a) for a in st.keep], dtype=np.int64
            )
            if not physical.activities_as_output_mask:
                m = np.isin(repo.event_activity, keep_ids)
                if m.shape[0] >= 2:
                    valid = valid & m[:-1] & m[1:]

        if physical.view_pushdown:
            g, labels = st.view.to_view().group_matrix(names)
            gmap = np.argmax(g, axis=1).astype(np.int32)
            src, dst = gmap[src], gmap[dst]
            a_count = len(labels)
        else:
            a_count = repo.num_activities

        psi = self._count(src, dst, valid, a_count, st, physical, repo)

        if physical.view_pushdown:
            vis = [i for i, l in enumerate(labels) if l != HIDDEN]
            return psi[np.ix_(vis, vis)], [labels[i] for i in vis]
        if keep_ids is not None and physical.activities_as_output_mask:
            psi = _zero_outside(psi, keep_ids)
        if st.view is not None:
            view = st.view.to_view()
            return view.apply_to_dfg(psi, names), view.visible_names(names)
        return psi, names

    def _count(
        self, src, dst, valid, a_count, st: _Collected,
        physical: PhysicalPlan, repo: EventRepository,
    ) -> np.ndarray:
        backend = physical.backend
        if backend == "numpy":
            return dfg_numpy(
                np.asarray(src), np.asarray(dst), np.asarray(valid), a_count
            )
        if backend == "distributed":
            return distributed_dfg(
                self.mesh, np.asarray(src, np.int32), np.asarray(dst, np.int32),
                np.asarray(valid, bool), a_count,
            )
        if backend == "pallas" and physical.fused_dicing and st.window is not None:
            from repro.kernels.dfg_count import ops as _ops

            ts = repo.event_time
            out = _ops.dfg_count_diced(
                np.asarray(src, np.int32), np.asarray(dst, np.int32),
                np.asarray(valid, bool),
                ts[:-1], ts[1:],
                np.asarray([st.window.t0, st.window.t1]),
                num_activities=a_count,
            )
            return np.asarray(out, dtype=np.int64)
        return dfg(src, dst, valid, a_count, backend=backend)

    def _histogram_on_repo(self, st: _Collected):
        repo = st.repo
        names = list(repo.activity_names)
        mask = np.ones(repo.num_events, dtype=bool)
        if st.window is not None:
            ts = repo.event_time
            mask &= (ts >= st.window.t0) & (ts < st.window.t1)
        counts = np.bincount(
            repo.event_activity[mask], minlength=repo.num_activities
        ).astype(np.int64)
        if st.keep is not None:
            keep_ids = np.asarray([names.index(a) for a in st.keep], np.int64)
            km = np.zeros(repo.num_activities, dtype=bool)
            km[keep_ids] = True
            counts = np.where(km, counts, 0)
        if st.view is not None:
            view = st.view.to_view()
            g, labels = view.group_matrix(names)
            counts = counts @ g
            vis = [i for i, l in enumerate(labels) if l != HIDDEN]
            return counts[vis], [labels[i] for i in vis]
        return counts, names

    def _variants_on_repo(self, st: _Collected, sink: VariantsSink):
        if st.view is not None:
            raise QueryPlanError("view() is not supported for variants()")
        repo = st.repo
        # for a variant table, pure predicates must change the *sequences*,
        # so they are executed with re-linking semantics here
        if st.window is not None or st.keep is not None:
            repo = dice_repository(
                repo,
                time_window=(
                    (st.window.t0, st.window.t1) if st.window else None
                ),
                activities=list(st.keep) if st.keep else None,
            )
        tv = trace_variants(repo)
        if sink.k is not None:
            tv = dataclasses.replace(
                tv, counts=tv.counts[: sink.k],
                sequences=tv.sequences[: sink.k],
            )
        return tv, None

    # -- conformance (fitness / alignments) ----------------------------------
    @staticmethod
    def _transform_tables(st: _Collected, names: List[str]):
        """(dest, out_names) for conformance's sequence semantics: ``dest``
        maps each raw activity id to its transformed id, ``-1`` meaning the
        event is dropped (filtered out / hidden) and its neighbors re-link.
        ``dest=None`` is the identity (no keep / no view)."""
        if st.keep is None and st.view is None:
            return None, list(names)
        a = len(names)
        dest = np.arange(a, dtype=np.int64)
        out_names = list(names)
        if st.keep is not None:
            kept = set(st.keep)
            for i, n in enumerate(names):
                if n not in kept:
                    dest[i] = -1
        if st.view is not None:
            view = st.view.to_view()
            out_names = view.visible_names(names)
            gidx = {g: i for i, g in enumerate(out_names)}
            mapped = np.full(a, -1, dtype=np.int64)
            for i, n in enumerate(names):
                if dest[i] < 0:
                    continue
                g = view.mapping.get(n, view.default)
                mapped[i] = gidx.get(g, -1)  # HIDDEN drops the event
            dest = mapped
        return dest, out_names

    @staticmethod
    def _model_key(ops: Tuple, st: _Collected) -> Tuple:
        """What the default model depends on besides the data: any barrier
        ops (they change the source the model is discovered from) plus the
        *folded* keep/view transform.  Keyed on ``st`` — not the raw op
        list — so every resolution route (plan ops, compare's raw signal,
        serve's grant view) that means the same transform shares one memo
        entry, and routes that mean different transforms never collide
        (a view-protected model must not alias the raw one)."""
        return (
            tuple(op for op in ops if is_barrier(op)),
            st.keep,
            st.view,
        )

    def _resolve_model(
        self, sink, key_tail: Tuple, fp: Optional[str], build
    ) -> ModelSpec:
        """The sink's model, or the memoized default (discovered from the
        whole source under the plan's transform — windows are a drift
        *question* against the overall process, so a sliding dashboard
        keeps one model per data generation).  ``key_tail`` comes from
        :meth:`_model_key`."""
        if sink.model is not None:
            return sink.model
        key = (fp,) + key_tail
        if fp is not None:
            with self._lock:
                hit = self._model_memo.get(key)
                if hit is not None:
                    self._model_memo.move_to_end(key)
                    return hit
        spec = ModelSpec.from_model(build())
        if fp is not None:
            with self._lock:
                self._model_memo[key] = spec
                while len(self._model_memo) > self._max_model_memo:
                    self._model_memo.popitem(last=False)
        return spec

    @staticmethod
    def _model_from_arrays(
        acts: np.ndarray, traces: np.ndarray, out_names: List[str]
    ) -> DiscoveredModel:
        """Dependency-graph discovery from (already transformed) canonical
        columns — Ψ plus trace-boundary counts, all vectorized."""
        a = len(out_names)
        n = acts.shape[0]
        starts = np.zeros(a, dtype=np.int64)
        ends = np.zeros(a, dtype=np.int64)
        if n == 0:
            psi = np.zeros((a, a), dtype=np.int64)
        else:
            if n >= 2:
                valid = traces[:-1] == traces[1:]
                psi = dfg_numpy(acts[:-1], acts[1:], valid, a)
            else:
                psi = np.zeros((a, a), dtype=np.int64)
            is_start = np.ones(n, dtype=bool)
            is_start[1:] = traces[1:] != traces[:-1]
            is_end = np.ones(n, dtype=bool)
            is_end[:-1] = traces[:-1] != traces[1:]
            np.add.at(starts, acts[is_start], 1)
            np.add.at(ends, acts[is_end], 1)
        return discover_dependency_graph(psi, out_names, starts, ends)

    def _conformance_value(
        self,
        sink,
        acts: np.ndarray,
        traces: np.ndarray,
        out_names: List[str],
        model: ModelSpec,
        num_traces: Optional[int],
    ):
        if isinstance(sink, FitnessSink):
            return replay_fitness_arrays(
                acts, traces, out_names, model, num_traces=num_traces
            )
        return align_arrays(
            acts, traces, out_names, model, num_traces=num_traces,
            backend="auto",
        )

    def _conformance_from_columns(
        self,
        logical: LogicalPlan,
        st: _Collected,
        source_fp: Optional[str],
        acts: np.ndarray,
        traces: np.ndarray,
        times: np.ndarray,
        num_traces: int,
        names: List[str],
    ):
        """Shared columnar/graph conformance: transform the event columns
        (sequence semantics), resolve the model from the whole selection,
        replay/align the windowed selection."""
        dest, out_names = self._transform_tables(st, names)
        acts = np.asarray(acts).astype(np.int64)
        traces = np.asarray(traces)
        keep_mask = np.ones(acts.shape[0], dtype=bool)
        tacts = acts
        if dest is not None:
            tacts = dest[acts]
            keep_mask &= tacts >= 0
        model = self._resolve_model(
            logical.sink, self._model_key(logical.ops, st), source_fp,
            lambda: self._model_from_arrays(
                tacts[keep_mask], traces[keep_mask], out_names
            ),
        )
        windowed = st.window is not None
        if windowed:
            ts = np.asarray(times)
            keep_mask &= (ts >= st.window.t0) & (ts < st.window.t1)
        transformed = dest is not None or windowed
        value = self._conformance_value(
            logical.sink,
            tacts[keep_mask] if transformed else tacts,
            traces[keep_mask] if transformed else traces,
            out_names, model,
            num_traces=None if transformed else num_traces,
        )
        return value, out_names

    def _conformance_on_repo(
        self, st: _Collected, logical: LogicalPlan, source_fp: Optional[str]
    ):
        repo = st.repo
        return self._conformance_from_columns(
            logical, st, source_fp,
            repo.event_activity, repo.event_trace, repo.event_time,
            repo.num_traces, list(repo.activity_names),
        )

    # -- sharded graph (case-partitioned shard merge) ------------------------
    def _shard_raw(
        self,
        sharded: ShardedLog,
        branch_ops: Tuple,
        sub_sink: Sink,
        union_names: List[str],
    ):
        """Per-shard raw sink values + alignment maps, each through a full
        :meth:`run` — so every shard keeps its own cache entry, its own
        CSR snapshot in the graph store, and its own append-aware delta
        path (an append touches only the owning shards' fingerprints; the
        other shards answer as plain cache hits with zero rows scanned).
        Sub-traces ride the enclosing trace as ``shard<k>`` branches, like
        union branches."""
        vals, maps = [], []
        cur = self._current_trace()
        for k, shard in sharded.present_shards():
            sub = self.run(Query(shard, branch_ops, self), sub_sink)
            if cur is not None and cur.enabled and sub.trace is not None:
                cur.add_branch(f"shard{k}", sub.trace)
            vals.append(sub.value)
            maps.append(
                self._align_ids(memmap_activity_names(shard), union_names)
            )
        return vals, maps

    def _execute_sharded(
        self, sharded: ShardedLog, logical: LogicalPlan,
        physical: PhysicalPlan,
    ):
        """Topology/histogram sinks over a case-partitioned sharded log.

        Cases never span shards under the ``case % K`` partition, so every
        DF pair is counted by exactly one shard and the global Ψ is a *pure
        sum* of the per-shard Ψ matrices on the aligned union vocabulary
        (:func:`repro.core.distributed.merge_shard_psis` — the same psum
        contract as the distributed backend; with a mesh the reduction runs
        on-device).  Each shard answers through the graph tier (pinned
        ``backend="graph"`` sub-query), so repeated queries hit resident
        CSR snapshots and never rescan the log; masks and views run once at
        the merge, exactly like union branches.
        """
        self._c_shard.inc()
        names = list(sharded.activity_labels())
        st = _collect(None, logical)  # planner guarantees barrier-free
        if st.keep is not None:
            _validate_keep(st.keep, names)
        branch_ops, _merge = distribute_over_union(logical)
        tr = self._current_trace()
        sink = logical.sink

        if isinstance(sink, HistogramSink):
            vals, maps = self._shard_raw(
                sharded, branch_ops, HistogramSink(backend="graph"), names
            )
            s = tr.begin("shard_merge") if tr is not None else None
            counts = merge_shard_counts(vals, maps, len(names))
            value, out_names = self._finish_streaming_hist(counts, names, st)
            if s is not None:
                tr.end(s)
            return value, out_names, None

        psis, maps = self._shard_raw(
            sharded, branch_ops, DFGSink(backend="graph"), names
        )
        counts_vals = cmaps = None
        if isinstance(sink, ProcessMapSink):
            # node weights need a second, histogram sub-query per shard —
            # same deliberate trade as the union merge: both sub-results
            # stay plain single-log cache entries every sink type reuses
            counts_vals, cmaps = self._shard_raw(
                sharded, branch_ops, HistogramSink(backend="graph"), names
            )
        s = tr.begin("shard_merge") if tr is not None else None
        psi = merge_shard_psis(psis, maps, len(names), mesh=self.mesh)
        if isinstance(sink, DFGSink):
            value, out_names = self._finish_streaming_dfg(psi, names, st)
        else:
            counts = (
                merge_shard_counts(counts_vals, cmaps, len(names))
                if counts_vals is not None
                else np.zeros(len(names), dtype=np.int64)
            )
            value, out_names = self._finish_topology(
                psi, counts, names, st, sink
            )
        if s is not None:
            tr.end(s)
        return value, out_names, None

    # -- graph (event-knowledge-graph store) ---------------------------------
    def _execute_graph(
        self, source, logical: LogicalPlan, physical: PhysicalPlan,
        source_fp: Optional[str],
    ):
        """Topology sinks answered from the CSR graph store.

        The graph is built once per source fingerprint (appends extend it
        over the proven suffix) and then:

        * un-windowed, un-filtered plans are pure lookups — DFG densifies
          the CSR, neighborhood/process map walk it directly;
        * filters/views post-process the densified Ψ exactly like the
          streaming finishers (count-preserving, pinned bit-identical);
        * a window needs the event-level tables (full graphs only): pairs
          are re-aggregated under the endpoint mask — same O(E) as
          columnar, kept only for pinned-backend correctness.
        """
        fp = source_fp if source_fp is not None else fingerprint(source)
        g = self.graphs.graph_for(source, fp, on_rows=self._note_rows)
        self._c_graph.inc()
        names = list(g.activity_names)
        st = _collect(None, logical)  # planner guarantees barrier-free
        if st.keep is not None:
            _validate_keep(st.keep, names)
        if isinstance(logical.sink, CONFORMANCE_SINKS):
            # replay/align over the stored event tables — the canonical
            # :BELONGS_TO order makes each case a contiguous segment whose
            # :DF steps are adjacent rows; no source re-materialization
            if not g.has_event_tables:
                raise QueryPlanError(
                    "conformance needs event tables; this graph is "
                    "topology-only (built out-of-core) — use streaming/auto"
                )
            value, out_names = self._conformance_from_columns(
                logical, st, fp,
                g.event_activity, g.event_trace, g.event_time,
                g.num_traces, names,
            )
            return value, out_names, None
        windowed = st.window is not None and not st.window.empty
        plain = st.window is None and st.keep is None and st.view is None

        if isinstance(logical.sink, HistogramSink):
            # counts straight from the store: the :OF_TYPE in-degrees
            # un-windowed, the time index (or a table mask) under a window
            if windowed:
                if not g.has_event_tables:
                    raise QueryPlanError(
                        "windowed graph histograms need event tables; this "
                        "graph is topology-only (built out-of-core) — use "
                        "streaming/auto"
                    )
                idx = g.window_index()
                if idx is not None:
                    counts = idx.counts(
                        st.window.t0, st.window.t1, g.num_activities
                    )
                else:
                    times = np.asarray(g.event_time)
                    m = (times >= st.window.t0) & (times < st.window.t1)
                    counts = np.bincount(
                        np.asarray(g.event_activity)[m],
                        minlength=g.num_activities,
                    ).astype(np.int64)
            else:
                counts = np.asarray(g.node_counts)
            value, out_names = self._finish_streaming_hist(counts, names, st)
            return value, out_names, None

        if plain and isinstance(logical.sink, NeighborhoodSink):
            self._check_center(logical.sink, names)
            value = derive_neighborhood(
                g.adj, g.radj, names, logical.sink.activity,
                logical.sink.k, logical.sink.direction,
            )
            return value, names, None
        if plain and isinstance(logical.sink, ProcessMapSink):
            value = derive_process_map(
                g.adj, g.node_counts, names,
                logical.sink.top, logical.sink.edge_top,
            )
            return value, names, None

        if windowed:
            if not g.has_event_tables:
                raise QueryPlanError(
                    "windowed graph queries need event tables; this graph "
                    "is topology-only (built out-of-core) — use "
                    "streaming/auto"
                )
            psi, counts = self._windowed_from_tables(
                g, st.window,
                need_counts=not isinstance(logical.sink, DFGSink),
            )
        else:
            psi = g.psi()
            counts = np.asarray(g.node_counts)
        if isinstance(logical.sink, DFGSink):
            value, out_names = self._finish_streaming_dfg(psi, names, st)
        else:
            value, out_names = self._finish_topology(
                psi, counts, names, st, logical.sink
            )
        return value, out_names, None

    @staticmethod
    def _windowed_from_tables(
        g: EventGraph, window: Window, need_counts: bool = True
    ):
        """(Ψ, node counts) under a time window, from the graph's canonical
        event tables — identical to the columnar pair-endpoint mask.
        ``need_counts=False`` (DFG sinks) skips the per-activity bincount.

        Resident graphs answer through their lazily built
        :class:`~repro.graph.build.WindowIndex` (two binary searches +
        O(window rows)); the masked O(E) path below is the fallback for
        tables the index can't represent."""
        a = g.num_activities
        idx = g.window_index()
        if idx is not None:
            psi = idx.psi(window.t0, window.t1, a)
            counts = (
                idx.counts(window.t0, window.t1, a) if need_counts else None
            )
            return psi, counts
        acts = np.asarray(g.event_activity)
        traces = np.asarray(g.event_trace)
        times = np.asarray(g.event_time)
        m = (times >= window.t0) & (times < window.t1)
        counts = (
            np.bincount(acts[m], minlength=a).astype(np.int64)
            if need_counts else None
        )
        if acts.shape[0] < 2:
            return np.zeros((a, a), dtype=np.int64), counts
        valid = (traces[:-1] == traces[1:]) & m[:-1] & m[1:]
        return dfg_numpy(acts[:-1], acts[1:], valid, a), counts

    @staticmethod
    def _check_center(sink: NeighborhoodSink, names: List[str]) -> None:
        if sink.activity not in names:
            raise QueryPlanError(
                f"unknown activity {sink.activity!r} for neighborhood(); "
                "under a view, name a visible group label"
            )

    def _finish_topology(
        self,
        psi_raw: np.ndarray,
        counts_raw: np.ndarray,
        names: List[str],
        st: _Collected,
        sink: Sink,
    ):
        """Mask/project a raw Ψ (+ raw node counts) and derive the topology
        sink's value.  Every execution path (graph, columnar, streaming,
        union merge) funnels through this + the same derive functions, so
        backend equivalence reduces to Ψ equivalence."""
        psi_v, names_v = self._finish_streaming_dfg(psi_raw, names, st)
        if isinstance(sink, ProcessMapSink):
            counts_v, _hnames = self._finish_streaming_hist(
                counts_raw, names, st
            )
            value = derive_process_map(
                csr_from_dense(psi_v), counts_v, names_v,
                sink.top, sink.edge_top,
            )
            return value, names_v
        self._check_center(sink, names_v)
        adj = csr_from_dense(psi_v)
        value = derive_neighborhood(
            adj, adj.transpose(), names_v, sink.activity, sink.k,
            sink.direction,
        )
        return value, names_v

    def _topology_on_repo(
        self, st: _Collected, logical: LogicalPlan, physical: PhysicalPlan
    ):
        """Columnar path for process map / neighborhood: count Ψ on the
        planned backend (window as pair predicate or fused into the
        kernel), raw node counts alongside, then the shared derivation."""
        repo = st.repo
        src, dst, valid = repo.df_pairs()
        window_fused = physical.fused_dicing and st.window is not None
        ev_mask = np.ones(repo.num_events, dtype=bool)
        if st.window is not None:
            ts = repo.event_time
            ev_mask = (ts >= st.window.t0) & (ts < st.window.t1)
            if not window_fused:
                valid = valid & pair_mask_for_window(
                    repo, (st.window.t0, st.window.t1)
                )
        psi = self._count(
            src, dst, valid, repo.num_activities, st, physical, repo
        )
        counts = np.bincount(
            repo.event_activity[ev_mask], minlength=repo.num_activities
        ).astype(np.int64)
        return self._finish_topology(
            psi, counts, list(repo.activity_names), st, logical.sink
        )

    # -- streaming (out-of-core) ---------------------------------------------
    def _finish_streaming_dfg(self, psi: np.ndarray, names: List[str], st: _Collected):
        """Post-mask + project a raw Ψ (shared by streaming, delta, and the
        empty-window short-circuit — the raw matrix is what resumable state
        carries, so post-processing must be reapplicable)."""
        if st.keep is not None:
            keep_ids = np.asarray([names.index(a) for a in st.keep], np.int64)
            psi = _zero_outside(psi, keep_ids)
        if st.view is not None:
            view = st.view.to_view()
            return view.apply_to_dfg(psi, names), view.visible_names(names)
        return psi, names

    def _finish_streaming_hist(self, counts: np.ndarray, names: List[str], st: _Collected):
        if st.keep is not None:
            keep_ids = np.asarray([names.index(a) for a in st.keep], np.int64)
            km = np.zeros(len(names), dtype=bool)
            km[keep_ids] = True
            counts = np.where(km, counts, 0)
        if st.view is not None:
            view = st.view.to_view()
            g, labels = view.group_matrix(names)
            counts = counts @ g
            vis = [i for i, l in enumerate(labels) if l != HIDDEN]
            return counts[vis], [labels[i] for i in vis]
        return counts, names

    def _apply_stream_transform(self, dest, a, c, t):
        """Sequence-semantics transform of one chunk: drop masked events,
        relabel survivors (re-linking is implicit — the replayer only ever
        sees the surviving stream)."""
        if dest is None:
            return a, c, t
        ta = dest[np.asarray(a).astype(np.int64)]
        m = ta >= 0
        return ta[m], np.asarray(c)[m], np.asarray(t)[m]

    def _streaming_default_model(
        self, log: MemmapLog, dest, out_names: List[str]
    ) -> DiscoveredModel:
        """Whole-log discovery in one O(A² + chunk) scan (memoized by the
        caller per source fingerprint)."""
        disc = StreamingModelDiscoverer(len(out_names))
        rows = 0
        for a, c, t in log.iter_chunks():
            rows += a.shape[0]
            disc.update(*self._apply_stream_transform(dest, a, c, t))
        self._note_rows(rows)
        return disc.finalize(out_names)

    def _streaming_conformance(
        self,
        log: MemmapLog,
        logical: LogicalPlan,
        physical: PhysicalPlan,
        st: _Collected,
        names: List[str],
        source_fp: Optional[str],
    ):
        """One-pass streaming replay (FitnessSink only — alignments need
        the variant table and are budget-gated by the planner)."""
        dest, out_names = self._transform_tables(st, names)
        model = self._resolve_model(
            logical.sink, self._model_key(logical.ops, st), source_fp,
            lambda: self._streaming_default_model(log, dest, out_names),
        )
        if st.window is not None and st.window.empty:
            rng = (0, 0)
        else:
            window = physical.row_range_window
            rng = (
                log.rows_for_window(*window) if window
                else (0, log.num_events)
            )
        self._note_rows(max(rng[1] - rng[0], 0))
        rep = StreamingReplayer(
            out_names, model, observer=self._observe_replay_chunk
        )
        for a, c, t in log.iter_chunks(row_range=rng):
            rep.update(*self._apply_stream_transform(dest, a, c, t))
        resume = None
        if rng[1] == log.num_events and logical.sink.model is not None:
            # resumable only under a pinned model: a default model is
            # re-discovered from the grown log, invalidating old state
            resume = ResumableState(
                rows_end=rng[1], num_activities=log.num_activities,
                replay=rep.snapshot(),
            )
        return rep.finalize(), out_names, resume

    def _execute_streaming(
        self,
        log: MemmapLog,
        logical: LogicalPlan,
        physical: PhysicalPlan,
        source_fp: Optional[str] = None,
    ):
        names = memmap_activity_names(log)
        st = _collect(None, logical)  # plan guarantees no barriers here
        if st.keep is not None:
            _validate_keep(st.keep, names)
        if isinstance(logical.sink, FitnessSink):
            return self._streaming_conformance(
                log, logical, physical, st, names, source_fp
            )
        # the planner owns the row-range pushdown decision; consume it here
        # so describe()/explain() always reflect what actually runs
        window = physical.row_range_window
        rng = log.rows_for_window(*window) if window else (0, log.num_events)
        self._note_rows(max(rng[1] - rng[0], 0))
        if isinstance(logical.sink, DFGSink):
            miner = StreamingDFGMiner(log.num_activities)
            for a, c, t in log.iter_chunks(row_range=rng):
                miner.update(a, c, t)
            # a scan that consumed the log through its last row is resumable
            # across future appends (the miner's per-case tails link pairs
            # straddling the append boundary)
            resume = None
            if rng[1] == log.num_events:
                resume = ResumableState(
                    rows_end=rng[1], num_activities=log.num_activities,
                    miner=miner.snapshot(),
                )
            value, out_names = self._finish_streaming_dfg(
                miner.finalize(), names, st
            )
            return value, out_names, resume
        if isinstance(logical.sink, HistogramSink):
            counts = np.zeros(log.num_activities, dtype=np.int64)
            for a, _, _ in log.iter_chunks(row_range=rng):
                counts += np.bincount(a, minlength=log.num_activities)
            resume = None
            if rng[1] == log.num_events:
                resume = ResumableState(
                    rows_end=rng[1], num_activities=log.num_activities,
                    counts=counts.copy(),
                )
            value, out_names = self._finish_streaming_hist(counts, names, st)
            return value, out_names, resume
        if isinstance(logical.sink, (ProcessMapSink, NeighborhoodSink)):
            # one scan accumulates Ψ and node counts together
            miner = StreamingDFGMiner(log.num_activities)
            counts = np.zeros(log.num_activities, dtype=np.int64)
            for a, c, t in log.iter_chunks(row_range=rng):
                miner.update(a, c, t)
                counts += np.bincount(a, minlength=log.num_activities)
            value, out_names = self._finish_topology(
                miner.finalize(), counts, names, st, logical.sink
            )
            return value, out_names, None
        raise QueryPlanError(
            f"sink {type(logical.sink).__name__} has no streaming path"
        )


# ---------------------------------------------------------------------------
# Shared default engine
# ---------------------------------------------------------------------------

_DEFAULT: Optional[QueryEngine] = None


def default_engine() -> QueryEngine:
    """Process-wide engine (and cache) used by ``Q`` terminals unless a
    query pins its own via :meth:`Query.using`."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = QueryEngine()
    return _DEFAULT


def set_default_engine(engine: Optional[QueryEngine]) -> None:
    global _DEFAULT
    _DEFAULT = engine
