"""Plan + result cache keyed on (repository fingerprint, canonical plan).

Dashboard-style workloads re-issue the same handful of queries against a
slowly changing store; the paper's in-store architecture makes those O(1)
once the store can recognize "same data, same query".  Both halves of the
key are content hashes:

* the **fingerprint** digests the source's actual bytes (columns + names
  for an :class:`EventRepository`; meta + column files for a
  :class:`MemmapLog`), so *any* append or rewrite invalidates;
* the **plan key** hashes the canonical logical plan, so two differently
  chained but equivalent queries share an entry.

Entries are LRU-evicted and returned as copies — a caller mutating a result
matrix can never corrupt the cache.
"""

from __future__ import annotations

import copy
import dataclasses
import hashlib
import json
import os
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.core.repository import EventRepository
from repro.core.streaming import MemmapLog

__all__ = [
    "fingerprint",
    "fingerprint_repository",
    "fingerprint_memmap",
    "QueryCache",
    "CacheStats",
]


# ---------------------------------------------------------------------------
# Source fingerprints
# ---------------------------------------------------------------------------


#: per-column sample size; columns up to 3× this hash in full
_SAMPLE_ROWS = 1 << 16


def _digest_column(h, col, sample_rows: int = _SAMPLE_ROWS) -> None:
    """Full hash for small columns; head + tail + strided sample for large
    ones, so fingerprinting stays O(sample) and a cache *hit* is cheap even
    on multi-GB repositories.  Appends/truncations always change the shape
    (hashed); an in-place edit of a large column is caught only if it lands
    in the sample — same tradeoff as the memmap fingerprint."""
    arr = np.ascontiguousarray(col)
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    n = arr.shape[0]
    if n <= 3 * sample_rows:
        h.update(arr.tobytes())
        return
    h.update(arr[:sample_rows].tobytes())
    h.update(arr[-sample_rows:].tobytes())
    stride = max(n // sample_rows, 1)
    h.update(np.ascontiguousarray(arr[::stride]).tobytes())


def fingerprint_repository(repo: EventRepository) -> str:
    h = hashlib.sha256()
    for col in (repo.event_activity, repo.event_trace, repo.event_time,
                repo.trace_log):
        _digest_column(h, col)
    h.update(json.dumps(
        [repo.activity_names, len(repo.trace_names), repo.log_names]
    ).encode())
    return "repo:" + h.hexdigest()[:32]


def fingerprint_memmap(log: MemmapLog, sample_rows: int = 4096) -> str:
    """O(sample) digest: meta + column file sizes + head/tail row samples.
    Appending rows changes ``num_events``/file sizes; editing in place is
    caught for the sampled ranges (full-file hashing would defeat the
    out-of-core design)."""
    h = hashlib.sha256()
    h.update(json.dumps([
        log.num_events, log.num_activities, log.num_traces, log.chunk_rows,
    ]).encode())
    for name in ("activity.i32", "case.i32", "time.f64"):
        h.update(str(os.path.getsize(os.path.join(log.path, name))).encode())
    k = min(sample_rows, log.num_events)
    for col in (log.activity, log.case, log.time):
        h.update(np.asarray(col[:k]).tobytes())
        h.update(np.asarray(col[log.num_events - k:]).tobytes())
    return "memmap:" + h.hexdigest()[:32]


def fingerprint(source) -> str:
    if isinstance(source, EventRepository):
        return fingerprint_repository(source)
    if isinstance(source, MemmapLog):
        return fingerprint_memmap(source)
    raise TypeError(f"cannot fingerprint {type(source).__name__}")


# ---------------------------------------------------------------------------
# LRU result cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0


def _copy_result(result):
    """Deep-enough copy: fresh arrays, shared immutable plan objects."""
    out = copy.copy(result)
    value = result.value
    if isinstance(value, np.ndarray):
        out.value = value.copy()
    else:
        out.value = copy.deepcopy(value)
    if result.names is not None:
        out.names = list(result.names)
    return out


class QueryCache:
    """LRU over (fingerprint, plan_key) → QueryResult.  Thread-safe: the
    serving layer shares one cache across concurrent tenants."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: "OrderedDict[Tuple[str, str], object]" = OrderedDict()
        self.stats = CacheStats()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: Tuple[str, str]):
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return _copy_result(entry)

    def put(self, key: Tuple[str, str], result) -> None:
        entry = _copy_result(result)
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.stats.evictions += 1

    def invalidate_source(self, fp: str) -> int:
        """Drop every entry for one source fingerprint (explicit refresh)."""
        with self._lock:
            dead = [k for k in self._entries if k[0] == fp]
            for k in dead:
                del self._entries[k]
            self.stats.invalidations += len(dead)
            return len(dead)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
