"""Plan + result cache keyed on (repository fingerprint, canonical plan).

Dashboard-style workloads re-issue the same handful of queries against a
slowly changing store; the paper's in-store architecture makes those O(1)
once the store can recognize "same data, same query".  Both halves of the
key are content hashes:

* the **fingerprint** digests the source's actual bytes (columns + names
  for an :class:`EventRepository`; prefix digest + shape for a
  :class:`MemmapLog`), so any append or rewrite invalidates;
* the **plan key** hashes the canonical logical plan, so two differently
  chained but equivalent queries share an entry.

The memmap fingerprint is **prefix-preserving**: it is the pair
``(prefix_digest(rows 0..n), n)`` rendered as a string, and
``prefix_digest`` is computable for any ``n`` on any log that still
contains those rows.  That lets the engine *prove* (up to the sampling the
fingerprint already accepts) that a changed log is an append-only extension
of a cached one — the basis of the delta query plans.

Entries may carry a :class:`ResumableState` (the streaming miner's Ψ +
open-case tails, or a histogram's raw counts) so a proven append scans only
the new suffix.  A per-(source path, plan) hint remembers the newest entry
to resume from; the hint is only a lookup accelerator — correctness always
comes from the prefix-digest proof.

Entries are LRU-evicted and returned as copies — a caller mutating a result
matrix can never corrupt the cache.
"""

from __future__ import annotations

import copy
import dataclasses
import hashlib
import json
import os
import threading
from collections import OrderedDict
from typing import Optional, Tuple
from urllib.parse import quote, unquote

import numpy as np

from repro.analysis.lockdep import make_lock
from repro.core.repository import EventRepository
from repro.core.streaming import MemmapLog, MinerState

__all__ = [
    "fingerprint",
    "fingerprint_repository",
    "fingerprint_memmap",
    "fingerprint_union",
    "split_union_fingerprint",
    "fingerprint_sharded",
    "split_sharded_fingerprint",
    "prefix_digest",
    "MemmapFingerprint",
    "parse_memmap_fingerprint",
    "ResumableState",
    "QueryCache",
    "CacheStats",
]


# ---------------------------------------------------------------------------
# Source fingerprints
# ---------------------------------------------------------------------------


#: per-column sample size; columns up to 3× this hash in full
_SAMPLE_ROWS = 1 << 16


def _digest_column(h, col, sample_rows: int = _SAMPLE_ROWS) -> None:
    """Full hash for small columns; head + tail + strided sample for large
    ones, so fingerprinting stays O(sample) and a cache *hit* is cheap even
    on multi-GB repositories.  Appends/truncations always change the shape
    (hashed); an in-place edit of a large column is caught only if it lands
    in the sample — same tradeoff as the memmap fingerprint."""
    arr = np.ascontiguousarray(col)
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    n = arr.shape[0]
    if n <= 3 * sample_rows:
        h.update(arr.tobytes())
        return
    h.update(arr[:sample_rows].tobytes())
    h.update(arr[-sample_rows:].tobytes())
    stride = max(n // sample_rows, 1)
    h.update(np.ascontiguousarray(arr[::stride]).tobytes())


def _digest_names(h, names, sample: int = 1024) -> None:
    """Hash a name list the way columns are hashed: in full when small,
    head + tail + strided sample when large.  Two repositories differing
    only in (sampled) names must not collide."""
    h.update(str(len(names)).encode())
    if len(names) <= 3 * sample:
        picked = names
    else:
        stride = max(len(names) // sample, 1)
        picked = (*names[:sample], *names[-sample:], *names[::stride])
    for name in picked:
        h.update(name.encode())
        h.update(b"\x00")


def fingerprint_repository(repo: EventRepository) -> str:
    h = hashlib.sha256()
    for col in (repo.event_activity, repo.event_trace, repo.event_time,
                repo.trace_log):
        _digest_column(h, col)
    h.update(json.dumps([repo.activity_names, repo.log_names]).encode())
    _digest_names(h, repo.trace_names)
    return "repo:" + h.hexdigest()[:32]


def prefix_digest(
    log: MemmapLog, n: Optional[int] = None, sample_rows: int = 4096
) -> str:
    """O(sample) digest of the first ``n`` rows of the log.

    The sample positions depend only on ``n`` (head, tail-of-prefix, and a
    stride over ``[0, n)``), so the digest is recomputable on any log that
    still contains those rows:  ``prefix_digest(grown_log, old_n) ==
    old_digest`` *proves* — to the same sampling confidence the fingerprint
    already accepts — that the change was append-only."""
    n = log.num_events if n is None else int(n)
    if not 0 <= n <= log.num_events:
        raise ValueError(f"prefix of {n} rows on a {log.num_events}-row log")
    h = hashlib.sha256()
    h.update(str(n).encode())
    k = min(sample_rows, n)
    stride = max(n // sample_rows, 1)
    for col in (log.activity, log.case, log.time):
        h.update(np.asarray(col[:k]).tobytes())
        h.update(np.asarray(col[n - k : n]).tobytes())
        if stride > 1:
            h.update(np.ascontiguousarray(col[:n:stride]).tobytes())
    return h.hexdigest()[:32]


@dataclasses.dataclass(frozen=True)
class MemmapFingerprint:
    """Structured form of a memmap fingerprint string."""

    prefix: str
    num_events: int
    num_activities: int


#: stat-validated fingerprint memo — the sharded tier fingerprints every
#: shard on every query (once in the composite, once per sub-query), and
#: at K=8 that sampling dominated the warm windowed path.  The memo key
#: carries (size, mtime_ns) of each column file, so an append (writer uses
#: append-mode file handles) or an in-place rewrite both recompute; a hit
#: costs three ``stat()`` calls instead of O(sample) hashing.
_FP_MEMO_MAX = 4096
_FP_COLUMNS = ("activity.i32", "case.i32", "time.f64")
_fp_memo: "OrderedDict[tuple, str]" = OrderedDict()
_fp_memo_lock = make_lock("FingerprintMemo")


def realpath_of(source) -> Optional[str]:
    """``os.path.realpath(source.path)``, cached on the source object —
    resolving symlinks costs one ``lstat`` per path component and the
    sharded tier asks per shard per query."""
    path = getattr(source, "path", None)
    if not path:
        return None
    cached = getattr(source, "_realpath_cache", None)
    if cached is not None and cached[0] == path:
        return cached[1]
    real = os.path.realpath(path)
    try:
        source._realpath_cache = (path, real)
    except AttributeError:  # __slots__ sources: resolve every time
        pass
    return real


def _memmap_stat_key(log: MemmapLog, sample_rows: int):
    """Validator key for the fingerprint memo, or None (no backing files →
    always hash)."""
    real = realpath_of(log)
    if real is None:
        return None
    stats = []
    try:
        for name in _FP_COLUMNS:
            st = os.stat(os.path.join(real, name))
            stats.append((st.st_size, st.st_mtime_ns))
    except OSError:
        return None
    return (real, tuple(stats), log.num_events, log.num_activities,
            sample_rows)


def fingerprint_memmap(log: MemmapLog, sample_rows: int = 4096) -> str:
    """Prefix-preserving fingerprint: ``memmap:<prefix_digest>:<rows>:<A>``.
    Appending rows changes the row count (and usually the digest); editing
    in place is caught for the sampled ranges (full-file hashing would
    defeat the out-of-core design)."""
    key = _memmap_stat_key(log, sample_rows)
    if key is not None:
        with _fp_memo_lock:
            hit = _fp_memo.get(key)
            if hit is not None:
                _fp_memo.move_to_end(key)
                return hit
    fp = "memmap:{}:{}:{}".format(
        prefix_digest(log, sample_rows=sample_rows),
        log.num_events,
        log.num_activities,
    )
    if key is not None:
        with _fp_memo_lock:
            _fp_memo[key] = fp
            _fp_memo.move_to_end(key)
            while len(_fp_memo) > _FP_MEMO_MAX:
                _fp_memo.popitem(last=False)
    return fp


def parse_memmap_fingerprint(fp: str) -> Optional[MemmapFingerprint]:
    if not fp.startswith("memmap:"):
        return None
    try:
        _, prefix, n, a = fp.split(":")
        return MemmapFingerprint(prefix, int(n), int(a))
    except ValueError:
        return None


def fingerprint_union(union) -> str:
    """Composite fingerprint ``union(name=fp_a|name=fp_b|...)``.

    Each component is the branch's own fingerprint — memmap branches keep
    their **prefix-preserving** ``memmap:<digest>:<rows>:<A>`` form, so the
    append-only proof (and with it the delta path) still works *per branch*
    while any branch change invalidates every union-level entry.  Branch
    names are part of the key: the same bytes relabeled is a different
    result (compare axes, provenance).  Names are percent-escaped so a
    caller-supplied name containing ``=`` / ``|`` cannot forge another
    union's key."""
    return "union(" + "|".join(
        f"{quote(b.name, safe='')}={fingerprint(b)}" for b in union.branches
    ) + ")"


def split_union_fingerprint(fp: str):
    """``union(a=fp1|b=fp2)`` → ``[("a", fp1), ("b", fp2)]`` (None if not a
    union fingerprint)."""
    if not (fp.startswith("union(") and fp.endswith(")")):
        return None
    out = []
    for part in fp[len("union("):-1].split("|"):
        name, _, bfp = part.partition("=")
        out.append((unquote(name), bfp))
    return out


def fingerprint_sharded(sharded) -> str:
    """Composite fingerprint ``sharded(fp0|fp1|...)``, one slot per residue
    class in shard order (``-`` marks a residue with no shard yet, so the
    slot count pins K).  Each present slot is the shard's own
    **prefix-preserving** ``memmap:<digest>:<rows>:<A>`` fingerprint: an
    append changes only the owning shards' slots, which is exactly what lets
    the engine keep per-shard cache entries (and delta resume) alive for
    every untouched shard while any change still invalidates sharded-level
    entries."""
    return "sharded(" + "|".join(
        "-" if s is None else fingerprint_memmap(s) for s in sharded.shards
    ) + ")"


def split_sharded_fingerprint(fp: str):
    """``sharded(fp0|fp1|...)`` → ``[fp0_or_None, fp1_or_None, ...]`` (None
    for absent shards; returns None if not a sharded fingerprint)."""
    if not (fp.startswith("sharded(") and fp.endswith(")")):
        return None
    return [
        None if part == "-" else part
        for part in fp[len("sharded("):-1].split("|")
    ]


def fingerprint(source) -> str:
    # local imports: ast.py / graph.shard depend on core only, so no cycle
    from repro.graph.shard import ShardedLog

    from .ast import FromLogs, LogRef, UnionSource

    if isinstance(source, EventRepository):
        return fingerprint_repository(source)
    if isinstance(source, MemmapLog):
        return fingerprint_memmap(source)
    if isinstance(source, ShardedLog):
        return fingerprint_sharded(source)
    if isinstance(source, UnionSource):
        return fingerprint_union(source)
    if isinstance(source, LogRef):
        return fingerprint(source.source)
    if isinstance(source, FromLogs):
        # derived from the parent's content + the selection — no need to
        # materialize the O(E) sub-repository just to key the cache
        h = hashlib.sha256("\x00".join(source.names).encode()).hexdigest()[:8]
        return f"fromlogs:{h}:{fingerprint_repository(source.repo)}"
    raise TypeError(f"cannot fingerprint {type(source).__name__}")


# ---------------------------------------------------------------------------
# Resumable execution state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResumableState:
    """State a streaming scan leaves behind when it consumed the log through
    its last row: resuming it over an appended suffix (including the pairs
    that straddle the boundary, via the miner's per-case tails) reproduces a
    full rescan bit for bit.  ``replay`` carries the streaming replayer's
    per-case tails + fitness accumulators for conformance sinks (only
    plans whose model is pinned in the sink are resumable — a default
    model is re-discovered from the grown log and would change under the
    resumed state's feet)."""

    rows_end: int  # rows [lo, rows_end) are accounted for
    num_activities: int
    miner: Optional[MinerState] = None  # DFG sinks
    counts: Optional[np.ndarray] = None  # histogram sinks (raw, pre-mask/view)
    replay: Optional[object] = None  # conformance sinks (ReplayState)

    def copy(self) -> "ResumableState":
        return ResumableState(
            self.rows_end,
            self.num_activities,
            self.miner.copy() if self.miner is not None else None,
            self.counts.copy() if self.counts is not None else None,
            self.replay.copy() if self.replay is not None else None,
        )


# ---------------------------------------------------------------------------
# LRU result cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0


@dataclasses.dataclass
class _Entry:
    result: object
    resume: Optional[ResumableState] = None


def _copy_result(result):
    """Deep-enough copy: fresh arrays, shared immutable plan objects."""
    out = copy.copy(result)
    value = result.value
    if isinstance(value, np.ndarray):
        out.value = value.copy()
    else:
        out.value = copy.deepcopy(value)
    if result.names is not None:
        out.names = list(result.names)
    # Traces describe one concrete execution; a stored entry must not leak
    # the producing run's spans into later hits (the engine attaches a fresh
    # cache-hit trace to each served copy).  The producing run's *trace id*
    # is retained so hit traces (and exemplars) can link back to the
    # execution that populated the entry.
    if getattr(out, "trace", None) is not None:
        if getattr(out, "source_trace_id", None) is None:
            out.source_trace_id = out.trace.trace_id
        out.trace = None
    return out


class QueryCache:
    """LRU over (fingerprint, plan_key) → QueryResult [+ ResumableState].
    Thread-safe: the serving layer shares one cache across concurrent
    tenants."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: "OrderedDict[Tuple[str, str], _Entry]" = OrderedDict()
        # (source hint, plan_key) -> fingerprint of the newest entry for it;
        # lets the engine find a resume candidate after the source changed
        self._hints: dict = {}
        self.stats = CacheStats()
        self._lock = make_lock("QueryCache")

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: Tuple[str, str]):
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return _copy_result(entry.result)

    def probe(self, key: Tuple[str, str]) -> bool:
        """Non-mutating presence check: no stats, no LRU touch, no copy.
        The serving tier's SLO classifier probes before enqueueing and must
        not skew hit/miss accounting or recency."""
        with self._lock:
            return key in self._entries

    def has_delta_hint(self, source_hint: Optional[str], plan_key: str) -> bool:
        """Non-mutating: is there a live resume candidate for this
        (source, plan)?  Like :meth:`probe`, a classification signal only —
        the engine still proves prefix preservation before trusting it."""
        if source_hint is None:
            return False
        with self._lock:
            fp = self._hints.get((source_hint, plan_key))
            return fp is not None and (fp, plan_key) in self._entries

    def put(
        self,
        key: Tuple[str, str],
        result,
        resume: Optional[ResumableState] = None,
        source_hint: Optional[str] = None,
    ) -> None:
        entry = _Entry(
            _copy_result(result),
            resume.copy() if resume is not None else None,
        )
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            if source_hint is not None:
                self._hints[(source_hint, key[1])] = key[0]
            while len(self._entries) > self.max_entries:
                dead_key, _ = self._entries.popitem(last=False)
                self.stats.evictions += 1
                self._drop_hints_locked(dead_key)

    # -- delta support -------------------------------------------------------
    def delta_candidate(self, source_hint: Optional[str], plan_key: str):
        """Newest (fingerprint, result copy, resume copy) put for this
        (source, plan) — a *candidate* only: the engine must prove prefix
        preservation before trusting it."""
        if source_hint is None:
            return None
        with self._lock:
            fp = self._hints.get((source_hint, plan_key))
            if fp is None:
                return None
            entry = self._entries.get((fp, plan_key))
            if entry is None:  # evicted since
                self._hints.pop((source_hint, plan_key), None)
                return None
            return (
                fp,
                _copy_result(entry.result),
                entry.resume.copy() if entry.resume is not None else None,
            )

    def drop_hint(self, source_hint: Optional[str], plan_key: str) -> None:
        with self._lock:
            self._hints.pop((source_hint, plan_key), None)

    def _drop_hints_locked(self, key: Tuple[str, str]) -> None:
        fp, plan_key = key
        dead = [
            hk for hk, hfp in self._hints.items()
            if hfp == fp and hk[1] == plan_key
        ]
        for hk in dead:
            del self._hints[hk]

    # -- maintenance ---------------------------------------------------------
    def invalidate_source(self, fp: str) -> int:
        """Drop every entry for one source fingerprint (explicit refresh)."""
        with self._lock:
            dead = [k for k in self._entries if k[0] == fp]
            for k in dead:
                del self._entries[k]
                self._drop_hints_locked(k)
            self.stats.invalidations += len(dead)
            return len(dead)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._hints.clear()
