"""Physical planning — pick the execution backend and the pushdowns.

The cost model is deliberately small (the paper's point is that the *store*
picks the strategy, not the analyst):

========================  =====================================================
situation                 physical operator
========================  =====================================================
memmap log > budget       ``streaming`` scan (O(A²+chunk) memory), window
                          pushed to a **row range** via the chunk time index
memmap log ≤ budget       materialize once, then the device path below
tiny input (≤ tiny_pairs) ``numpy`` scatter-add — beats device dispatch
                          overhead by orders of magnitude at this size
mesh available            ``distributed`` shard_map + psum over every axis
CPU default backend       ``scatter`` (jnp .at[].add)
TPU/GPU                   ``pallas`` MXU kernel; a time window fuses into the
                          kernel's WHERE clause (``dfg_count_diced``)
graph built / amortized   ``graph`` — un-windowed topology sinks (DFG,
                          process map, neighborhood) become CSR lookups in
                          the event-knowledge graph store (repro.graph)
========================  =====================================================

Pushdown decisions recorded on the :class:`PhysicalPlan`:

* ``row_range`` — the memmap chunk-time-index dice (paper Experiment 2);
* ``fused_dicing`` — window evaluated inside the Pallas kernel (f32
  timestamps; requires f32-exact times for bit-identity, which the engine's
  ``fused_dicing`` flag gates);
* ``view_pushdown`` — when the projection shrinks the activity set, relabel
  pair columns to group ids *before* counting so the matmul/count runs at
  G×G instead of A×A;
* ``activities_as_output_mask`` — a paper-semantics activity filter commutes
  past counting: Ψ restricted to keep×keep equals counting masked pairs, so
  the filter becomes a free O(A²) mask on the result instead of an O(E)
  pair predicate.

One physical operator is chosen by the *engine*, not here: ``delta``.  When
a memmap source is proven to be an append-only extension of a cached scan
(prefix-preserving fingerprint), the engine resumes the cached streaming
state over just the appended suffix — ``delta_rows`` records the suffix row
range it scanned.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.repository import EventRepository
from repro.core.streaming import MemmapLog
from repro.graph.shard import ShardedLog

from .ast import (
    CONFORMANCE_SINKS,
    TOPOLOGY_SINKS,
    Activities,
    AlignmentsSink,
    ApplyView,
    CompareSink,
    DFGSink,
    FitnessSink,
    HistogramSink,
    LogicalPlan,
    NeighborhoodSink,
    ProcessMapSink,
    QueryPlanError,
    UnionSource,
    VariantsSink,
    Window,
    is_barrier,
    source_kind,
    union_activity_names,
)

__all__ = [
    "SourceInfo",
    "PhysicalPlan",
    "CrossoverCurve",
    "source_info",
    "plan_physical",
    "load_calibration",
    "resolve_threshold",
    "estimate_cost_s",
    "SLO_HOT_CUTOFF_S",
]

_LOG = logging.getLogger(__name__)

#: below this many pairs, numpy beats any device dispatch
TINY_PAIRS = 2048
#: above this many events a memmap log is mined out-of-core
MEMORY_BUDGET_EVENTS = 1 << 22
#: repeated topology queries on one source after which building the
#: event-knowledge graph (repro.graph) amortizes — measured crossover
#: comes from BENCH_graph.json when available
GRAPH_REPEAT_CROSSOVER = 3
#: memmap events above which the one-pass streaming replayer beats
#: materialize-then-replay for conformance sinks; the static default ties
#: it to the memory budget (identical behavior to the budget gate), the
#: measured value comes from BENCH_conformance.json
REPLAY_STREAMING_CROSSOVER = MEMORY_BUDGET_EVENTS
#: sharded-log events above which the sharded-graph backend (per-shard CSR
#: snapshots + aligned psum merge) beats concatenate-and-materialize on a
#: single host; the measured value comes from BENCH_shard.json
SHARDED_SINGLE_CROSSOVER = 1 << 18
#: predicted execution cost (seconds) below which the serving tier
#: (repro.transport) classifies a request *hot* — it rides the warm lane
#: with cache/delta/graph serves instead of queueing behind cold scans.
#: The static default sits between a cache hit (~100µs) and a cold
#: streaming scan (~300ms); the measured boundary comes from
#: BENCH_serve.json (geometric mean of the measured warm-lane p99 and
#: cold-lane median service times)
SLO_HOT_CUTOFF_S = 0.05

# Order-of-magnitude cost priors for the observability drift check: fixed
# per-backend dispatch overhead plus an events-per-second throughput.
# These exist so a recorded trace (repro.obs.QueryTrace) can be contrasted
# with the planner's choice — they never influence planning itself, which
# uses the measured calibration crossovers above.
_COST_DISPATCH_S = {
    "numpy": 5e-5,
    "scatter": 3e-4,      # jit-cache lookup
    "onehot": 3e-4,
    "pallas": 3e-4,       # jit-cache lookup + host↔device transfers
    "distributed": 1e-3,  # mesh collective setup
    "graph": 5e-5,        # CSR lookup / densify
    "sharded-graph": 5e-4,  # K store lookups + K·A² aligned merge
}
# Conservative CPU-measured throughputs (events/s), cold-path inclusive:
# a cold scan on a memmap source pays materialization + masking on top of
# the kernel itself, and the drift band (±16x by default) absorbs warm-path
# speedups and accelerator headroom.  These priors never influence planning
# — they only give traces a prediction to contrast with the measured span.
_COST_RATE_EVENTS_S = {
    "numpy": 2e7,
    "scatter": 2e6,
    "onehot": 1e6,
    "pallas": 5e6,
    "distributed": 1e7,
    "streaming": 5e6,
    "delta": 5e6,
    "graph": 4e8,
    "concat": 5e6,
    "sharded-graph": 2e8,  # per-shard CSR serves, minus the merge constant
}


def estimate_cost_s(backend: str, num_events: int) -> float:
    """Prior execution cost (seconds) of ``backend`` over ``num_events``
    events — the prediction a trace records so ``explain(after=...)`` and
    the ``planner_drift_total`` counter can contrast it with the measured
    span."""
    rate = _COST_RATE_EVENTS_S.get(backend, 5e6)
    return _COST_DISPATCH_S.get(backend, 1e-4) + num_events / rate


# ---------------------------------------------------------------------------
# Measured calibration (ROADMAP "smarter cost model")
# ---------------------------------------------------------------------------

#: sanity rails: a stray or corrupt bench record must not be able to flip
#: plans far outside the regime the bench actually measured
_CALIBRATION_CLAMPS = {
    "tiny_pairs": (256, 4096),
    "memory_budget_events": (1 << 20, 1 << 26),
}
_GRAPH_CLAMPS = {
    "graph_repeat_crossover": (1, 64),
}
_CONFORMANCE_CLAMPS = {
    "replay_streaming_crossover": (1 << 18, 1 << 26),
}
_SHARD_CLAMPS = {
    "sharded_single_crossover": (1 << 14, 1 << 24),
}
#: float clamp bounds mark float-valued calibration keys (the serve
#: boundary is seconds, not a count)
_SERVE_CLAMPS = {
    "slo_hot_cutoff_s": (1e-4, 2.0),
}
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "..")
)

#: calibration basenames already warned about this process — a planner that
#: runs on static fallbacks should say so exactly once, not on every query
_warned_missing: set = set()


@dataclasses.dataclass(frozen=True)
class CrossoverCurve:
    """A crossover threshold as a *function of problem size* instead of one
    scalar.  Benches emit ``calibration.curves.<key>`` as measured
    ``[work, threshold]`` points where ``work = events × activities``; the
    curve interpolates them piecewise-linearly (clamped to the endpoint
    thresholds outside the measured range), so the same mechanism serves
    every backend crossover — tiny_pairs, replay, graph repeats, and the
    sharded-vs-single-host decision."""

    key: str
    xs: Tuple[float, ...]  # sorted work coordinates (events × activities)
    ys: Tuple[float, ...]  # measured thresholds at those sizes

    def value_at(self, work: float) -> int:
        return int(round(float(np.interp(float(work), self.xs, self.ys))))


def _parse_curves(
    cal: dict, clamps: Dict[str, Tuple[int, int]], out: Dict
) -> None:
    """Fit clamp-railed :class:`CrossoverCurve` objects from a record's
    ``curves`` section.  Only keys this record is allowed to calibrate (its
    scalar clamp keys) are accepted, and every threshold passes the same
    sanity rails as the scalar — one corrupt record still cannot flip plans
    outside the measured regime."""
    curves = cal.get("curves")
    if not isinstance(curves, dict):
        return
    for key, (lo, hi) in clamps.items():
        pts = curves.get(key)
        if not isinstance(pts, list) or not pts:
            continue
        try:
            parsed = sorted(
                (float(x), float(min(max(float(y), lo), hi)))
                for x, y in pts
                if float(x) >= 0 and float(y) > 0
            )
        except (TypeError, ValueError):
            continue
        if parsed:
            out.setdefault("curves", {})[key] = CrossoverCurve(
                key=key,
                xs=tuple(x for x, _ in parsed),
                ys=tuple(y for _, y in parsed),
            )


def _read_calibration(
    explicit: Optional[str],
    basename: str,
    clamps: Dict[str, Tuple[int, int]],
    out: Dict,
) -> None:
    """Merge one bench record's ``calibration`` section into ``out``,
    clamped.  An explicitly named record is authoritative: if it is missing
    or corrupt we fall back to the *static constants*, never to whatever
    record happens to sit in the cwd / repo root."""
    candidates = [explicit] if explicit else [
        basename,
        os.path.join(_REPO_ROOT, basename),
    ]
    for cand in candidates:
        if not cand or not os.path.isfile(cand):
            continue
        try:
            with open(cand) as f:
                data = json.load(f)
        except (OSError, ValueError):
            continue  # unreadable / corrupt: static fallback
        cal = data.get("calibration")
        if not isinstance(cal, dict):
            continue
        for key, (lo, hi) in clamps.items():
            v = cal.get(key)
            if isinstance(v, (int, float)) and not isinstance(v, bool) and v > 0:
                # float clamp bounds mark float-valued keys (e.g. the
                # serve-tier SLO boundary in seconds); everything else
                # stays an integer threshold
                if isinstance(lo, float) or isinstance(hi, float):
                    out[key] = float(min(max(float(v), lo), hi))
                else:
                    out[key] = int(min(max(int(v), lo), hi))
        _parse_curves(cal, clamps, out)
        return
    if basename not in _warned_missing:
        _warned_missing.add(basename)
        _LOG.warning(
            "calibration record %s not found (searched explicit path, cwd, "
            "repo root); the planner falls back to static thresholds for %s",
            basename, sorted(clamps),
        )


def load_calibration(
    path: Optional[str] = None,
    graph_path: Optional[str] = None,
    conformance_path: Optional[str] = None,
    shard_path: Optional[str] = None,
    serve_path: Optional[str] = None,
) -> Dict:
    """Cost-model thresholds, measured when available.

    ``benchmarks/bench_query_engine.py`` writes a ``calibration`` section
    (backend-crossover ``tiny_pairs``, machine-sized
    ``memory_budget_events``) into ``BENCH_query.json``, and
    ``benchmarks/bench_graph.py`` writes the columnar↔graph crossover
    (``graph_repeat_crossover`` — the repeat-query count above which
    building the event-knowledge graph amortizes) into
    ``BENCH_graph.json``, and ``benchmarks/bench_conformance.py`` the
    streaming↔materialize replay crossover
    (``replay_streaming_crossover`` events) into
    ``BENCH_conformance.json``, and ``benchmarks/bench_shard.py`` the
    sharded-vs-single-host crossover (``sharded_single_crossover`` events)
    into ``BENCH_shard.json``, and ``benchmarks/bench_serve.py`` the
    serving tier's measured hot/cold lane boundary (``slo_hot_cutoff_s``
    seconds — the crossover between warm-lane serves and cold scans that
    the transport SLO classifier splits traffic on) into
    ``BENCH_serve.json``.  When such records exist — searched as:
    explicit path argument, ``$GRAPHPM_BENCH_QUERY`` /
    ``$GRAPHPM_BENCH_GRAPH`` / ``$GRAPHPM_BENCH_CONFORMANCE`` /
    ``$GRAPHPM_BENCH_SHARD`` / ``$GRAPHPM_BENCH_SERVE``, ``./BENCH_*.json``, ``<repo
    root>/BENCH_*.json`` — their values replace the static constants,
    clamped to sanity rails, and any ``curves`` section becomes a
    :class:`CrossoverCurve` under ``out["curves"]`` (threshold as a function
    of events × activities — used in preference to the scalar when
    present).  The constants are always the fallback, so a machine that
    never benchmarked plans exactly as before; a missing record logs a
    one-time warning so silent static-fallback runs are visible.
    """
    out: Dict = {
        "tiny_pairs": TINY_PAIRS,
        "memory_budget_events": MEMORY_BUDGET_EVENTS,
        "graph_repeat_crossover": GRAPH_REPEAT_CROSSOVER,
        "replay_streaming_crossover": REPLAY_STREAMING_CROSSOVER,
        "sharded_single_crossover": SHARDED_SINGLE_CROSSOVER,
        "slo_hot_cutoff_s": SLO_HOT_CUTOFF_S,
        "curves": {},
    }
    _read_calibration(
        path or os.environ.get("GRAPHPM_BENCH_QUERY"),
        "BENCH_query.json", _CALIBRATION_CLAMPS, out,
    )
    _read_calibration(
        graph_path or os.environ.get("GRAPHPM_BENCH_GRAPH"),
        "BENCH_graph.json", _GRAPH_CLAMPS, out,
    )
    _read_calibration(
        conformance_path or os.environ.get("GRAPHPM_BENCH_CONFORMANCE"),
        "BENCH_conformance.json", _CONFORMANCE_CLAMPS, out,
    )
    _read_calibration(
        shard_path or os.environ.get("GRAPHPM_BENCH_SHARD"),
        "BENCH_shard.json", _SHARD_CLAMPS, out,
    )
    _read_calibration(
        serve_path or os.environ.get("GRAPHPM_BENCH_SERVE"),
        "BENCH_serve.json", _SERVE_CLAMPS, out,
    )
    return out


def resolve_threshold(calibration: Dict, key: str, work: float) -> int:
    """The effective crossover for ``key`` at problem size ``work``
    (events × activities): the fitted curve when the calibration carries
    one, else the (possibly bench-measured) scalar."""
    curve = calibration.get("curves", {}).get(key)
    if curve is not None:
        return curve.value_at(work)
    return int(calibration[key])

_DFG_BACKENDS = {
    "auto", "numpy", "scatter", "onehot", "pallas", "streaming", "distributed",
    "graph", "sharded-graph",
}
#: conformance sinks replay/align sequences — device counting backends do
#: not apply; "numpy" is the columnar replay, "streaming" the one-pass
#: replayer, "graph" the stored-event-table walk
_CONFORMANCE_BACKENDS = {"auto", "numpy", "streaming", "graph"}


@dataclasses.dataclass(frozen=True)
class SourceInfo:
    kind: str  # "repository" | "memmap" | "sharded" | "union(...)"
    num_events: int
    num_pairs: int
    num_activities: int
    activity_names: Optional[Tuple[str, ...]]
    # union sources only: per-branch shapes (costed individually — one
    # union may mix an out-of-core memmap branch with in-memory ones)
    branches: Optional[Tuple["SourceInfo", ...]] = None
    branch_names: Optional[Tuple[str, ...]] = None
    # sharded sources only: per-shard shapes (each shard is costed as an
    # independent memmap — windowed serves need every shard in budget)
    shards: Optional[Tuple["SourceInfo", ...]] = None
    shard_names: Optional[Tuple[str, ...]] = None


def source_info(source) -> SourceInfo:
    if isinstance(source, EventRepository):
        return SourceInfo(
            kind="repository",
            num_events=source.num_events,
            num_pairs=max(source.num_events - 1, 0),
            num_activities=source.num_activities,
            activity_names=tuple(source.activity_names),
        )
    if isinstance(source, MemmapLog):
        return SourceInfo(
            kind="memmap",
            num_events=source.num_events,
            num_pairs=max(source.num_events - 1, 0),
            num_activities=source.num_activities,
            activity_names=None,
        )
    if isinstance(source, ShardedLog):
        present = source.present_shards()
        infos = tuple(source_info(s) for _, s in present)
        return SourceInfo(
            kind="sharded",
            num_events=sum(i.num_events for i in infos),
            num_pairs=sum(i.num_pairs for i in infos),
            num_activities=source.num_activities,
            activity_names=tuple(source.activity_labels()),
            shards=infos,
            shard_names=tuple(f"shard{k}" for k, _ in present),
        )
    if isinstance(source, UnionSource):
        infos = tuple(source_info(b.resolve()) for b in source.branches)
        names = tuple(union_activity_names(source))
        return SourceInfo(
            kind=source_kind(source),
            num_events=sum(i.num_events for i in infos),
            num_pairs=sum(i.num_pairs for i in infos),
            num_activities=len(names),
            activity_names=names,
            branches=infos,
            branch_names=source.branch_names,
        )
    raise QueryPlanError(f"unsupported source {type(source).__name__}")


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    # numpy | scatter | onehot | pallas | streaming | distributed | delta
    #   | union | compare | concat | graph
    # ("delta" is engine-chosen only: it resumes cached streaming state over
    # a proven append-only suffix and is never requestable by the analyst;
    # "union"/"compare" merge per-branch sub-plans — the notes record each
    # branch's own backend — "concat" materializes the concatenated
    # repository for ops that do not distribute, and "graph" answers
    # topology sinks from the CSR event-knowledge graph store)
    backend: str
    materialize: bool = False  # memmap source loaded into memory first
    row_range_window: Optional[Tuple[float, float]] = None
    fused_dicing: bool = False
    view_pushdown: bool = False
    activities_as_output_mask: bool = False
    delta_rows: Optional[Tuple[int, int]] = None  # suffix row range scanned
    notes: Tuple[str, ...] = ()

    def describe(self) -> str:
        parts = [f"backend={self.backend}"]
        if self.materialize:
            parts.append("materialize=memmap→memory")
        if self.row_range_window is not None:
            parts.append("pushdown=row_range(chunk time index)")
        if self.fused_dicing:
            parts.append("pushdown=fused_pallas_dicing")
        if self.view_pushdown:
            parts.append("pushdown=view_below_count")
        if self.activities_as_output_mask:
            parts.append("rewrite=activity_filter→output_mask")
        if self.delta_rows is not None:
            parts.append(f"delta=scan_rows[{self.delta_rows[0]}:{self.delta_rows[1]})")
        parts.extend(self.notes)
        return ", ".join(parts)


def _segment_features(plan: LogicalPlan):
    """Ops of the final (post-barrier) segment + whether barriers exist."""
    has_barrier = any(is_barrier(op) for op in plan.ops)
    tail = []
    for op in plan.ops:
        if is_barrier(op):
            tail = []
        else:
            tail.append(op)
    window = next((o for o in tail if isinstance(o, Window)), None)
    acts = next(
        (o for o in tail if isinstance(o, Activities) and not o.relink), None
    )
    view = next((o for o in tail if isinstance(o, ApplyView)), None)
    return has_barrier, window, acts, view


def _device_backend(
    num_pairs: int, *, mesh, tiny_pairs: int, requested: str
) -> str:
    if requested != "auto":
        return requested
    if mesh is not None and num_pairs > tiny_pairs:
        return "distributed"
    if num_pairs <= tiny_pairs:
        return "numpy"
    if jax.default_backend() == "cpu":
        return "scatter"
    return "pallas"


def _plan_conformance(
    plan: LogicalPlan,
    info: SourceInfo,
    *,
    memory_budget_events: int,
    replay_crossover: int,
    graph_available: bool,
) -> PhysicalPlan:
    """Physical plan for fitness/alignments on a single source.

    Replay (fitness) has all three evaluation paths; alignments need the
    variant table, so out-of-core sources are budget-gated like
    :class:`VariantsSink`.  The streaming↔materialize crossover for replay
    is measured (``replay_streaming_crossover``), the budget is the hard
    rail.
    """
    requested = plan.sink.backend
    if requested not in _CONFORMANCE_BACKENDS:
        raise QueryPlanError(
            f"backend {requested!r} is not a conformance backend; pick one "
            f"of {sorted(_CONFORMANCE_BACKENDS)}"
        )
    has_barrier, window, _acts, _view = _segment_features(plan)
    notes = []
    if window is not None and window.empty:
        notes.append("empty_window=zeros")
    is_align = isinstance(plan.sink, AlignmentsSink)

    if requested == "graph" or (
        requested == "auto" and graph_available and not has_barrier
    ):
        if has_barrier:
            raise QueryPlanError(
                "graph backend cannot evaluate materializing ops "
                "(top_variants / relink); drop them or use another backend"
            )
        if info.kind == "memmap" and info.num_events > memory_budget_events:
            raise QueryPlanError(
                "graph conformance replays the stored event tables; this "
                "out-of-core log builds a topology-only graph — use "
                "streaming/auto"
            )
        return PhysicalPlan(
            backend="graph",
            notes=("graph=event_table_replay",) + tuple(notes),
        )

    if info.kind == "memmap":
        if requested == "streaming" and (has_barrier or is_align):
            raise QueryPlanError(
                "streaming replay cannot evaluate "
                + ("materializing ops" if has_barrier else "alignments")
                + "; they need a materialized repository"
            )
        if has_barrier or is_align:
            if info.num_events > memory_budget_events:
                raise QueryPlanError(
                    "alignments / materializing ops on an out-of-core log "
                    "exceed the memory budget; raise memory_budget_events "
                    "or pre-dice the log"
                )
            return PhysicalPlan(
                backend="numpy", materialize=True, notes=tuple(notes)
            )
        out_of_core = info.num_events > memory_budget_events
        if out_of_core and requested == "numpy":
            raise QueryPlanError(
                "backend 'numpy' would materialize an out-of-core log into "
                "memory; use streaming/auto or raise memory_budget_events"
            )
        if requested == "streaming" or (
            requested == "auto"
            and info.num_events > min(memory_budget_events, replay_crossover)
        ):
            return PhysicalPlan(
                backend="streaming",
                row_range_window=(
                    (window.t0, window.t1)
                    if window is not None and not window.empty
                    else None
                ),
                notes=("replay=O(A²+chunk) scan",) + tuple(notes),
            )
        return PhysicalPlan(
            backend="numpy", materialize=True, notes=tuple(notes)
        )
    if requested == "streaming":
        raise QueryPlanError("streaming backend requires a MemmapLog source")
    return PhysicalPlan(backend="numpy", notes=tuple(notes))


def _plan_union(
    plan: LogicalPlan,
    info: SourceInfo,
    *,
    mesh,
    tiny_pairs: int,
    memory_budget_events: int,
    fused_dicing: bool,
    replay_crossover: int = REPLAY_STREAMING_CROSSOVER,
) -> PhysicalPlan:
    """Union costing: every branch is costed on its own shape (one union may
    mix an out-of-core memmap with tiny in-memory repositories), and the
    chosen per-branch backends are recorded in the notes."""
    has_barrier, window, acts, _view = _segment_features(plan)
    notes = []
    if window is not None and window.empty:
        notes.append("empty_window=zeros")

    if isinstance(plan.sink, CompareSink):
        if len(info.branches) < 2:
            raise QueryPlanError(
                "compare() needs at least two logs; got "
                f"{len(info.branches)}"
            )
        if has_barrier:
            raise QueryPlanError(
                "materializing ops (top_variants / relink) are not "
                "supported under compare(): they do not distribute over "
                "the union"
            )
        backend = "compare"
    elif has_barrier or isinstance(plan.sink, VariantsSink):
        # non-distributive: materialize the canonical concatenation
        if info.num_events > memory_budget_events:
            raise QueryPlanError(
                "variants / materializing ops on a union concatenate the "
                "branches in memory; the union exceeds the memory budget"
            )
        return PhysicalPlan(
            backend="concat",
            materialize=True,
            notes=("union=materialize_concatenation",) + tuple(notes),
        )
    else:
        backend = "union"

    # per-branch sub-plans: the window distributes into each branch, the
    # rest (activity mask / view) runs once at the merge.  Conformance
    # sinks distribute *every* op (sequence predicates transform each
    # branch's traces — traces never span branches) and keep their own
    # sink so each branch is costed as a replay, not a count.
    if isinstance(plan.sink, CONFORMANCE_SINKS):
        branch_ops = plan.ops
        branch_sink = plan.sink
    else:
        branch_ops = (window,) if window is not None else ()
        branch_sink = (
            HistogramSink()
            if isinstance(plan.sink, HistogramSink)
            else DFGSink(backend=plan.sink.backend)
        )
    for name, binfo in zip(info.branch_names, info.branches):
        bplan = LogicalPlan(binfo.kind, branch_ops, branch_sink)
        bphys = plan_physical(
            bplan, binfo,
            mesh=mesh, tiny_pairs=tiny_pairs,
            memory_budget_events=memory_budget_events,
            fused_dicing=fused_dicing,
            replay_crossover=replay_crossover,
        )
        notes.append(f"branch[{name}]={bphys.backend}")
    return PhysicalPlan(
        backend=backend,
        row_range_window=(
            (window.t0, window.t1)
            if window is not None and not window.empty
            else None
        ),
        activities_as_output_mask=acts is not None,
        notes=tuple(notes),
    )


def _plan_sharded(
    plan: LogicalPlan,
    info: SourceInfo,
    *,
    mesh,
    tiny_pairs: int,
    memory_budget_events: int,
    fused_dicing: bool,
    graph_available: bool,
    sharded_crossover: int,
) -> PhysicalPlan:
    """Physical plan for a case-partitioned :class:`ShardedLog`.

    The ``sharded-graph`` backend serves topology/histogram sinks from K
    per-shard CSR snapshots merged by an aligned pure sum (cases never span
    shards).  Below the measured sharded-vs-single-host crossover — and when
    the shard graphs are not already warm — a one-host
    concatenate-and-materialize count wins (the K-way merge constant
    dominates tiny logs), so ``auto`` falls back to it; the crossover joins
    the same calibration-curve mechanism as every other threshold.
    """
    has_barrier, window, acts, view = _segment_features(plan)
    windowed = window is not None and not window.empty
    notes = []
    if window is not None and window.empty:
        notes.append("empty_window=zeros")

    if isinstance(plan.sink, CompareSink):
        raise QueryPlanError(
            "compare() requires a multi-log source — build one with "
            "Q.logs(a, b, ...)"
        )
    if isinstance(plan.sink, CONFORMANCE_SINKS):
        raise QueryPlanError(
            "conformance sinks are not implemented for sharded logs; "
            "query one shard directly or materialize a dicing first"
        )
    requested = getattr(plan.sink, "backend", "auto")
    if requested not in ("auto", "sharded-graph"):
        raise QueryPlanError(
            f"backend {requested!r} is not available on a sharded log; "
            "use 'sharded-graph' or 'auto'"
        )

    if has_barrier or isinstance(plan.sink, VariantsSink):
        if requested == "sharded-graph":
            raise QueryPlanError(
                "sharded-graph cannot evaluate variants / materializing ops "
                "(top_variants / relink): they need the global trace table; "
                "drop them or use auto"
            )
        if info.num_events > memory_budget_events:
            raise QueryPlanError(
                "variants / materializing ops on a sharded log concatenate "
                "the shards in memory; the log exceeds the memory budget"
            )
        return PhysicalPlan(
            backend="numpy",
            materialize=True,
            notes=("sharded=materialize_concatenation",) + tuple(notes),
        )

    single_host = (
        requested == "auto"
        and not graph_available
        and info.num_events <= min(sharded_crossover, memory_budget_events)
    )
    if single_host:
        notes.append(
            f"sharded=single_host_below_crossover"
            f"({info.num_events}≤{sharded_crossover})"
        )
        if isinstance(plan.sink, HistogramSink):
            return PhysicalPlan(
                backend="numpy", materialize=True, notes=tuple(notes)
            )
        backend = _device_backend(
            info.num_pairs, mesh=mesh, tiny_pairs=tiny_pairs,
            requested="auto",
        )
        view_pushdown = False
        if view is not None and info.activity_names is not None:
            labels = view.to_view().visible_labels(info.activity_names)
            if len(labels) < info.num_activities:
                view_pushdown = True
                notes.append(
                    f"count_space=G×G ({len(labels)}<{info.num_activities})"
                )
        return PhysicalPlan(
            backend=backend,
            materialize=True,
            fused_dicing=fused_dicing and backend == "pallas" and windowed,
            view_pushdown=view_pushdown,
            activities_as_output_mask=acts is not None and not view_pushdown,
            notes=tuple(notes),
        )

    if windowed:
        for name, sinfo in zip(info.shard_names, info.shards):
            if sinfo.num_events > memory_budget_events:
                raise QueryPlanError(
                    "windowed sharded-graph queries serve from per-shard "
                    f"event tables; {name} exceeds the memory budget — "
                    "repartition into more shards"
                )
    notes.append(
        "sharded=tables_window_merge" if windowed
        else "sharded=csr_psum_merge"
    )
    # per-shard cost estimates: each shard is an independent graph serve
    for name, sinfo in zip(info.shard_names, info.shards):
        notes.append(
            f"shard[{name}]=graph "
            f"cost≈{estimate_cost_s('graph', sinfo.num_events):.1e}s"
        )
    return PhysicalPlan(
        backend="sharded-graph",
        row_range_window=(window.t0, window.t1) if windowed else None,
        activities_as_output_mask=acts is not None,
        notes=tuple(notes),
    )


def plan_physical(
    plan: LogicalPlan,
    info: SourceInfo,
    *,
    mesh=None,
    tiny_pairs: int = TINY_PAIRS,
    memory_budget_events: int = MEMORY_BUDGET_EVENTS,
    fused_dicing: bool = True,
    graph_available: bool = False,
    replay_crossover: int = REPLAY_STREAMING_CROSSOVER,
    sharded_crossover: int = SHARDED_SINGLE_CROSSOVER,
    curves: Optional[Dict[str, CrossoverCurve]] = None,
) -> PhysicalPlan:
    """Map a canonical logical plan to a physical one.  ``plan`` must be the
    output of :func:`repro.query.optimize.canonicalize`.

    ``graph_available`` is the engine's amortization signal: True when the
    event-knowledge graph of this source is already built (or provably
    extendable / past the repeat-query crossover, so building it now pays).
    With it, un-windowed topology sinks route to the ``graph`` backend —
    CSR lookups instead of an O(E) recount — and conformance sinks replay
    the graph's stored event tables.

    ``curves`` (from ``load_calibration()["curves"]``) upgrades the scalar
    crossovers to fitted per-backend curves evaluated at this source's
    problem size (events × activities).
    """
    if curves:
        work = float(info.num_events) * float(max(info.num_activities, 1))
        for key, cur in (
            ("tiny_pairs", "tiny_pairs"),
            ("replay_streaming_crossover", "replay"),
            ("sharded_single_crossover", "sharded"),
        ):
            curve = curves.get(key)
            if curve is None:
                continue
            v = curve.value_at(work)
            if cur == "tiny_pairs":
                tiny_pairs = v
            elif cur == "replay":
                replay_crossover = v
            else:
                sharded_crossover = v
    if isinstance(plan.sink, (DFGSink, CompareSink, ProcessMapSink,
                              NeighborhoodSink, HistogramSink)):
        if plan.sink.backend not in _DFG_BACKENDS:
            raise QueryPlanError(f"unknown DFG backend {plan.sink.backend!r}")
        if (
            plan.sink.backend == "sharded-graph"
            and info.shards is None
        ):
            raise QueryPlanError(
                "backend 'sharded-graph' requires a ShardedLog source — "
                "partition one with repro.graph.partition_memmap_log"
            )
    if info.branches is not None:
        return _plan_union(
            plan, info,
            mesh=mesh, tiny_pairs=tiny_pairs,
            memory_budget_events=memory_budget_events,
            fused_dicing=fused_dicing,
            replay_crossover=replay_crossover,
        )
    if info.shards is not None:
        return _plan_sharded(
            plan, info,
            mesh=mesh, tiny_pairs=tiny_pairs,
            memory_budget_events=memory_budget_events,
            fused_dicing=fused_dicing,
            graph_available=graph_available,
            sharded_crossover=sharded_crossover,
        )
    if isinstance(plan.sink, CompareSink):
        raise QueryPlanError(
            "compare() requires a multi-log source — build one with "
            "Q.logs(a, b, ...)"
        )
    if isinstance(plan.sink, CONFORMANCE_SINKS):
        return _plan_conformance(
            plan, info,
            memory_budget_events=memory_budget_events,
            replay_crossover=replay_crossover,
            graph_available=graph_available,
        )
    has_barrier, window, acts, view = _segment_features(plan)
    notes = []
    if window is not None and window.empty:
        # the engine short-circuits to zeros before touching the backend
        notes.append("empty_window=zeros")

    if isinstance(plan.sink, (HistogramSink, VariantsSink)):
        needs_repo = isinstance(plan.sink, VariantsSink) or has_barrier
        requested = getattr(plan.sink, "backend", "auto")
        if info.kind == "memmap":
            # graph histograms: the stored :OF_TYPE in-degrees answer the
            # un-windowed counts as a lookup; a window reads the graph's
            # time index (event tables required, so out-of-core logs whose
            # graphs are topology-only can't serve windowed counts)
            windowed = window is not None and not window.empty
            graph_ok = not needs_repo and not (
                windowed and info.num_events > memory_budget_events
            )
            if requested == "graph":
                if not graph_ok:
                    raise QueryPlanError(
                        "graph histograms cannot evaluate materializing ops "
                        "or windows over out-of-core logs (topology-only "
                        "graph) — use streaming/auto"
                    )
                return PhysicalPlan(
                    backend="graph",
                    activities_as_output_mask=acts is not None,
                    notes=("graph=of_type_counts",) + tuple(notes),
                )
            if (
                requested == "auto" and graph_available and graph_ok
                and not windowed
            ):
                return PhysicalPlan(
                    backend="graph",
                    activities_as_output_mask=acts is not None,
                    notes=("graph=of_type_counts",) + tuple(notes),
                )
            if not needs_repo:  # chunked bincount, window → row range
                return PhysicalPlan(
                    backend="streaming",
                    row_range_window=(window.t0, window.t1) if window else None,
                )
            if info.num_events > memory_budget_events:
                raise QueryPlanError(
                    "variants / materializing ops on an out-of-core log "
                    "exceed the memory budget; raise memory_budget_events "
                    "or pre-dice the log"
                )
            return PhysicalPlan(backend="numpy", materialize=True)
        if requested == "graph" and not needs_repo:
            return PhysicalPlan(
                backend="graph",
                activities_as_output_mask=acts is not None,
                notes=("graph=of_type_counts",) + tuple(notes),
            )
        return PhysicalPlan(backend="numpy")

    # -- topology sinks (DFG / process map / neighborhood) -------------------
    requested = plan.sink.backend  # validated against _DFG_BACKENDS above

    # graph backend: the aggregated :DF CSR answers un-windowed topology
    # queries as lookups.  A window needs the event-level tables (out-of-core
    # graphs are topology-only), and barriers change the source itself.
    if requested == "graph" or (
        requested == "auto"
        and graph_available
        and not has_barrier
        and (window is None or window.empty)
    ):
        if has_barrier:
            raise QueryPlanError(
                "graph backend cannot evaluate materializing ops "
                "(top_variants / relink); drop them or use another backend"
            )
        windowed = window is not None and not window.empty
        if (
            windowed
            and info.kind == "memmap"
            and info.num_events > memory_budget_events
        ):
            raise QueryPlanError(
                "windowed graph queries need event tables; this out-of-core "
                "log builds a topology-only graph — use streaming/auto"
            )
        notes.append(
            "graph=event_tables_window" if windowed else "graph=csr_lookup"
        )
        return PhysicalPlan(
            backend="graph",
            activities_as_output_mask=acts is not None,
            notes=tuple(notes),
        )

    if info.kind == "memmap":
        if has_barrier:
            if requested == "streaming":
                raise QueryPlanError(
                    "streaming cannot evaluate materializing ops "
                    "(top_variants / relink)"
                )
            if info.num_events > memory_budget_events:
                raise QueryPlanError(
                    "materializing ops (top_variants / relink) on an "
                    "out-of-core log exceed the memory budget"
                )
        if (
            info.num_events > memory_budget_events
            and requested not in ("auto", "streaming")
        ):
            raise QueryPlanError(
                f"backend {requested!r} would materialize an out-of-core "
                "log into memory; use streaming/auto or raise "
                "memory_budget_events"
            )
        out_of_core = requested == "streaming" or (
            requested == "auto" and info.num_events > memory_budget_events
        )
        out_of_core = out_of_core and not has_barrier
        if out_of_core:
            return PhysicalPlan(
                backend="streaming",
                row_range_window=(window.t0, window.t1) if window else None,
                # streaming always post-masks the raw Ψ (before any view)
                activities_as_output_mask=acts is not None,
                notes=("streaming=O(A²+chunk) memory",),
            )
        backend = _device_backend(
            info.num_pairs, mesh=mesh, tiny_pairs=tiny_pairs,
            requested=requested,
        )
        materialize = True
    else:
        if requested == "streaming":
            raise QueryPlanError(
                "streaming backend requires a MemmapLog source"
            )
        backend = _device_backend(
            info.num_pairs, mesh=mesh, tiny_pairs=tiny_pairs,
            requested=requested,
        )
        materialize = False

    if backend == "distributed" and mesh is None:
        raise QueryPlanError("distributed backend requires a mesh")

    view_pushdown = False
    if view is not None and info.activity_names is not None:
        labels = view.to_view().visible_labels(info.activity_names)
        if len(labels) < info.num_activities:
            view_pushdown = True
            notes.append(f"count_space=G×G ({len(labels)}<{info.num_activities})")

    fuse = (
        fused_dicing
        and backend == "pallas"
        and window is not None
        and not window.empty
    )
    return PhysicalPlan(
        backend=backend,
        materialize=materialize,
        fused_dicing=fuse,
        view_pushdown=view_pushdown,
        # with a view pushdown the filter must stay a pair predicate (the
        # result matrix is in group space, so raw-activity rows are gone);
        # without it the mask applies to the raw Ψ before any projection
        activities_as_output_mask=acts is not None and not view_pushdown,
        notes=tuple(notes),
    )
