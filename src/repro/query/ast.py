"""Logical query algebra + the fluent ``Q`` builder.

The paper's headline interface is a *single declarative query* (Cypher with
WHERE-clause dicing) executed where the data lives.  This module is the
repo's equivalent of that Cypher surface: an analyst writes

    Q.log(repo).window(t0, t1).activities(["a", "b"]).view(view).dfg()

and the chain compiles to a :class:`LogicalPlan` — ``source → ops → sink`` —
that the optimizer rewrites and the engine executes on the backend the cost
model picks.  Nothing here touches data; plans are frozen, hashable, and
serialize to a stable key for the plan/result cache.

Grammar::

    plan   := source  op*  sink
    source := leaf                      -- EventRepository | MemmapLog
            | LogRef(leaf, name)        -- a *named* single log
            | FromLogs(repo, names)     -- the L×T dice: the named logs of a
                                           multi-log repository
            | Union(source, ...)        -- multi-source (class UnionSource),
                                           built via Q.logs(a, b, ...)
    op     := Window(t0, t1)            -- WHERE t0 <= time < t1, paper
                                           semantics (both pair endpoints)
            | Activities(keep, relink)  -- keep only these activities;
                                           relink=False: pair predicate
                                           (paper semantics), relink=True:
                                           pm4py re-linking (materializes)
            | TopVariants(k)            -- keep traces of the top-k variants
                                           (materializes)
            | ApplyView(mapping)        -- access-control projection (§2.2)
    sink   := DFGSink(backend) | HistogramSink() | VariantsSink(k)
            | CompareSink(backend)      -- union only: per-log Ψ + drift
            | ProcessMapSink(top, ...)  -- significance-filtered map
            | NeighborhoodSink(act, k)  -- k-hop :DF neighborhood
            | FitnessSink(model)        -- token-replay conformance
            | AlignmentsSink(model)     -- optimal DFG alignments

Conformance sinks evaluate **sequence semantics**: Window / Activities /
ApplyView drop (or relabel) events and re-link the survivors within each
trace, exactly like :class:`VariantsSink` — replay scores trace
*sequences*, so predicates must transform the sequences, not mask pairs.

The source algebra is what makes "which logs" a plan property instead of a
pre-filter: predicates distribute into every branch, union sinks merge
branch results on an aligned activity axis, and :class:`CompareSink` keeps
branches separate for cross-deployment conformance drift.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.conformance import ModelSpec
from repro.core.repository import EventRepository
from repro.core.streaming import MemmapLog, memmap_log_name
from repro.core.views import HIDDEN, ActivityView

__all__ = [
    "Window",
    "EMPTY_WINDOW",
    "Activities",
    "TopVariants",
    "ApplyView",
    "DFGSink",
    "HistogramSink",
    "VariantsSink",
    "CompareSink",
    "ProcessMapSink",
    "NeighborhoodSink",
    "FitnessSink",
    "AlignmentsSink",
    "TOPOLOGY_SINKS",
    "CONFORMANCE_SINKS",
    "ModelSpec",
    "LogRef",
    "FromLogs",
    "UnionSource",
    "union_activity_names",
    "LogicalPlan",
    "Query",
    "Q",
    "QueryPlanError",
    "source_kind",
]


class QueryPlanError(ValueError):
    """Raised for queries outside the supported algebra (bad op/sink combo,
    unsupported source, unknown activity names)."""


# ---------------------------------------------------------------------------
# Operators
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Window:
    """Time dice ``t0 <= time < t1``; a pair counts iff both endpoints fall
    inside (the paper's WHERE clause — the E×E relation stays fixed)."""

    t0: float
    t1: float

    @property
    def empty(self) -> bool:
        return self.t0 >= self.t1

    def normalized(self) -> "Window":
        """Every empty window collapses to the one canonical
        :data:`EMPTY_WINDOW`, so equivalent-but-differently-phrased empty
        queries share a plan key (and backends can short-circuit on it)."""
        return EMPTY_WINDOW if self.empty else self

    def intersect(self, other: "Window") -> "Window":
        """Exact pair-mask intersection (masks AND together), normalized."""
        return Window(max(self.t0, other.t0), min(self.t1, other.t1)).normalized()


#: the canonical empty time dice — selects no event, so DFG/histogram sinks
#: short-circuit to zeros without scanning
EMPTY_WINDOW = Window(0.0, 0.0)


@dataclasses.dataclass(frozen=True)
class Activities:
    """Activity filter.  ``relink=False``: a pair counts iff both endpoints
    execute a kept activity (pure predicate — commutes past counting).
    ``relink=True``: pm4py semantics — drop events, re-link survivors
    (materializes a diced repository; a plan barrier)."""

    keep: Tuple[str, ...]
    relink: bool = False


@dataclasses.dataclass(frozen=True)
class TopVariants:
    """Keep only traces of the ``k`` most frequent variants (materializes)."""

    k: int


@dataclasses.dataclass(frozen=True)
class ApplyView:
    """Access-control projection: raw activity → group label (or HIDDEN).
    Canonical, hashable mirror of :class:`repro.core.views.ActivityView`."""

    mapping: Tuple[Tuple[str, str], ...]
    default: str = HIDDEN

    @staticmethod
    def from_view(view: Union[ActivityView, "ApplyView", Dict[str, str]]) -> "ApplyView":
        if isinstance(view, ApplyView):
            return view
        if isinstance(view, ActivityView):
            return ApplyView(
                mapping=tuple(sorted(view.mapping.items())), default=view.default
            )
        return ApplyView(mapping=tuple(sorted(view.items())))

    def to_view(self) -> ActivityView:
        return ActivityView(mapping=dict(self.mapping), default=self.default)


Op = Union[Window, Activities, TopVariants, ApplyView]

#: ops that force materializing an intermediate repository — predicates
#: cannot be pushed across them
BARRIER_OPS = (TopVariants,)


def is_barrier(op: Op) -> bool:
    return isinstance(op, BARRIER_OPS) or (
        isinstance(op, Activities) and op.relink
    )


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DFGSink:
    """Ψ count matrix — Algorithm 1.  ``backend="auto"`` defers to the cost
    model; anything else pins the physical operator."""

    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class CompareSink:
    """Cross-log comparison (union sources only): per-log Ψ matrices on the
    aligned union vocabulary, Ψ-difference matrices against the first
    (reference) branch, and replay-fitness drift
    (:func:`repro.core.conformance.replay_fitness` of every branch against
    the dependency graph discovered from the reference branch).  ``backend``
    pins the per-branch counting operator, like :class:`DFGSink`."""

    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class HistogramSink:
    """Per-activity event counts (the aggregate-only histogram endpoint).
    ``backend`` pins the physical operator like :class:`DFGSink`:
    ``"graph"`` serves the counts from the stored ``:OF_TYPE`` in-degrees
    (windowed: from the graph's time index) instead of rescanning."""

    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class VariantsSink:
    """Trace-variant table, optionally truncated to the top ``k``."""

    k: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ProcessMapSink:
    """ProFIT-style significance-filtered process map: the top ``top``
    fraction of Activity nodes by event frequency, then the top
    ``edge_top`` (default ``top``) fraction of ``:DF`` edges among them —
    the sink the graph tier makes a store lookup.  ``backend`` pins the
    physical operator like :class:`DFGSink` (``"graph"`` forces the CSR
    store)."""

    top: float = 0.2
    edge_top: Optional[float] = None
    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class NeighborhoodSink:
    """k-hop ``:DF`` neighborhood of one activity (``direction`` ∈
    out | in | both): reached activities with hop distances plus the
    induced edge subgraph.  Under a view, ``activity`` names a visible
    group label."""

    activity: str
    k: int = 1
    direction: str = "out"
    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class FitnessSink:
    """Token-replay conformance: per-trace replay fitness of the selected
    traces against ``model`` (a canonical :class:`ModelSpec`).

    ``model=None`` replays against the source's **own** discovered
    dependency graph (whole log, the plan's view/filter applied, windows
    ignored — the "does this slice conform to the overall process" drift
    question; the engine memoizes the discovery per source fingerprint).
    Under :meth:`Query.compare` the default is the *reference branch's*
    model.  ``backend`` ∈ auto | numpy | streaming | graph."""

    model: Optional[ModelSpec] = None
    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class AlignmentsSink:
    """Optimal DFG alignments (skip / insert / move-on-model edit distance
    over the model's edge relation), batched per trace variant.  ``model``
    defaults like :class:`FitnessSink`.  Needs the variant table, so it
    materializes like :class:`VariantsSink` (budget-gated out-of-core)."""

    model: Optional[ModelSpec] = None
    backend: str = "auto"


Sink = Union[
    DFGSink, HistogramSink, VariantsSink, CompareSink,
    ProcessMapSink, NeighborhoodSink, FitnessSink, AlignmentsSink,
]

#: sinks answered from the aggregated :DF topology — the graph backend's
#: domain (and the planner's amortization candidates)
TOPOLOGY_SINKS = (DFGSink, ProcessMapSink, NeighborhoodSink)

#: sinks that replay/align trace sequences — ops apply with re-linking
#: (sequence) semantics, and the graph backend serves them from the stored
#: event tables rather than the aggregated CSR
CONFORMANCE_SINKS = (FitnessSink, AlignmentsSink)


# ---------------------------------------------------------------------------
# Source algebra
# ---------------------------------------------------------------------------


class LogRef:
    """A *named* single-log source — the leaf of the source algebra.

    ``name`` is the branch label used for provenance (result ``log_names``,
    per-branch physical-plan notes, compare axes); ``resolve()`` yields the
    underlying store the engine executes on."""

    def __init__(self, source, name: str):
        from repro.graph.shard import ShardedLog

        if not isinstance(source, (EventRepository, MemmapLog, ShardedLog)):
            raise QueryPlanError(
                f"LogRef wraps a leaf source, got {type(source).__name__}"
            )
        self.source = source
        self.name = str(name)

    def resolve(self):
        return self.source

    @property
    def kind(self) -> str:
        return source_kind(self.source)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"LogRef({self.kind}, name={self.name!r})"


class FromLogs:
    """The L×T dice as a plan node: the traces of the named logs of one
    multi-log repository (``trace_log`` / ``log_names`` are already
    materialized — Definition 1).  Resolution (one
    :meth:`EventRepository.select_logs` call) is lazy and memoized; sibling
    branches expanded from the same repository by :meth:`Q.logs` share one
    :meth:`EventRepository.split_logs` pass instead of re-dicing per
    branch."""

    def __init__(
        self,
        repo: EventRepository,
        names: Sequence[str],
        name=None,
        _sibling_split: Optional[Dict[str, EventRepository]] = None,
        _siblings: Optional[Tuple[str, ...]] = None,
    ):
        if not isinstance(repo, EventRepository):
            raise QueryPlanError("FromLogs requires an EventRepository")
        self.repo = repo
        self.names = tuple(str(n) for n in names)
        if not self.names:
            raise QueryPlanError("FromLogs needs at least one log name")
        for n in self.names:
            if n not in repo.log_names:
                raise QueryPlanError(
                    f"unknown log {n!r}; repository has {repo.log_names}"
                )
        self.name = str(name) if name is not None else "+".join(self.names)
        self._resolved: Optional[EventRepository] = None
        # Q.logs fills this shared dict with one split_logs pass covering
        # every sibling branch the first time any of them resolves
        self._sibling_split = _sibling_split
        self._siblings = _siblings

    def resolve(self) -> EventRepository:
        if self._resolved is None:
            if (
                self._sibling_split is not None
                and self._siblings is not None
                and len(self.names) == 1
            ):
                if not self._sibling_split:
                    self._sibling_split.update(
                        self.repo.split_logs(self._siblings)
                    )
                self._resolved = self._sibling_split[self.names[0]]
            else:
                self._resolved = self.repo.select_logs(self.names)
        return self._resolved

    @property
    def kind(self) -> str:
        return "repository"

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"FromLogs({self.names!r}, name={self.name!r})"


class UnionSource:
    """An ordered union of named branches (:class:`LogRef` /
    :class:`FromLogs`).  Branch names are unique; nesting is flattened by
    the :meth:`Q.logs` builder, so the algebra stays one level deep."""

    def __init__(self, branches: Sequence[object]):
        branches = tuple(branches)
        if not branches:
            raise QueryPlanError("union of zero sources")
        for b in branches:
            if isinstance(b, UnionSource):
                raise QueryPlanError(
                    "nested unions are not supported; flatten the branches"
                )
            if not isinstance(b, (LogRef, FromLogs)):
                raise QueryPlanError(
                    f"union branches must be LogRef/FromLogs, got "
                    f"{type(b).__name__}"
                )
        names = [b.name for b in branches]
        if len(set(names)) != len(names):
            raise QueryPlanError(f"duplicate branch names in union: {names}")
        self.branches = branches

    @property
    def branch_names(self) -> Tuple[str, ...]:
        return tuple(b.name for b in self.branches)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"UnionSource({', '.join(map(repr, self.branches))})"


def union_activity_names(union: UnionSource) -> List[str]:
    """The aligned union vocabulary — the sorted name union over the
    branches, derived from *unresolved* branch metadata (``select_logs``
    preserves its parent's vocabulary, so a FromLogs branch contributes
    exactly the parent's names).  This is the one implementation both the
    engine (cache-hit canonicalization, merge axes) and the planner
    (:func:`~repro.query.planner.source_info`) use, and it equals the
    vocabulary of the canonical concatenated repository."""
    names = set()
    for b in union.branches:
        if isinstance(b, FromLogs):
            names |= set(b.repo.activity_names)
        elif isinstance(b.source, EventRepository):
            names |= set(b.source.activity_names)
        else:
            names |= set(b.source.activity_labels())
    return sorted(names)


def _default_branch_name(source, index: int) -> str:
    from repro.graph.shard import ShardedLog, sharded_log_name

    if isinstance(source, MemmapLog):
        # same rule as repository_from_memmap provenance (core.streaming)
        return memmap_log_name(source)
    if isinstance(source, ShardedLog):
        return sharded_log_name(source)
    if isinstance(source, EventRepository):
        if len(source.log_names) == 1:
            return source.log_names[0]
        return "+".join(source.log_names)
    return f"log{index}"


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


def source_kind(source) -> str:
    # local import: graph.shard depends on core + sharding only — no cycle
    from repro.graph.shard import ShardedLog

    if isinstance(source, EventRepository):
        return "repository"
    if isinstance(source, MemmapLog):
        return "memmap"
    if isinstance(source, ShardedLog):
        return "sharded"
    if isinstance(source, UnionSource):
        return "union(" + ",".join(b.kind for b in source.branches) + ")"
    if isinstance(source, (LogRef, FromLogs)):
        return source.kind
    raise QueryPlanError(
        f"unsupported query source {type(source).__name__}; "
        "expected EventRepository, MemmapLog, ShardedLog, or a "
        "source-algebra node"
    )


@dataclasses.dataclass(frozen=True)
class LogicalPlan:
    source: str  # "repository" | "memmap"
    ops: Tuple[Op, ...]
    sink: Sink

    def _payload(self) -> list:
        def enc(x) -> list:
            return [type(x).__name__, dataclasses.asdict(x)]

        return [self.source, [enc(o) for o in self.ops], enc(self.sink)]

    def key(self) -> str:
        """Stable content hash — the cache key half owned by the plan."""
        blob = json.dumps(self._payload(), sort_keys=True, default=repr)
        return hashlib.sha256(blob.encode()).hexdigest()[:32]

    def has_barrier(self) -> bool:
        """True when any op materializes an intermediate repository — such
        plans cannot be answered incrementally from cached suffix state."""
        return any(is_barrier(op) for op in self.ops)

    def describe(self) -> str:
        ops = " → ".join(
            f"{type(o).__name__}{dataclasses.astuple(o) if not isinstance(o, ApplyView) else (len(o.mapping),)}"
            for o in self.ops
        ) or "(no ops)"
        return f"{self.source} → {ops} → {type(self.sink).__name__}"


# ---------------------------------------------------------------------------
# Fluent builder
# ---------------------------------------------------------------------------


class Query:
    """Immutable fluent chain.  Non-terminal methods return a new Query;
    terminal methods (:meth:`dfg`, :meth:`histogram`, :meth:`variants`)
    hand the plan to a :class:`repro.query.execute.QueryEngine`."""

    def __init__(self, source, ops: Tuple[Op, ...] = (), engine=None):
        if isinstance(source, (LogRef, FromLogs)):
            # a single named/selected source executes as its resolution —
            # the wrapper only matters inside a UnionSource
            source = source.resolve()
        self._kind = source_kind(source)
        self.source = source
        self.ops = tuple(ops)
        self._engine = engine

    def _with(self, op: Op) -> "Query":
        return Query(self.source, self.ops + (op,), self._engine)

    # -- non-terminals -------------------------------------------------------
    def window(self, t0: float, t1: float) -> "Query":
        return self._with(Window(float(t0), float(t1)))

    def activities(self, keep: Sequence[str], relink: bool = False) -> "Query":
        return self._with(Activities(tuple(str(a) for a in keep), relink))

    def top_variants(self, k: int) -> "Query":
        return self._with(TopVariants(int(k)))

    def view(self, view) -> "Query":
        return self._with(ApplyView.from_view(view))

    def using(self, engine) -> "Query":
        """Pin a specific :class:`QueryEngine` (default: the module-level
        shared engine with the shared cache)."""
        q = Query(self.source, self.ops, engine)
        return q

    # -- terminals -----------------------------------------------------------
    def _run(self, sink: Sink):
        from .execute import default_engine

        engine = self._engine or default_engine()
        return engine.run(self, sink)

    def dfg(self, backend: str = "auto"):
        return self._run(DFGSink(backend=backend))

    def histogram(self, backend: str = "auto"):
        return self._run(HistogramSink(backend=backend))

    def variants(self, k: Optional[int] = None):
        return self._run(VariantsSink(k=k))

    def process_map(
        self,
        top: float = 0.2,
        edge_top: Optional[float] = None,
        backend: str = "auto",
    ):
        """Significance-filtered process map (top-fraction nodes/edges) —
        served from the CSR graph store once one is built."""
        return self._run(ProcessMapSink(
            top=float(top),
            edge_top=float(edge_top) if edge_top is not None else None,
            backend=backend,
        ))

    def neighborhood(
        self,
        activity: str,
        k: int = 1,
        direction: str = "out",
        backend: str = "auto",
    ):
        """k-hop ``:DF`` successor/predecessor neighborhood of
        ``activity``."""
        if direction not in ("out", "in", "both"):
            raise QueryPlanError(
                f"direction must be out|in|both, got {direction!r}"
            )
        return self._run(NeighborhoodSink(
            activity=str(activity), k=int(k), direction=direction,
            backend=backend,
        ))

    def fitness(self, model=None, backend: str = "auto"):
        """Token-replay conformance of the selected traces.

        ``model`` is a :class:`~repro.core.discovery.DiscoveredModel` (or
        canonical :class:`ModelSpec`); ``None`` replays against the
        source's own whole-log discovered model (see :class:`FitnessSink`).
        """
        return self._run(FitnessSink(
            model=ModelSpec.from_model(model) if model is not None else None,
            backend=backend,
        ))

    def alignments(self, model=None, backend: str = "auto"):
        """Optimal DFG alignments (per-trace cost + normalized fitness),
        batched per variant.  ``model`` as in :meth:`fitness`."""
        return self._run(AlignmentsSink(
            model=ModelSpec.from_model(model) if model is not None else None,
            backend=backend,
        ))

    def compare(self, backend: str = "auto"):
        """Cross-log comparison (requires a ``Q.logs(...)`` source): per-log
        Ψ + difference matrices + replay-fitness drift vs the first log."""
        return self._run(CompareSink(backend=backend))

    # -- introspection -------------------------------------------------------
    def logical_plan(self, sink: Sink) -> LogicalPlan:
        return LogicalPlan(self._kind, self.ops, sink)

    def explain(self, sink: Optional[Sink] = None, after=None) -> str:
        """Planner-side explanation; pass ``after=`` a prior
        :class:`QueryResult` (or its trace) to diff prediction vs. what
        actually ran."""
        from .execute import default_engine

        engine = self._engine or default_engine()
        return engine.explain(self, sink or DFGSink(), after=after)


class Q:
    """Entry point: ``Q.log(repo_or_memmap)`` or ``Q.logs(a, b, ...)``."""

    @staticmethod
    def log(source) -> Query:
        return Query(source)

    @staticmethod
    def logs(*sources, names: Optional[Sequence[str]] = None) -> Query:
        """Multi-source entry point — builds a :class:`UnionSource`.

        Accepted shapes:

        * ``Q.logs(a, b, ...)`` — each argument an ``EventRepository`` /
          ``MemmapLog`` (auto-named), a ``(source, name)`` pair, or a
          prebuilt ``LogRef`` / ``FromLogs`` / ``UnionSource`` (flattened);
        * ``Q.logs(repo)`` with a *multi-log* repository — one branch per
          entry of ``repo.log_names`` (cross-deployment compare without
          pre-splitting);
        * ``Q.logs(repo, names=["prod", "canary"])`` — ``FromLogs``
          selection of the named logs, one branch each.
        """
        if not sources:
            raise QueryPlanError("Q.logs() needs at least one source")
        # (branch, explicitly_named) — explicit duplicates are an error (a
        # tenant naming the same log twice would silently double-count);
        # only auto-derived collisions (two memmaps sharing a basename) are
        # uniquified with a suffix
        branches: List[Tuple[object, bool]] = []
        if names is not None:
            if len(sources) != 1 or not isinstance(sources[0], EventRepository):
                raise QueryPlanError(
                    "Q.logs(..., names=...) takes exactly one multi-log "
                    "repository"
                )
            shared: Dict[str, EventRepository] = {}
            siblings = tuple(str(n) for n in names)
            branches = [
                (FromLogs(sources[0], (n,), _sibling_split=shared,
                          _siblings=siblings), True)
                for n in names
            ]
        elif (
            len(sources) == 1
            and isinstance(sources[0], EventRepository)
            and len(sources[0].log_names) > 1
        ):
            shared = {}
            siblings = tuple(sources[0].log_names)
            branches = [
                (FromLogs(sources[0], (n,), _sibling_split=shared,
                          _siblings=siblings), True)
                for n in sources[0].log_names
            ]
        else:
            for i, s in enumerate(sources):
                if isinstance(s, UnionSource):
                    branches.extend((b, True) for b in s.branches)
                elif isinstance(s, (LogRef, FromLogs)):
                    branches.append((s, True))
                elif isinstance(s, tuple) and len(s) == 2:
                    branches.append((LogRef(s[0], str(s[1])), True))
                else:
                    branches.append(
                        (LogRef(s, _default_branch_name(s, i)), False)
                    )
        seen: Dict[str, int] = {}
        named: List[object] = []
        for b, explicit in branches:
            n = seen.get(b.name)
            if n is None:
                seen[b.name] = 1
                named.append(b)
                continue
            if explicit:
                raise QueryPlanError(
                    f"duplicate branch name {b.name!r}; name each log "
                    "uniquely (or drop the duplicate)"
                )
            fresh = f"{b.name}#{n}"
            while fresh in seen:  # '#n' may itself be a taken basename
                n += 1
                fresh = f"{b.name}#{n}"
            seen[b.name] = n + 1
            seen[fresh] = 1
            # only bare leaves are auto-named, so b is always a LogRef here
            named.append(LogRef(b.source, fresh))
        return Query(UnionSource(named))
