"""Logical query algebra + the fluent ``Q`` builder.

The paper's headline interface is a *single declarative query* (Cypher with
WHERE-clause dicing) executed where the data lives.  This module is the
repo's equivalent of that Cypher surface: an analyst writes

    Q.log(repo).window(t0, t1).activities(["a", "b"]).view(view).dfg()

and the chain compiles to a :class:`LogicalPlan` — ``source → ops → sink`` —
that the optimizer rewrites and the engine executes on the backend the cost
model picks.  Nothing here touches data; plans are frozen, hashable, and
serialize to a stable key for the plan/result cache.

Grammar::

    plan   := source  op*  sink
    source := "repository" (EventRepository) | "memmap" (MemmapLog)
    op     := Window(t0, t1)            -- WHERE t0 <= time < t1, paper
                                           semantics (both pair endpoints)
            | Activities(keep, relink)  -- keep only these activities;
                                           relink=False: pair predicate
                                           (paper semantics), relink=True:
                                           pm4py re-linking (materializes)
            | TopVariants(k)            -- keep traces of the top-k variants
                                           (materializes)
            | ApplyView(mapping)        -- access-control projection (§2.2)
    sink   := DFGSink(backend) | HistogramSink() | VariantsSink(k)
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Optional, Sequence, Tuple, Union

from repro.core.repository import EventRepository
from repro.core.streaming import MemmapLog
from repro.core.views import HIDDEN, ActivityView

__all__ = [
    "Window",
    "EMPTY_WINDOW",
    "Activities",
    "TopVariants",
    "ApplyView",
    "DFGSink",
    "HistogramSink",
    "VariantsSink",
    "LogicalPlan",
    "Query",
    "Q",
    "QueryPlanError",
    "source_kind",
]


class QueryPlanError(ValueError):
    """Raised for queries outside the supported algebra (bad op/sink combo,
    unsupported source, unknown activity names)."""


# ---------------------------------------------------------------------------
# Operators
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Window:
    """Time dice ``t0 <= time < t1``; a pair counts iff both endpoints fall
    inside (the paper's WHERE clause — the E×E relation stays fixed)."""

    t0: float
    t1: float

    @property
    def empty(self) -> bool:
        return self.t0 >= self.t1

    def normalized(self) -> "Window":
        """Every empty window collapses to the one canonical
        :data:`EMPTY_WINDOW`, so equivalent-but-differently-phrased empty
        queries share a plan key (and backends can short-circuit on it)."""
        return EMPTY_WINDOW if self.empty else self

    def intersect(self, other: "Window") -> "Window":
        """Exact pair-mask intersection (masks AND together), normalized."""
        return Window(max(self.t0, other.t0), min(self.t1, other.t1)).normalized()


#: the canonical empty time dice — selects no event, so DFG/histogram sinks
#: short-circuit to zeros without scanning
EMPTY_WINDOW = Window(0.0, 0.0)


@dataclasses.dataclass(frozen=True)
class Activities:
    """Activity filter.  ``relink=False``: a pair counts iff both endpoints
    execute a kept activity (pure predicate — commutes past counting).
    ``relink=True``: pm4py semantics — drop events, re-link survivors
    (materializes a diced repository; a plan barrier)."""

    keep: Tuple[str, ...]
    relink: bool = False


@dataclasses.dataclass(frozen=True)
class TopVariants:
    """Keep only traces of the ``k`` most frequent variants (materializes)."""

    k: int


@dataclasses.dataclass(frozen=True)
class ApplyView:
    """Access-control projection: raw activity → group label (or HIDDEN).
    Canonical, hashable mirror of :class:`repro.core.views.ActivityView`."""

    mapping: Tuple[Tuple[str, str], ...]
    default: str = HIDDEN

    @staticmethod
    def from_view(view: Union[ActivityView, "ApplyView", Dict[str, str]]) -> "ApplyView":
        if isinstance(view, ApplyView):
            return view
        if isinstance(view, ActivityView):
            return ApplyView(
                mapping=tuple(sorted(view.mapping.items())), default=view.default
            )
        return ApplyView(mapping=tuple(sorted(view.items())))

    def to_view(self) -> ActivityView:
        return ActivityView(mapping=dict(self.mapping), default=self.default)


Op = Union[Window, Activities, TopVariants, ApplyView]

#: ops that force materializing an intermediate repository — predicates
#: cannot be pushed across them
BARRIER_OPS = (TopVariants,)


def is_barrier(op: Op) -> bool:
    return isinstance(op, BARRIER_OPS) or (
        isinstance(op, Activities) and op.relink
    )


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DFGSink:
    """Ψ count matrix — Algorithm 1.  ``backend="auto"`` defers to the cost
    model; anything else pins the physical operator."""

    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class HistogramSink:
    """Per-activity event counts (the aggregate-only histogram endpoint)."""


@dataclasses.dataclass(frozen=True)
class VariantsSink:
    """Trace-variant table, optionally truncated to the top ``k``."""

    k: Optional[int] = None


Sink = Union[DFGSink, HistogramSink, VariantsSink]


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


def source_kind(source) -> str:
    if isinstance(source, EventRepository):
        return "repository"
    if isinstance(source, MemmapLog):
        return "memmap"
    raise QueryPlanError(
        f"unsupported query source {type(source).__name__}; "
        "expected EventRepository or MemmapLog"
    )


@dataclasses.dataclass(frozen=True)
class LogicalPlan:
    source: str  # "repository" | "memmap"
    ops: Tuple[Op, ...]
    sink: Sink

    def _payload(self) -> list:
        def enc(x) -> list:
            return [type(x).__name__, dataclasses.asdict(x)]

        return [self.source, [enc(o) for o in self.ops], enc(self.sink)]

    def key(self) -> str:
        """Stable content hash — the cache key half owned by the plan."""
        blob = json.dumps(self._payload(), sort_keys=True, default=repr)
        return hashlib.sha256(blob.encode()).hexdigest()[:32]

    def has_barrier(self) -> bool:
        """True when any op materializes an intermediate repository — such
        plans cannot be answered incrementally from cached suffix state."""
        return any(is_barrier(op) for op in self.ops)

    def describe(self) -> str:
        ops = " → ".join(
            f"{type(o).__name__}{dataclasses.astuple(o) if not isinstance(o, ApplyView) else (len(o.mapping),)}"
            for o in self.ops
        ) or "(no ops)"
        return f"{self.source} → {ops} → {type(self.sink).__name__}"


# ---------------------------------------------------------------------------
# Fluent builder
# ---------------------------------------------------------------------------


class Query:
    """Immutable fluent chain.  Non-terminal methods return a new Query;
    terminal methods (:meth:`dfg`, :meth:`histogram`, :meth:`variants`)
    hand the plan to a :class:`repro.query.execute.QueryEngine`."""

    def __init__(self, source, ops: Tuple[Op, ...] = (), engine=None):
        self._kind = source_kind(source)
        self.source = source
        self.ops = tuple(ops)
        self._engine = engine

    def _with(self, op: Op) -> "Query":
        return Query(self.source, self.ops + (op,), self._engine)

    # -- non-terminals -------------------------------------------------------
    def window(self, t0: float, t1: float) -> "Query":
        return self._with(Window(float(t0), float(t1)))

    def activities(self, keep: Sequence[str], relink: bool = False) -> "Query":
        return self._with(Activities(tuple(str(a) for a in keep), relink))

    def top_variants(self, k: int) -> "Query":
        return self._with(TopVariants(int(k)))

    def view(self, view) -> "Query":
        return self._with(ApplyView.from_view(view))

    def using(self, engine) -> "Query":
        """Pin a specific :class:`QueryEngine` (default: the module-level
        shared engine with the shared cache)."""
        q = Query(self.source, self.ops, engine)
        return q

    # -- terminals -----------------------------------------------------------
    def _run(self, sink: Sink):
        from .execute import default_engine

        engine = self._engine or default_engine()
        return engine.run(self, sink)

    def dfg(self, backend: str = "auto"):
        return self._run(DFGSink(backend=backend))

    def histogram(self):
        return self._run(HistogramSink())

    def variants(self, k: Optional[int] = None):
        return self._run(VariantsSink(k=k))

    # -- introspection -------------------------------------------------------
    def logical_plan(self, sink: Sink) -> LogicalPlan:
        return LogicalPlan(self._kind, self.ops, sink)

    def explain(self, sink: Optional[Sink] = None) -> str:
        from .execute import default_engine

        engine = self._engine or default_engine()
        return engine.explain(self, sink or DFGSink())


class Q:
    """Entry point: ``Q.log(repo_or_memmap)``."""

    @staticmethod
    def log(source) -> Query:
        return Query(source)
