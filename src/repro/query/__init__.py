"""``repro.query`` — the declarative process-query engine.

One entry point::

    from repro.query import Q

    result = Q.log(repo).window(t0, t1).activities(keep).view(v).dfg()
    result.value        # the Ψ count matrix
    result.names        # its activity (or group) labels
    result.from_cache   # True when served from the plan/result cache

The chain compiles to a logical plan (:mod:`repro.query.ast`), is rewritten
by count-preserving rules (:mod:`repro.query.optimize`), mapped to a
physical backend by a small cost model (:mod:`repro.query.planner`), and
executed on the repo's existing primitives (:mod:`repro.query.execute`)
with an LRU plan/result cache (:mod:`repro.query.cache`).
"""

from .ast import (
    CONFORMANCE_SINKS,
    EMPTY_WINDOW,
    TOPOLOGY_SINKS,
    Activities,
    AlignmentsSink,
    ApplyView,
    CompareSink,
    DFGSink,
    FitnessSink,
    FromLogs,
    HistogramSink,
    LogicalPlan,
    LogRef,
    ModelSpec,
    NeighborhoodSink,
    ProcessMapSink,
    Q,
    Query,
    QueryPlanError,
    TopVariants,
    UnionSource,
    VariantsSink,
    Window,
    union_activity_names,
)
from .cache import (
    MemmapFingerprint,
    QueryCache,
    ResumableState,
    fingerprint,
    fingerprint_memmap,
    fingerprint_repository,
    fingerprint_union,
    parse_memmap_fingerprint,
    prefix_digest,
    split_union_fingerprint,
)
from .execute import (
    CompareResult,
    EngineStats,
    PlanProbe,
    QueryEngine,
    QueryResult,
    default_engine,
    set_default_engine,
)
from repro.obs import MetricsRegistry, QueryTrace

from .optimize import canonicalize, distribute_over_union
from .planner import (
    PhysicalPlan,
    SourceInfo,
    load_calibration,
    plan_physical,
    source_info,
)

__all__ = [
    "Q", "Query", "QueryPlanError",
    "Window", "EMPTY_WINDOW", "Activities", "TopVariants", "ApplyView",
    "DFGSink", "HistogramSink", "VariantsSink", "CompareSink",
    "ProcessMapSink", "NeighborhoodSink", "FitnessSink", "AlignmentsSink",
    "ModelSpec", "TOPOLOGY_SINKS", "CONFORMANCE_SINKS", "LogicalPlan",
    "LogRef", "FromLogs", "UnionSource", "union_activity_names",
    "QueryCache", "fingerprint", "fingerprint_memmap",
    "fingerprint_repository", "fingerprint_union", "split_union_fingerprint",
    "prefix_digest", "parse_memmap_fingerprint",
    "MemmapFingerprint", "ResumableState",
    "QueryEngine", "QueryResult", "CompareResult", "EngineStats",
    "PlanProbe",
    "MetricsRegistry", "QueryTrace",
    "default_engine", "set_default_engine",
    "canonicalize", "distribute_over_union",
    "plan_physical", "PhysicalPlan", "SourceInfo", "source_info",
    "load_calibration",
]
