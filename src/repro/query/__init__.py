"""``repro.query`` — the declarative process-query engine.

One entry point::

    from repro.query import Q

    result = Q.log(repo).window(t0, t1).activities(keep).view(v).dfg()
    result.value        # the Ψ count matrix
    result.names        # its activity (or group) labels
    result.from_cache   # True when served from the plan/result cache

The chain compiles to a logical plan (:mod:`repro.query.ast`), is rewritten
by count-preserving rules (:mod:`repro.query.optimize`), mapped to a
physical backend by a small cost model (:mod:`repro.query.planner`), and
executed on the repo's existing primitives (:mod:`repro.query.execute`)
with an LRU plan/result cache (:mod:`repro.query.cache`).
"""

from .ast import (
    Activities,
    ApplyView,
    DFGSink,
    HistogramSink,
    LogicalPlan,
    Q,
    Query,
    QueryPlanError,
    TopVariants,
    VariantsSink,
    Window,
)
from .cache import QueryCache, fingerprint
from .execute import (
    EngineStats,
    QueryEngine,
    QueryResult,
    default_engine,
    set_default_engine,
)
from .optimize import canonicalize
from .planner import PhysicalPlan, SourceInfo, plan_physical, source_info

__all__ = [
    "Q", "Query", "QueryPlanError",
    "Window", "Activities", "TopVariants", "ApplyView",
    "DFGSink", "HistogramSink", "VariantsSink", "LogicalPlan",
    "QueryCache", "fingerprint",
    "QueryEngine", "QueryResult", "EngineStats",
    "default_engine", "set_default_engine",
    "canonicalize", "plan_physical", "PhysicalPlan", "SourceInfo",
    "source_info",
]
