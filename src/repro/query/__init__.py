"""``repro.query`` — the declarative process-query engine.

One entry point::

    from repro.query import Q

    result = Q.log(repo).window(t0, t1).activities(keep).view(v).dfg()
    result.value        # the Ψ count matrix
    result.names        # its activity (or group) labels
    result.from_cache   # True when served from the plan/result cache

The chain compiles to a logical plan (:mod:`repro.query.ast`), is rewritten
by count-preserving rules (:mod:`repro.query.optimize`), mapped to a
physical backend by a small cost model (:mod:`repro.query.planner`), and
executed on the repo's existing primitives (:mod:`repro.query.execute`)
with an LRU plan/result cache (:mod:`repro.query.cache`).
"""

from .ast import (
    EMPTY_WINDOW,
    Activities,
    ApplyView,
    DFGSink,
    HistogramSink,
    LogicalPlan,
    Q,
    Query,
    QueryPlanError,
    TopVariants,
    VariantsSink,
    Window,
)
from .cache import (
    MemmapFingerprint,
    QueryCache,
    ResumableState,
    fingerprint,
    fingerprint_memmap,
    fingerprint_repository,
    parse_memmap_fingerprint,
    prefix_digest,
)
from .execute import (
    EngineStats,
    QueryEngine,
    QueryResult,
    default_engine,
    set_default_engine,
)
from .optimize import canonicalize
from .planner import PhysicalPlan, SourceInfo, plan_physical, source_info

__all__ = [
    "Q", "Query", "QueryPlanError",
    "Window", "EMPTY_WINDOW", "Activities", "TopVariants", "ApplyView",
    "DFGSink", "HistogramSink", "VariantsSink", "LogicalPlan",
    "QueryCache", "fingerprint", "fingerprint_memmap",
    "fingerprint_repository", "prefix_digest", "parse_memmap_fingerprint",
    "MemmapFingerprint", "ResumableState",
    "QueryEngine", "QueryResult", "EngineStats",
    "default_engine", "set_default_engine",
    "canonicalize", "plan_physical", "PhysicalPlan", "SourceInfo",
    "source_info",
]
