"""Rule-based logical optimization.

All rewrites are **count-preserving**: every optimized plan produces counts
bit-identical to naive left-to-right evaluation of the original chain
(verified against the Algorithm 1 oracle in ``tests/test_query_engine.py``).

Rules, applied per segment between materialization barriers
(:func:`repro.query.ast.is_barrier` ops fix an intermediate repository, so
predicates must not cross them):

* **window fusion** — ``Window(a,b) ∧ Window(c,d) → Window(max(a,c),
  min(b,d))`` (pair-endpoint masks AND together, so the intersection is
  exact); every empty window — fused or phrased directly — normalizes to
  the one canonical :data:`~repro.query.ast.EMPTY_WINDOW`, so equivalent
  empty queries share a cache key and backends short-circuit to zeros;
* **activity-predicate intersection** — consecutive paper-semantics
  ``Activities`` filters intersect their keep-sets;
* **view composition** — ``ApplyView ∘ ApplyView`` collapses to one
  projection (group partitions compose; HIDDEN absorbs);
* **canonical ordering** — each segment is normalized to
  ``[Window?, Activities?, ApplyView?]``.  Pure predicates commute with each
  other and with the projection, so reordering is free — and it makes the
  plan key insensitive to the order the analyst happened to chain calls in
  (better cache hit rate);
* **no-op elimination** — infinite windows and keep-everything filters drop
  (needs the source schema, hence the ``activity_names`` argument).

The same canonical form is count-preserving under the conformance sinks'
**sequence semantics** (fitness / alignments re-link survivors instead of
masking pairs): window fusion is exact because dicing events by two windows
in either order keeps exactly the events inside the intersection, activity
keep-sets intersect identically, and composed views project each event
once — so one canonical plan serves both interpretation families and they
share cache keys per sink.

Physical pushdowns (row-range dicing into :class:`MemmapLog`'s chunk time
index, fused Pallas dicing, view-below-count relabeling, activity filters as
output masks) are decided by :mod:`repro.query.planner` on top of the
canonical plan produced here.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

from .ast import (
    EMPTY_WINDOW,
    Activities,
    ApplyView,
    LogicalPlan,
    Op,
    QueryPlanError,
    Window,
    is_barrier,
)
from repro.core.views import HIDDEN

__all__ = ["canonicalize", "compose_views", "distribute_over_union"]


def distribute_over_union(plan: LogicalPlan) -> Tuple[Tuple[Op, ...], Tuple[Op, ...]]:
    """Split a canonical **barrier-free** plan into ``(branch_ops,
    merge_ops)`` for a union source.

    The rewrite is count-preserving because every op in scope is either

    * a :class:`Window` — a pair-endpoint predicate.  Pairs never span
      branches (traces belong to exactly one log), so filtering each branch
      and summing equals filtering the concatenation: the window is
      **pushed into every branch** (where it keeps the branch's own
      row-range / fused-kernel pushdowns);
    * an :class:`Activities` pair predicate or an :class:`ApplyView`
      projection — both are *linear* in Ψ (an output mask, resp.
      ``Gᵀ Ψ G``), so they commute with the union sum and run **once at the
      merge**, on the aligned union vocabulary.  Running them per branch
      would instead have to re-derive branch-local keep-ids/group orders
      and re-align group axes — same counts, more work, and a worse cache
      key (branch entries stay reusable as plain single-log scans).

    Materializing ops (:func:`is_barrier`) do not distribute — top-k
    variants of a union is not the union of per-branch top-k — and are
    routed to the materialized-concatenation path by the planner.

    Topology sinks (process map / neighborhood) ride the same split: their
    branch sub-queries count plain Ψ (plus per-branch histograms for node
    significance), and the significance filter / BFS runs once at the
    merge on the aligned union matrix — a per-branch process map would not
    merge count-preservingly.
    """
    if plan.has_barrier():
        raise QueryPlanError(
            "materializing ops do not distribute over a union"
        )
    branch = tuple(op for op in plan.ops if isinstance(op, Window))
    merge = tuple(op for op in plan.ops if not isinstance(op, Window))
    return branch, merge


def compose_views(first: ApplyView, second: ApplyView) -> ApplyView:
    """``second ∘ first`` as a single raw→group projection.

    A raw activity hidden at either stage is hidden in the composition;
    otherwise it lands on ``second``'s label for ``first``'s label.  Group
    *order* (first appearance over the raw vocabulary) is preserved, so the
    composed projection yields the same matrix as staged application.
    """
    m2 = dict(second.mapping)

    def lift(label: str) -> str:
        if label == HIDDEN:
            return HIDDEN
        return m2.get(label, second.default)

    mapping = {a: lift(l1) for a, l1 in first.mapping}
    return ApplyView(
        mapping=tuple(sorted(mapping.items())), default=lift(first.default)
    )


def _canonical_segment(
    seg: List[Op], activity_names: Optional[Sequence[str]], notes: List[str]
) -> List[Op]:
    window: Optional[Window] = None
    acts: Optional[Tuple[str, ...]] = None
    view: Optional[ApplyView] = None
    for op in seg:
        if isinstance(op, Window):
            if window is None:
                window = op
            else:
                window = window.intersect(op)
                notes.append("fuse_windows")
        elif isinstance(op, Activities):
            if view is not None:
                # after a projection "activities" would name group labels —
                # ambiguous and non-commutable.  Filter first, or hide
                # groups with a second view.
                raise QueryPlanError(
                    "activities() after view() is not supported: activity "
                    "filters name raw activities; apply them before the view"
                )
            keep = tuple(sorted(set(op.keep)))
            if acts is None:
                acts = keep
            else:
                acts = tuple(sorted(set(acts) & set(keep)))
                notes.append("intersect_activity_filters")
        elif isinstance(op, ApplyView):
            if view is None:
                view = op
            else:
                view = compose_views(view, op)
                notes.append("compose_views")
        else:  # barrier ops never reach here
            raise AssertionError(op)

    out: List[Op] = []
    if window is not None:
        if window.t0 == -math.inf and window.t1 == math.inf:
            notes.append("drop_infinite_window")
        else:
            if window.empty and window != EMPTY_WINDOW:
                notes.append("normalize_empty_window")
            out.append(window.normalized())
    if acts is not None:
        # drop only an exact keep-everything filter; a superset contains
        # unknown names and must reach the executor's validation
        if activity_names is not None and set(acts) == set(activity_names):
            notes.append("drop_keep_all_filter")
        else:
            out.append(Activities(acts, relink=False))
    if view is not None:
        out.append(view)
    return out


def canonicalize(
    plan: LogicalPlan, activity_names: Optional[Sequence[str]] = None
) -> Tuple[LogicalPlan, List[str]]:
    """Return (canonical plan, list of applied rewrites)."""
    notes: List[str] = []
    ops: List[Op] = []
    seg: List[Op] = []
    for op in plan.ops:
        if is_barrier(op):
            ops.extend(_canonical_segment(seg, activity_names, notes))
            seg = []
            ops.append(op)
            # after a barrier the vocabulary is unchanged (filters keep the
            # full activity_names list), so the schema stays valid
        else:
            seg.append(op)
    ops.extend(_canonical_segment(seg, activity_names, notes))
    out = LogicalPlan(plan.source, tuple(ops), plan.sink)
    if out.ops != plan.ops:
        notes.append("canonical_order")
    return out, notes
