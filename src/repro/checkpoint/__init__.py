from .checkpointer import CheckpointManager

__all__ = ["CheckpointManager"]
