"""Sharded checkpoints with async writes and elastic resharding.

Layout (atomic: written to ``<dir>/tmp-<step>`` then renamed):

  <dir>/step-<n>/
    manifest.json   — tree structure, shapes, dtypes, step, user metadata
    <flat-key>.npy  — one array per leaf

On restore the arrays are ``device_put`` with the *target* shardings —
which may belong to a different mesh than the one that wrote the
checkpoint.  That is the elastic-scaling path: a 512-chip run can restore
a 256-chip checkpoint and vice versa (leaves are stored unsharded; the
layout cost is paid once at restore).  On a real pod the same manifest
drives per-shard streaming restore; the logical contract is identical.

Async mode snapshots leaves to host memory on the caller's thread (cheap:
device→host copy), then a writer thread persists — checkpointing overlaps
the next training steps (write-behind), keeping saves off the critical
path.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "::"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path
        )
        flat.append((key, leaf))
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_writes: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_writes = async_writes
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._errors: List[BaseException] = []
        self._thread: Optional[threading.Thread] = None
        if async_writes:
            self._thread = threading.Thread(target=self._writer, daemon=True)
            self._thread.start()

    # -- public API ----------------------------------------------------------
    def save(self, step: int, tree, metadata: Optional[Dict] = None) -> None:
        """Snapshot now; persist (possibly) later."""
        flat = [(k, np.asarray(v)) for k, v in _flatten(tree)]
        treedef = jax.tree_util.tree_structure(tree)
        job = (step, flat, str(treedef), metadata or {})
        if self.async_writes:
            self._q.put(job)
        else:
            self._write(job)

    def wait(self) -> None:
        """Block until pending async writes are durable."""
        if self.async_writes:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step-"):
                out.append(int(name.split("-", 1)[1]))
        return sorted(out)

    def restore(
        self,
        step: Optional[int] = None,
        shardings=None,
        template=None,
    ):
        """Load a checkpoint.  ``shardings`` (a matching tree of
        NamedShardings) reshards onto the *current* mesh — elastic restore.
        ``template`` (any matching pytree) restores the tree structure when
        no shardings are given."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        path = os.path.join(self.directory, f"step-{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        ref = shardings if shardings is not None else template
        if ref is None:
            raise ValueError("pass shardings= (elastic) or template=")
        flat_ref = _flatten(ref)
        leaves = []
        for key, ref_leaf in flat_ref:
            arr = np.load(os.path.join(path, _fname(key)))
            if shardings is not None:
                arr = jax.device_put(arr, ref_leaf)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(ref)
        return (
            jax.tree_util.tree_unflatten(treedef, leaves),
            manifest["metadata"],
        )

    # -- internals ---------------------------------------------------------------
    def _write(self, job) -> None:
        step, flat, treedef_str, metadata = job
        tmp = os.path.join(self.directory, f"tmp-{step}")
        final = os.path.join(self.directory, f"step-{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "treedef": treedef_str,
            "metadata": metadata,
            "leaves": {},
        }
        for key, arr in flat:
            manifest["leaves"][key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
            np.save(os.path.join(tmp, _fname(key)), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s}"),
                          ignore_errors=True)

    def _writer(self) -> None:
        while True:
            job = self._q.get()
            try:
                self._write(job)
            except BaseException as e:  # noqa: BLE001 — surface via wait()
                self._errors.append(e)
            finally:
                self._q.task_done()


def _fname(key: str) -> str:
    safe = key.replace(_SEP, "__").replace("/", "_")
    return f"{safe}.npy"
