from .spec import (
    ShardingRules,
    batch_shardings,
    cache_shardings,
    make_rules,
    param_shardings,
)

__all__ = [
    "ShardingRules", "batch_shardings", "cache_shardings", "make_rules",
    "param_shardings",
]
