"""Logical-axis sharding rules → NamedSharding.

Weights carry *logical axes*; a :class:`ShardingRules` maps logical names to
mesh axes.  Key decisions (DESIGN §5):

* Fused projection dims (`qkv` = heads·head_dim, `ffn`, `vocab`, experts'
  ffn) shard over ``model`` — divisible by 16 for every assigned arch
  (head counts alone are not, e.g. starcoder2's 24 or llava's 56).
* Every rule is **divisibility-guarded**: jit in_shardings demand exact
  divisibility, so a dim that doesn't divide (whisper's 51865 vocab,
  kv_heads=8 on a 16-way model axis) falls back to the next-best axis or
  replication, never to an invalid spec.
* ``batch`` shards over (``pod``, ``data``) for train/prefill/decode.
* ``long_500k`` (batch=1) swaps the batch rule for **sequence sharding** of
  the KV cache (context parallelism for single-stream decode).
* KV caches shard kv_heads over ``model`` when divisible (gemma2-27b,
  olmoe), else the cache *sequence* dim over ``model`` (llava, gemma3 …) —
  this is what keeps 32k×128 caches inside 16 GiB/chip.
* Stacked-unit leading dims are never sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = [
    "ShardingRules", "make_rules", "param_shardings", "batch_shardings",
    "cache_shardings", "GraphShardSpec", "shard_of_cases", "graph_mesh",
]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    batch_axes: Tuple[str, ...]  # mesh axes carrying the batch
    model_axis: Optional[str]  # mesh axis carrying tensor parallelism
    seq_axes: Tuple[str, ...] = ()  # cache sequence sharding (long decode)
    # ZeRO/FSDP: params + grads + optimizer state additionally sharded over
    # these axes for training (weights are all-gathered per scanned unit,
    # grads reduce-scattered — the standard GSPMD FSDP pattern).  Without it
    # a 47B model needs ~47 GiB/chip of f32 param+Adam state at TP=16.
    fsdp_axes: Tuple[str, ...] = ()

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.model_axis else 1

    def axes_size(self, axes) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def nd(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -- divisibility-guarded axis pickers ---------------------------------
    def model_if(self, dim: int):
        m = self.model_axis
        return m if (m and dim % self.mesh.shape[m] == 0) else None

    def batch_if(self, dim: int):
        if self.batch_axes and dim % self.axes_size(self.batch_axes) == 0:
            return self.batch_axes
        return None

    def fsdp_if(self, dim: int):
        if self.fsdp_axes and dim % self.axes_size(self.fsdp_axes) == 0:
            return self.fsdp_axes
        return None


def make_rules(mesh: Mesh, shape: ShapeConfig) -> ShardingRules:
    axes = list(mesh.axis_names)
    model_axis = "model" if "model" in axes else None
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    if shape.kind == "decode" and shape.global_batch < dsize:
        # long-context single-stream decode: batch unshardable → shard cache
        # sequence over the data axes instead (context parallelism)
        return ShardingRules(
            mesh, batch_axes=(), model_axis=model_axis, seq_axes=data_axes
        )
    return ShardingRules(
        mesh, batch_axes=data_axes, model_axis=model_axis,
        fsdp_axes=data_axes if shape.kind == "train" else (),
    )


# ---------------------------------------------------------------------------
# Parameter shardings (path-pattern → PartitionSpec)
# ---------------------------------------------------------------------------


def _param_spec(path_keys, shape, r: ShardingRules, moe_ep: bool = False) -> P:
    name = path_keys[-1]
    inside_unit = "units" in path_keys or "enc_units" in path_keys
    u = (None,) if inside_unit else ()  # stacked-unit leading dim
    d = shape[len(u):]  # logical dims past the unit stack

    def mi(i):  # model axis iff divisible
        return r.model_if(d[i])

    def fi(i):  # fsdp axes iff training + divisible
        return r.fsdp_if(d[i])

    if name in ("embed", "lm_head"):
        v = r.model_if(d[0])
        return P(v, fi(1) if v else r.model_if(d[1]))
    if name in ("wq", "wk", "wv"):  # (D, fused)
        return P(*u, fi(0), mi(1))
    if name == "wo":  # (fused, D)
        return P(*u, mi(0), fi(1))
    if name in ("w_up", "w_gate"):
        if "moe" in path_keys:  # (E, D, F)
            if moe_ep and r.model_if(d[0]):
                return P(*u, "model", fi(1), None)  # expert parallel
            return P(*u, None, fi(1), mi(2))
        return P(*u, fi(0), mi(1))  # (D, F)
    if name == "w_down":
        if "moe" in path_keys:  # (E, F, D)
            if moe_ep and r.model_if(d[0]):
                return P(*u, "model", None, fi(2))
            return P(*u, None, mi(1), fi(2))
        return P(*u, mi(0), fi(1))  # (F, D)
    if name == "router":  # (D, E) — small, replicated
        return P(*u, None, None)
    if name == "in_proj":  # mamba (D, proj_out)
        return P(*u, fi(0), mi(1))
    if name == "out_proj":  # mamba (d_inner, D)
        return P(*u, mi(0), fi(1))
    if name == "conv_w":  # (W, C)
        return P(*u, None, mi(1))
    if name in ("conv_b", "norm_w", "A_log", "D", "dt_bias"):
        return P(*u, mi(0))
    # norms and anything else: replicated beyond the unit stack
    return P(*u, *([None] * len(d)))


def param_shardings(r: ShardingRules, params_shape, cfg=None) -> Dict:
    """Tree of NamedShardings matching a params (or abstract params) tree."""
    moe_ep = bool(cfg is not None and getattr(cfg, "moe_expert_parallel", False))

    def one(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        spec = _param_spec(keys, leaf.shape, r, moe_ep=moe_ep)
        assert len(spec) == len(leaf.shape), (keys, spec, leaf.shape)
        return r.nd(spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------


def batch_shardings(r: ShardingRules, batch_shape) -> Dict:
    """tokens/labels (B, S); vision_embeds/frames (B, S', D)."""

    def one(path, leaf):
        spec = [r.batch_if(leaf.shape[0])] + [None] * (len(leaf.shape) - 1)
        return r.nd(P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_shardings(r: ShardingRules, cache_shape) -> Dict:
    """Attention caches (U, B, S, KV, hd); mamba conv (U, B, W, C) and
    state (U, B, H, P, N); cross k/v (U, B, S_enc, KV, hd)."""

    def attn_spec(shape) -> P:
        _, B, S, KV, _ = shape
        b = r.batch_if(B)
        if r.seq_axes:  # long-context mode: context parallelism
            seq = (
                r.seq_axes
                if S % r.axes_size(r.seq_axes) == 0
                else None
            )
            kv = r.model_if(KV)
            if kv is None and seq is not None:
                # fold model into the seq shard when kv can't split
                both = tuple(r.seq_axes) + (r.model_axis,)
                if r.model_axis and S % r.axes_size(both) == 0:
                    seq = both
            return P(None, b, seq, kv if kv else None, None)
        kv = r.model_if(KV)
        if kv is not None:
            return P(None, b, None, kv, None)
        m = r.model_if(S)  # fall back: shard the cache sequence over model
        return P(None, b, m, None, None)

    def one(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = keys[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "cross_k", "cross_v") and nd == 5:
            return r.nd(attn_spec(leaf.shape))
        if name == "conv" and nd == 4:  # (U, B, W, C)
            return r.nd(P(None, r.batch_if(leaf.shape[1]), None,
                          r.model_if(leaf.shape[3])))
        if name == "state" and nd == 5:  # (U, B, H, P, N)
            return r.nd(P(None, r.batch_if(leaf.shape[1]),
                          r.model_if(leaf.shape[2]), None, None))
        return r.nd(P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


# ---------------------------------------------------------------------------
# Graph-tier shard assignment (case-partitioned event-log shards)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphShardSpec:
    """Static description of a case-partitioned log sharding.

    Cases are assigned whole to shards (``assignment="case_mod"`` maps case
    ``c`` to shard ``c % num_shards``), so every directly-follows pair is
    shard-local and the global Ψ is a pure sum of per-shard (A, A) counts on
    the aligned union vocabulary — the psum contract of
    :func:`repro.core.distributed.distributed_dfg`.
    """

    num_shards: int
    assignment: str = "case_mod"

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if self.assignment != "case_mod":
            raise ValueError(f"unknown shard assignment {self.assignment!r}")

    def shard_of(self, case_ids: np.ndarray) -> np.ndarray:
        return shard_of_cases(case_ids, self.num_shards)


def shard_of_cases(case_ids, num_shards: int) -> np.ndarray:
    """Owning shard per case id under the stable ``case % K`` rule.

    Stability across appends is the load-bearing property: new events for an
    existing case always land on the shard that already holds that case, so
    an append touches only the owning shards and every other shard's
    prefix-preserving fingerprint (and therefore its cached graph) survives.
    """
    ids = np.asarray(case_ids, dtype=np.int64)
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    return ids % np.int64(num_shards)


def graph_mesh(num_shards: int) -> Optional[Mesh]:
    """1-D ``("shard",)`` mesh over up to ``num_shards`` local devices, for
    running the shard merge as an on-device psum; ``None`` when only a single
    device is visible (the numpy aligned-sum merge path needs no mesh)."""
    devices = jax.devices()
    n = min(num_shards, len(devices))
    if n <= 1:
        return None
    return Mesh(np.asarray(devices[:n]), ("shard",))
