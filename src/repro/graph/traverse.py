"""Graph-native sinks: topology queries answered from the CSR store.

Everything here consumes the aggregated ``:DF`` CSR (plus node degrees) —
never the event stream — which is exactly what makes the second-and-later
topology query cheap: once :func:`repro.graph.build.build_graph` has
materialized the relation, a k-hop neighborhood is a few ``indptr`` lookups
and a process map is an O(nnz) sort, independent of E.

The columnar execution paths produce **the same results bit for bit** by
routing through the same derivation functions: they count their dense Ψ as
before, sparsify with :func:`~repro.graph.build.csr_from_dense`, and call
:func:`derive_neighborhood` / :func:`derive_process_map` — CSR is uniquely
determined by Ψ, so graph-vs-columnar equivalence reduces to the DFG
equivalence the engine already pins against Algorithm 1.

* :func:`dfg_from_graph` — Algorithm 1 as a store lookup (densify CSR);
* :func:`neighborhood` — k-hop successor/predecessor BFS with the induced
  edge subgraph;
* :func:`path_frequencies` — frequency-weighted walk counts ``(Ψ^ℓ)[a, b]``
  via repeated CSR matvec (never densifying powers);
* :func:`process_map` — ProFIT-style significance filter: top-fraction
  nodes by event frequency, then top-fraction edges among the kept nodes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .build import CSR, EventGraph

__all__ = [
    "Neighborhood",
    "ProcessMap",
    "dfg_from_graph",
    "neighborhood",
    "derive_neighborhood",
    "path_frequencies",
    "process_map",
    "derive_process_map",
]


# ---------------------------------------------------------------------------
# DFG — Algorithm 1 as a lookup
# ---------------------------------------------------------------------------


def dfg_from_graph(g: EventGraph) -> np.ndarray:
    """The Ψ count matrix from the materialized ``:DF`` relation —
    bit-identical to Algorithm 1 on the source (pinned by tests)."""
    return g.psi()


# ---------------------------------------------------------------------------
# k-hop neighborhoods
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Neighborhood:
    """The k-hop ``:DF`` neighborhood of one activity.

    ``activities`` lists the reached nodes in (hop, id) order — the center
    first at hop 0; ``hops`` maps each to its minimal distance; ``edges``
    is the induced subgraph among reached nodes as ``(src, dst, count)``
    triples (deterministic (src, dst) order).
    """

    center: str
    k: int
    direction: str
    activities: List[str]
    hops: Dict[str, int]
    edges: List[Tuple[str, str, int]]


def _frontier_expand(csr: CSR, frontier: np.ndarray) -> np.ndarray:
    """All CSR neighbors of the frontier ids (deduplicated, ascending)."""
    if frontier.shape[0] == 0:
        return frontier
    parts = [
        csr.indices[csr.indptr[a] : csr.indptr[a + 1]] for a in frontier
    ]
    if not parts:
        return np.zeros((0,), dtype=np.int64)
    return np.unique(np.concatenate(parts)).astype(np.int64)


def derive_neighborhood(
    adj: CSR,
    radj: CSR,
    names: Sequence[str],
    center: str,
    k: int = 1,
    direction: str = "out",
) -> Neighborhood:
    if direction not in ("out", "in", "both"):
        raise ValueError(f"direction must be out|in|both, got {direction!r}")
    if center not in names:
        raise ValueError(
            f"unknown activity {center!r}; graph has {len(names)} activities"
        )
    c = list(names).index(center)
    hop_of = {c: 0}
    frontier = np.asarray([c], dtype=np.int64)
    for hop in range(1, int(k) + 1):
        nxt = []
        if direction in ("out", "both"):
            nxt.append(_frontier_expand(adj, frontier))
        if direction in ("in", "both"):
            nxt.append(_frontier_expand(radj, frontier))
        reached = (
            np.unique(np.concatenate(nxt)) if nxt else
            np.zeros((0,), dtype=np.int64)
        )
        fresh = [int(a) for a in reached if int(a) not in hop_of]
        for a in fresh:
            hop_of[a] = hop
        frontier = np.asarray(fresh, dtype=np.int64)
        if frontier.shape[0] == 0:
            break
    # (hop, id) order keeps the result deterministic and center-first
    ordered = sorted(hop_of, key=lambda a: (hop_of[a], a))
    kept = set(ordered)
    edges: List[Tuple[str, str, int]] = []
    for a in ordered:
        cols, cnts = adj.row(a)
        for b, n in zip(cols, cnts):
            if int(b) in kept:
                edges.append((names[a], names[int(b)], int(n)))
    edges.sort(key=lambda e: (e[0], e[1]))
    return Neighborhood(
        center=center,
        k=int(k),
        direction=direction,
        activities=[names[a] for a in ordered],
        hops={names[a]: hop_of[a] for a in ordered},
        edges=edges,
    )


def neighborhood(
    g: EventGraph, center: str, k: int = 1, direction: str = "out"
) -> Neighborhood:
    """k-hop neighborhood straight off the stored CSR — the repeated-query
    fast path (no Ψ recompute, no event scan)."""
    return derive_neighborhood(
        g.adj, g.radj, g.activity_names, center, k, direction
    )


# ---------------------------------------------------------------------------
# Path frequencies
# ---------------------------------------------------------------------------


def path_frequencies(
    g: EventGraph, src: str, dst: str, max_hops: int = 4
) -> np.ndarray:
    """Frequency-weighted walk counts: entry ``ℓ-1`` is ``(Ψ^ℓ)[src, dst]``
    for ℓ = 1..max_hops — "how much flow reaches ``dst`` from ``src`` in
    exactly ℓ directly-follows steps".  Computed as repeated CSR matvecs
    (O(max_hops · nnz)); float64 because walk weights compound."""
    names = g.activity_names
    for x in (src, dst):
        if x not in names:
            raise ValueError(f"unknown activity {x!r}")
    s, d = names.index(src), names.index(dst)
    a = g.num_activities
    rows = np.repeat(
        np.arange(a, dtype=np.int64), np.diff(g.adj.indptr).astype(np.int64)
    )
    v = np.zeros(a, dtype=np.float64)
    v[s] = 1.0
    out = np.zeros(int(max_hops), dtype=np.float64)
    for hop in range(int(max_hops)):
        # v ← v @ Ψ  via the CSR triplets
        v = np.bincount(
            g.adj.indices.astype(np.int64),
            weights=v[rows] * g.adj.counts,
            minlength=a,
        )
        out[hop] = v[d]
    return out


# ---------------------------------------------------------------------------
# Significance-filtered process map (ProFIT-style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ProcessMap:
    """A significance-filtered process map.

    ``activities`` / ``node_counts`` are the kept nodes (original axis
    order) with their event frequencies; ``edges`` the kept ``:DF`` edges
    as ``(src, dst, count)``, most frequent first.  ``dropped_*`` record
    what the filter removed, so a dashboard can say "showing top 20%".
    """

    top: float
    edge_top: float
    activities: List[str]
    node_counts: np.ndarray
    edges: List[Tuple[str, str, int]]
    dropped_activities: int
    dropped_edges: int


def _top_fraction(order: np.ndarray, frac: float) -> np.ndarray:
    """First ``ceil(frac · n)`` entries of a significance-sorted id list."""
    n = order.shape[0]
    if n == 0:
        return order
    keep = min(n, max(1, int(np.ceil(float(frac) * n))))
    return order[:keep]


def derive_process_map(
    adj: CSR,
    node_counts: np.ndarray,
    names: Sequence[str],
    top: float = 0.2,
    edge_top: Optional[float] = None,
) -> ProcessMap:
    """ProFIT-style filter: rank Activity nodes by event frequency and keep
    the top ``top`` fraction (of the *observed* nodes); then rank the
    ``:DF`` edges among kept nodes by count and keep the top ``edge_top``
    (default ``top``) fraction.  Ties break by id, so the map is
    deterministic and identical across execution backends."""
    if not 0.0 < float(top) <= 1.0:
        raise ValueError(f"top must be in (0, 1], got {top}")
    edge_top = float(top if edge_top is None else edge_top)
    if not 0.0 < edge_top <= 1.0:
        raise ValueError(f"edge_top must be in (0, 1], got {edge_top}")
    names = list(names)
    node_counts = np.asarray(node_counts, dtype=np.int64)
    active = np.nonzero(node_counts > 0)[0]
    order = active[np.lexsort((active, -node_counts[active]))]
    kept_ids = np.sort(_top_fraction(order, float(top)))
    kept = set(int(a) for a in kept_ids)

    a = adj.num_nodes
    rows = np.repeat(
        np.arange(a, dtype=np.int64), np.diff(adj.indptr).astype(np.int64)
    )
    in_kept = np.isin(rows, kept_ids) & np.isin(
        adj.indices.astype(np.int64), kept_ids
    )
    esrc = rows[in_kept]
    edst = adj.indices[in_kept].astype(np.int64)
    ecnt = adj.counts[in_kept]
    eorder = np.lexsort((edst, esrc, -ecnt))
    ekeep = _top_fraction(eorder, edge_top)
    edges = [
        (names[int(esrc[i])], names[int(edst[i])], int(ecnt[i]))
        for i in ekeep
    ]
    return ProcessMap(
        top=float(top),
        edge_top=edge_top,
        activities=[names[int(i)] for i in kept_ids],
        node_counts=node_counts[kept_ids],
        edges=edges,
        dropped_activities=int(active.shape[0] - kept_ids.shape[0]),
        dropped_edges=int(esrc.shape[0] - len(edges)),
    )


def process_map(
    g: EventGraph, top: float = 0.2, edge_top: Optional[float] = None
) -> ProcessMap:
    """Significance-filtered map straight off the stored CSR + node degrees
    — only the graph representation makes this a sub-millisecond call."""
    return derive_process_map(
        g.adj, g.node_counts, g.activity_names, top, edge_top
    )
