"""Graph-snapshot persistence + the append-aware in-process graph registry.

A built :class:`~repro.graph.build.EventGraph` is worth keeping: the whole
point of the graph tier is that construction cost is paid once and every
later topology query is a lookup.  Two layers:

* :func:`save_graph` / :func:`load_graph` — a memmap-backed on-disk format
  (one ``.npy`` per array + ``meta.json``); loading maps the CSR arrays
  read-only (``mmap_mode="r"``), so opening a snapshot is O(metadata) and
  pages in only what queries touch;
* :class:`GraphStore` — the in-process registry the query engine consults:
  graphs keyed by **source fingerprint**, with the PR 2 delta machinery
  reused for appends.  A memmap log that grew since the graph was built is
  *proven* append-only (``prefix_digest`` recomputed on the current bytes —
  never assumed from the path hint), and the stored miner state (Ψ +
  open-case tails) resumes over just the suffix: the CSR is extended in
  place of a rebuild, O(suffix + A² + nnz) instead of O(E).

Snapshots carry the same prefix-preserving source fingerprint
(``memmap:<prefix_digest>:<rows>:<A>``), so a snapshot saved before an
append still proves and extends after reload — the round-trip the tests
pin: build → save → load → append → extend ≡ fresh build, array for array.

With a ``spill_dir`` the registry becomes **two-tier** (the sharded graph
tier's shape: a local LRU of materialized shard snapshots over a
shard-remote manifest).  Evicted graphs are spilled to fingerprint-addressed
snapshot directories recorded in ``spill_dir/manifest.json``; a later miss
on that fingerprint *pages the snapshot in* (O(metadata), arrays mmap'd
read-only) instead of rebuilding, and a proven append can extend a paged-in
snapshot — suffix-only, never O(E).  Snapshots are immutable once written
(a fingerprint names exact bytes), so spilling an already-manifested
fingerprint is a no-op and concurrent spills of the same graph are benign.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.lockdep import make_lock
from repro.core.streaming import MemmapLog, MinerState, StreamingDFGMiner
from repro.obs import MetricsRegistry

from .build import CSR, EventGraph, build_graph, csr_from_dense

__all__ = [
    "save_graph",
    "load_graph",
    "extend_graph",
    "GraphStore",
    "GraphStoreStats",
]

_FORMAT_VERSION = 1

_CSR_FIELDS = ("indptr", "indices", "counts")
_TABLE_FIELDS = (
    "event_activity", "event_trace", "event_time",
    "act_indptr", "act_events", "case_indptr",
)


# ---------------------------------------------------------------------------
# On-disk snapshots
# ---------------------------------------------------------------------------


def save_graph(g: EventGraph, path: str) -> None:
    """Persist a graph snapshot (overwrites an existing snapshot at
    ``path`` — e.g. re-saving after an extension)."""
    os.makedirs(path, exist_ok=True)
    arrays: Dict[str, np.ndarray] = {"node_counts": g.node_counts}
    for prefix, csr in (("adj", g.adj), ("radj", g.radj)):
        for f in _CSR_FIELDS:
            arrays[f"{prefix}_{f}"] = getattr(csr, f)
    if g.has_event_tables:
        for f in _TABLE_FIELDS:
            arrays[f] = getattr(g, f)
    if g.miner is not None:
        arrays["miner_psi"] = g.miner.psi
        keys = np.asarray(sorted(g.miner.last_by_case), dtype=np.int64)
        arrays["miner_case"] = keys
        arrays["miner_last"] = np.asarray(
            [g.miner.last_by_case[int(k)] for k in keys], dtype=np.int64
        )
    for name, arr in arrays.items():
        np.save(os.path.join(path, f"{name}.npy"), np.asarray(arr))
    meta = {
        "format": _FORMAT_VERSION,
        "activity_names": g.activity_names,
        "num_events": g.num_events,
        "num_traces": g.num_traces,
        "rows_end": g.rows_end,
        "source_fp": g.source_fp,
        "has_event_tables": g.has_event_tables,
        "has_miner": g.miner is not None,
        "miner_events_seen": (
            g.miner.events_seen if g.miner is not None else None
        ),
    }
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def load_graph(path: str, mmap: bool = True) -> EventGraph:
    """Open a snapshot; with ``mmap`` (default) the arrays stay on disk and
    page in on first touch."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if meta.get("format") != _FORMAT_VERSION:
        raise ValueError(
            f"unsupported graph snapshot format {meta.get('format')!r}"
        )
    mode = "r" if mmap else None

    def arr(name: str) -> np.ndarray:
        return np.load(os.path.join(path, f"{name}.npy"), mmap_mode=mode)

    def csr(prefix: str) -> CSR:
        return CSR(*(arr(f"{prefix}_{f}") for f in _CSR_FIELDS))

    tables = {}
    if meta["has_event_tables"]:
        tables = {f: arr(f) for f in _TABLE_FIELDS}
    miner = None
    if meta["has_miner"]:
        # the miner state is mutated on resume: load a private copy
        keys = np.load(os.path.join(path, "miner_case.npy"))
        last = np.load(os.path.join(path, "miner_last.npy"))
        miner = MinerState(
            psi=np.load(os.path.join(path, "miner_psi.npy")),
            last_by_case={int(k): int(v) for k, v in zip(keys, last)},
            events_seen=int(meta["miner_events_seen"]),
        )
    return EventGraph(
        activity_names=list(meta["activity_names"]),
        num_events=int(meta["num_events"]),
        num_traces=int(meta["num_traces"]),
        node_counts=arr("node_counts"),
        adj=csr("adj"),
        radj=csr("radj"),
        source_fp=meta["source_fp"],
        rows_end=int(meta["rows_end"]),
        miner=miner,
        **tables,
    )


# ---------------------------------------------------------------------------
# Append-aware extension (the delta machinery on the graph tier)
# ---------------------------------------------------------------------------


def _proves_append_only(g: EventGraph, log: MemmapLog) -> bool:
    """True iff ``log`` is a proven append-only extension of the rows the
    graph consumed — same proof the engine's delta plans use."""
    from repro.query.cache import parse_memmap_fingerprint, prefix_digest

    if g.miner is None or g.source_fp is None:
        return False
    old = parse_memmap_fingerprint(g.source_fp)
    if old is None or not 0 < old.num_events < log.num_events:
        return False
    if old.num_activities > log.num_activities:
        return False  # vocabulary shrank: not an append
    return prefix_digest(log, old.num_events) == old.prefix


def extend_graph(
    g: EventGraph,
    log: MemmapLog,
    *,
    memory_budget_events: Optional[int] = None,
    source_fp: Optional[str] = None,
) -> EventGraph:
    """Extend a memmap-sourced graph over the log's appended suffix.

    The caller must have proven the append (see :func:`_proves_append_only`;
    :class:`GraphStore` does).  The stored miner state resumes over rows
    ``[rows_end, num_events)`` — boundary pairs are linked through the
    carried per-case tails — and the CSR / node degrees are updated from
    the new Ψ: O(suffix + A² + nnz), never O(E).  Event tables (when the
    old graph had them and the grown log still fits the budget) are
    re-materialized from the log, identical to a fresh build.
    """
    a = log.num_activities
    miner = StreamingDFGMiner.restore(g.miner, num_activities=a)
    node_counts = np.zeros(a, dtype=np.int64)
    node_counts[: g.node_counts.shape[0]] = g.node_counts
    for acts, cases, times in log.iter_chunks(
        row_range=(g.rows_end, log.num_events)
    ):
        miner.update(acts, cases, times)
        node_counts += np.bincount(acts, minlength=a)
    adj = csr_from_dense(miner.finalize())

    tables: dict = {}
    in_budget = (
        memory_budget_events is None
        or log.num_events <= memory_budget_events
    )
    if g.has_event_tables and in_budget:
        from repro.query.execute import repository_from_memmap

        from .build import _event_tables

        repo = repository_from_memmap(log)
        tables = _event_tables(
            repo.event_activity, repo.event_trace, repo.event_time,
            a, repo.num_traces,
        )
    if source_fp is None:
        from repro.query.cache import fingerprint_memmap

        source_fp = fingerprint_memmap(log)
    return EventGraph(
        activity_names=log.activity_labels(),
        num_events=log.num_events,
        num_traces=log.num_traces,
        node_counts=node_counts,
        adj=adj,
        radj=adj.transpose(),
        source_fp=source_fp,
        rows_end=log.num_events,
        miner=miner.snapshot(),
        **tables,
    )


# ---------------------------------------------------------------------------
# In-process registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphStoreStats:
    """Point-in-time snapshot; the live counters sit in the store's
    :class:`repro.obs.MetricsRegistry` (shared with the engine's when the
    engine constructed the store), so increments are lock-protected —
    builds/extends used to bump bare ints outside the store lock."""

    builds: int = 0
    extends: int = 0  # append-proven CSR extensions (suffix-only scans)
    hits: int = 0
    spills: int = 0  # LRU evictions persisted to the disk tier
    pageins: int = 0  # misses served from the disk tier instead of a build


class GraphStore:
    """LRU registry of built graphs keyed by source fingerprint.

    ``graph_for`` is the engine's single entry point: a fingerprint hit is
    O(1); a memmap source whose bytes grew since the last build is extended
    via the prefix-digest proof (suffix-only scan); anything else builds
    fresh.  Thread-safe; builds serialize on the store lock so concurrent
    tenants cannot duplicate the construction work.

    With ``spill_dir`` the LRU sits over a disk tier: evictions spill to
    fingerprint-addressed snapshots listed in a manifest, and misses check
    the manifest before building (see module docstring).  ``max_graphs``
    then bounds *materialized* graphs only — the working set a host keeps
    hot — while the manifest can hold every shard of a log far larger than
    one host's memory.
    """

    def __init__(
        self,
        *,
        max_graphs: int = 8,
        memory_budget_events: Optional[int] = None,
        backend: str = "auto",
        metrics: Optional[MetricsRegistry] = None,
        spill_dir: Optional[str] = None,
    ):
        self.max_graphs = max_graphs
        self.memory_budget_events = memory_budget_events
        self.backend = backend
        self.spill_dir = spill_dir
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_builds = self.metrics.counter("graph_store_builds_total")
        self._c_extends = self.metrics.counter("graph_store_extends_total")
        self._c_hits = self.metrics.counter("graph_store_hits_total")
        self._c_spills = self.metrics.counter("graph_store_spills_total")
        self._c_pageins = self.metrics.counter("graph_store_pageins_total")
        self._graphs: "OrderedDict[str, EventGraph]" = OrderedDict()
        self._hints: Dict[str, str] = {}  # memmap realpath → newest fp
        self._disk: Dict[str, str] = {}  # fp → snapshot dir (guarded by _lock)
        self._lock = make_lock("GraphStore")
        # per-fingerprint build gates: concurrent requests for the same
        # graph wait for the first builder instead of duplicating the O(E)
        # work — and the registry lock is never held across a build, so
        # O(1) hits on other sources proceed during one
        self._building: Dict[str, threading.Event] = {}
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
            manifest = os.path.join(spill_dir, "manifest.json")
            if os.path.exists(manifest):
                with open(manifest) as f:
                    self._disk = dict(json.load(f).get("graphs", {}))

    @property
    def stats(self) -> GraphStoreStats:
        return GraphStoreStats(
            builds=self._c_builds.value,
            extends=self._c_extends.value,
            hits=self._c_hits.value,
            spills=self._c_spills.value,
            pageins=self._c_pageins.value,
        )

    def __len__(self) -> int:
        with self._lock:
            return len(self._graphs)

    def peek(self, fp: str) -> bool:
        """Availability probe (no LRU bump, no stats)."""
        with self._lock:
            return fp in self._graphs

    def has_extendable(self, source) -> bool:
        """True when a graph built from an earlier state of this memmap
        path is registered (either tier) — an append-proof candidate, so
        serving the grown log from the graph tier costs only a suffix scan
        (plus an O(metadata) page-in when the candidate was spilled)."""
        hint = self._hint(source)
        with self._lock:
            fp = self._hints.get(hint) if hint is not None else None
            return fp is not None and (fp in self._graphs or fp in self._disk)

    def get(self, fp: str) -> Optional[EventGraph]:
        with self._lock:
            g = self._graphs.get(fp)
            if g is not None:
                self._graphs.move_to_end(fp)
                self._c_hits.inc()
            return g

    def _register_locked(
        self,
        fp: str,
        g: EventGraph,
        hint: Optional[str],
        replaced_fp: Optional[str] = None,
    ) -> List[Tuple[str, EventGraph]]:
        """Insert + LRU-evict + hint bookkeeping; caller holds the lock.
        ``replaced_fp`` drops the superseded generation an extension grew
        out of — it can never be queried again (its fingerprint names the
        pre-append bytes) and would otherwise pin its event tables until
        LRU eviction.  Returns the LRU-evicted graphs so the caller can
        spill them to the disk tier *outside* the lock (an O(nnz) snapshot
        write must not block O(1) hits)."""
        if replaced_fp is not None and replaced_fp != fp:
            self._graphs.pop(replaced_fp, None)
            if self._disk.pop(replaced_fp, None) is not None:
                # the pre-append bytes no longer exist anywhere, so the
                # superseded snapshot can never satisfy a query; unmanifest
                # it (files stay — another handle may still mmap them)
                self._write_manifest_locked()
        self._graphs[fp] = g
        self._graphs.move_to_end(fp)
        if hint is not None:
            self._hints[hint] = fp
        evicted: List[Tuple[str, EventGraph]] = []
        while len(self._graphs) > self.max_graphs:
            dead_fp, dead_g = self._graphs.popitem(last=False)
            evicted.append((dead_fp, dead_g))
            if self.spill_dir is None:
                # no disk tier: the fingerprint becomes unreachable, so any
                # hint naming it is dead too.  With a disk tier the hint
                # stays — the spilled snapshot still extends after page-in.
                for h, hfp in list(self._hints.items()):
                    if hfp == dead_fp:
                        del self._hints[h]
        return evicted

    def put(self, fp: str, g: EventGraph, hint: Optional[str] = None) -> None:
        with self._lock:
            evicted = self._register_locked(fp, g, hint)
        self._spill(evicted)

    # -- disk tier ----------------------------------------------------------
    def _write_manifest_locked(self) -> None:
        tmp = os.path.join(self.spill_dir, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump({"format": _FORMAT_VERSION, "graphs": self._disk}, f)
        os.replace(tmp, os.path.join(self.spill_dir, "manifest.json"))

    def _spill(self, evicted: List[Tuple[str, EventGraph]]) -> None:
        """Persist evicted graphs to fingerprint-addressed snapshots.

        Runs outside the registry lock.  A fingerprint already manifested is
        skipped — it names exact source bytes, so the existing snapshot is
        already the right one (which also makes a concurrent double-spill of
        the same fingerprint an idempotent overwrite)."""
        if self.spill_dir is None or not evicted:
            return
        for fp, g in evicted:
            with self._lock:
                if fp in self._disk:
                    continue
            d = os.path.join(
                self.spill_dir, hashlib.sha256(fp.encode()).hexdigest()[:24]
            )
            save_graph(g, d)
            self._c_spills.inc()
            with self._lock:
                self._disk[fp] = d
                self._write_manifest_locked()

    def _page_in(self, fp: str) -> Optional[EventGraph]:
        """Load a manifested snapshot for ``fp`` (caller is the elected
        builder for this fingerprint, so nobody else is loading it)."""
        with self._lock:
            d = self._disk.get(fp)
        if d is None:
            return None
        try:
            g = load_graph(d)
        except (OSError, ValueError):
            # stale manifest entry (snapshot dir removed / unreadable):
            # drop it and fall through to a rebuild
            with self._lock:
                if self._disk.get(fp) == d:
                    del self._disk[fp]
                    self._write_manifest_locked()
            return None
        self._c_pageins.inc()
        return g

    @staticmethod
    def _hint(source) -> Optional[str]:
        if isinstance(source, MemmapLog):
            return os.path.realpath(source.path)
        return None

    def graph_for(self, source, fp: str, on_rows=None) -> EventGraph:
        """The graph of ``source`` (whose fingerprint is ``fp``): registry
        hit, disk-tier page-in, proven append extension, or fresh build —
        in that order.

        Construction runs *outside* the registry lock (an O(E) build must
        not block O(1) hits on other sources); a per-fingerprint gate makes
        concurrent requests for the same graph wait for the first builder.

        ``on_rows`` (when given) is called with the number of source rows
        this call actually scanned: 0 for hits and page-ins, the appended
        suffix length for extensions, the full row count for builds — the
        engine's ``rows_scanned`` accounting, which is how the tests prove
        that an append rescans only the owning shards.
        """
        while True:
            g = self.get(fp)
            if g is not None:
                return g
            hint = self._hint(source)
            with self._lock:
                g = self._graphs.get(fp)  # re-check: lost a build race
                if g is not None:
                    self._graphs.move_to_end(fp)
                    self._c_hits.inc()
                    return g
                gate = self._building.get(fp)
                if gate is None:
                    gate = threading.Event()
                    self._building[fp] = gate
                    old_fp_hint = (
                        self._hints.get(hint) if hint is not None else None
                    )
                    old = (
                        self._graphs.get(old_fp_hint)
                        if old_fp_hint is not None
                        else None
                    )
                    break  # we are the builder
            # someone else is building this fingerprint: wait and retry
            # (on builder failure the gate is set with nothing registered,
            # and the retry loop elects a new builder)
            gate.wait()

        old_fp = None
        try:
            g = self._page_in(fp)
            if g is None:
                if old is None and old_fp_hint is not None \
                        and old_fp_hint != fp:
                    # the extension candidate was LRU-evicted to the disk
                    # tier: page it in — a suffix scan over a loaded
                    # snapshot still beats an O(E) rebuild
                    old = self._page_in(old_fp_hint)
                if old is not None and isinstance(source, MemmapLog):
                    if _proves_append_only(old, source):
                        suffix = source.num_events - old.rows_end
                        g = extend_graph(
                            old, source,
                            memory_budget_events=self.memory_budget_events,
                            source_fp=fp,
                        )
                        old_fp = old.source_fp
                        self._c_extends.inc()
                        if on_rows is not None:
                            on_rows(suffix)
                    else:
                        with self._lock:
                            self._hints.pop(hint, None)
                if g is None:
                    g = build_graph(
                        source,
                        backend=self.backend,
                        memory_budget_events=self.memory_budget_events,
                        source_fp=fp,
                    )
                    self._c_builds.inc()
                    if on_rows is not None:
                        on_rows(int(source.num_events))
            with self._lock:
                evicted = self._register_locked(
                    fp, g, hint, replaced_fp=old_fp
                )
            self._spill(evicted)
            return g
        finally:
            with self._lock:
                self._building.pop(fp, None)
            gate.set()
