"""Case-partitioned sharded event logs — the horizontal-scaling graph tier.

The paper's scaling story (arXiv:2007.09352 §6, and the speed study it
leans on, arXiv:1701.00072) is that a *partitioned* graph database computes
the DFG where each partition lives and merges cheap per-partition counts.
We reproduce that shape host-side: a :class:`ShardedLog` is K independent
:class:`~repro.core.streaming.MemmapLog` shards under one directory, with
cases assigned **whole** to shards by the stable ``case % K`` rule
(:func:`repro.sharding.spec.shard_of_cases`).

Because a case never spans shards, every directly-follows pair is
shard-local: the global Ψ is a *pure sum* of the per-shard (A, A) count
matrices on the aligned union vocabulary — exactly the psum contract of
:func:`repro.core.distributed.distributed_dfg`, and the reason the
``sharded-graph`` backend can merge per-shard ``EventGraph`` snapshots
without any cross-shard reconciliation.

Stability of ``case % K`` across appends is the delta-resume property: new
events for an existing case always land on the shard already holding it, so
an append touches only the owning shards — every other shard keeps its
prefix-preserving fingerprint and its cached CSR snapshot.

Empty residue classes own no events and would need zero-length memmaps
(which ``np.memmap`` rejects), so they simply have no shard directory; the
manifest records which residues are present and :meth:`ShardedLog.append`
creates missing shards on demand when a new case hashes into one.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional, Tuple

import numpy as np

from repro.core.streaming import MemmapLog
from repro.sharding.spec import GraphShardSpec, shard_of_cases

__all__ = [
    "ShardedLog",
    "partition_memmap_log",
    "open_sharded_log",
    "sharded_log_name",
]

_MANIFEST = "manifest.json"
_FORMAT_VERSION = 1


def _shard_dirname(k: int) -> str:
    return f"shard{k:03d}"


def sharded_log_name(log: "ShardedLog") -> str:
    """Provenance name of a sharded source — same rule as
    :func:`repro.core.streaming.memmap_log_name`: the final path component."""
    base = os.path.basename(os.path.normpath(log.path))
    return base or "sharded"


@dataclasses.dataclass
class ShardedLog:
    """K case-partitioned memmap shards + a manifest, presented as one log.

    ``shards[k]`` is the :class:`MemmapLog` owning residue class ``k``, or
    ``None`` when no case with ``case % K == k`` exists yet.  Each shard is
    a plain memmap log, so the whole single-log toolchain — ``build_graph``,
    prefix fingerprints, ``GraphStore`` extension — applies per shard
    unchanged.
    """

    path: str
    spec: GraphShardSpec
    shards: Tuple[Optional[MemmapLog], ...]

    # -- shape --------------------------------------------------------------
    @property
    def num_shards(self) -> int:
        return self.spec.num_shards

    @property
    def num_events(self) -> int:
        return sum(s.num_events for _, s in self.present_shards())

    @property
    def num_activities(self) -> int:
        """Union vocabulary size: appends may grow one shard's vocabulary
        ahead of the others, so the union is the max (shard vocabularies are
        always prefixes of the union under the shared ``act_%03d`` rule)."""
        return max(
            (s.num_activities for _, s in self.present_shards()), default=0
        )

    @property
    def num_traces(self) -> int:
        return max((s.num_traces for _, s in self.present_shards()), default=0)

    def activity_labels(self) -> list:
        return [f"act_{i:03d}" for i in range(self.num_activities)]

    def present_shards(self) -> List[Tuple[int, MemmapLog]]:
        return [(k, s) for k, s in enumerate(self.shards) if s is not None]

    def owning_shards(self, case_ids) -> np.ndarray:
        """Sorted unique shard indices owning the given case ids."""
        return np.unique(self.spec.shard_of(np.asarray(case_ids)))

    # -- io -----------------------------------------------------------------
    @staticmethod
    def open(path: str) -> "ShardedLog":
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        if manifest.get("format") != _FORMAT_VERSION:
            raise ValueError(
                f"unsupported sharded-log format {manifest.get('format')!r}"
            )
        spec = GraphShardSpec(
            num_shards=manifest["num_shards"],
            assignment=manifest.get("assignment", "case_mod"),
        )
        shards: List[Optional[MemmapLog]] = [None] * spec.num_shards
        for key, dirname in manifest["shards"].items():
            shards[int(key)] = MemmapLog.open(os.path.join(path, dirname))
        return ShardedLog(path=path, spec=spec, shards=tuple(shards))

    def _write_manifest(self) -> None:
        manifest = {
            "format": _FORMAT_VERSION,
            "num_shards": self.spec.num_shards,
            "assignment": self.spec.assignment,
            "shards": {
                str(k): _shard_dirname(k) for k, _ in self.present_shards()
            },
        }
        tmp = os.path.join(self.path, _MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(self.path, _MANIFEST))

    # -- growing ------------------------------------------------------------
    def append(
        self, activity: np.ndarray, case: np.ndarray, time: np.ndarray
    ) -> "ShardedLog":
        """Route one time-ordered batch to its owning shards and return a
        fresh handle.  Only the owning shards' files (and fingerprints)
        change; a residue class seen for the first time gets a new shard
        directory.  Row order within each shard is the batch's own order, so
        per-shard streams stay time-ordered."""
        activity = np.ascontiguousarray(activity, dtype=np.int32)
        case = np.ascontiguousarray(case, dtype=np.int32)
        time = np.ascontiguousarray(time, dtype=np.float64)
        if activity.shape[0] == 0:
            return ShardedLog.open(self.path)
        owners = self.spec.shard_of(case)
        new_shards = list(self.shards)
        chunk_rows = max(
            (s.chunk_rows for _, s in self.present_shards()), default=1 << 20
        )
        grew_manifest = False
        for k in np.unique(owners):
            k = int(k)
            m = owners == k
            a, c, t = activity[m], case[m], time[m]
            shard = new_shards[k]
            if shard is None:
                w = MemmapLog.create(
                    os.path.join(self.path, _shard_dirname(k)),
                    num_events=int(a.shape[0]),
                    num_activities=max(
                        self.num_activities, int(a.max()) + 1
                    ),
                    num_traces=max(self.num_traces, int(c.max()) + 1),
                    chunk_rows=chunk_rows,
                )
                w.append(a, c, t)
                new_shards[k] = w.close()
                grew_manifest = True
            else:
                new_shards[k] = shard.append(a, c, t)
        grown = ShardedLog(
            path=self.path, spec=self.spec, shards=tuple(new_shards)
        )
        if grew_manifest:
            grown._write_manifest()
        return grown


def open_sharded_log(path: str) -> ShardedLog:
    return ShardedLog.open(path)


def partition_memmap_log(
    log: MemmapLog,
    num_shards: int,
    out_dir: str,
    *,
    chunk_rows: Optional[int] = None,
) -> ShardedLog:
    """Partition a memmap log case-wise into ``num_shards`` shards.

    Two streaming passes with O(chunk) working memory — the source log is
    never materialized, so a log larger than the single-host budget can be
    sharded on the host that holds it: pass 1 sizes each shard with
    ``bincount(case % K)``; pass 2 routes rows.  Relative event order is
    preserved within each shard (each shard is a subsequence of the
    time-ordered stream, hence itself time-ordered).
    """
    spec = GraphShardSpec(num_shards=num_shards)
    os.makedirs(out_dir, exist_ok=True)
    if os.path.exists(os.path.join(out_dir, _MANIFEST)):
        raise FileExistsError(
            f"{out_dir} already holds a sharded log; refusing to overwrite"
        )
    cr = chunk_rows or log.chunk_rows

    counts = np.zeros(num_shards, dtype=np.int64)
    for _, c, _ in log.iter_chunks():
        counts += np.bincount(
            shard_of_cases(c, num_shards), minlength=num_shards
        )

    writers = {
        k: MemmapLog.create(
            os.path.join(out_dir, _shard_dirname(k)),
            num_events=int(counts[k]),
            num_activities=log.num_activities,
            num_traces=log.num_traces,
            chunk_rows=cr,
        )
        for k in range(num_shards)
        if counts[k]
    }
    for a, c, t in log.iter_chunks():
        owners = shard_of_cases(c, num_shards)
        for k in np.unique(owners):
            k = int(k)
            if k in writers:
                m = owners == k
                writers[k].append(a[m], c[m], t[m])

    shards: List[Optional[MemmapLog]] = [None] * num_shards
    for k, w in writers.items():
        shards[k] = w.close()
    sharded = ShardedLog(path=out_dir, spec=spec, shards=tuple(shards))
    sharded._write_manifest()
    return sharded
