"""``repro.graph`` — the event-knowledge-graph tier.

The paper's storage thesis made concrete: the event log *lives as a graph*
(Event / Case / Activity nodes; ``:DF``, ``:BELONGS_TO``, ``:OF_TYPE``
edges) held as CSR adjacency in arrays, so DFG / neighborhood /
process-map queries are store lookups instead of per-query scans.

    from repro.graph import build_graph, neighborhood, process_map

    g = build_graph(repo)               # or a MemmapLog (streams; CSR out)
    g.psi()                             # Algorithm 1, as a lookup
    neighborhood(g, "a3", k=2)          # k-hop :DF successors
    process_map(g, top=0.2)             # ProFIT-style significance filter

The query engine exposes the same store as the ``graph`` physical backend
(``Q.log(...).process_map()``, ``.neighborhood(act, k)``, or
``.dfg(backend="graph")``); :class:`~repro.graph.store.GraphStore` keeps
built graphs keyed by source fingerprint and extends them in place over
proven append-only suffixes.

The **sharded graph tier** (:mod:`repro.graph.shard`) scales the same
store case-wise: :func:`partition_memmap_log` splits a memmap log into K
case-partitioned shards (``case % K`` — cases never span shards), each an
independently fingerprinted CSR snapshot, and the engine's
``sharded-graph`` backend merges per-shard Ψ with a pure aligned sum.
"""

from .build import (
    CSR,
    EventGraph,
    WindowIndex,
    build_graph,
    build_window_index,
    csr_from_dense,
    dense_from_csr,
)
from .shard import (
    ShardedLog,
    open_sharded_log,
    partition_memmap_log,
    sharded_log_name,
)
from .store import (
    GraphStore,
    GraphStoreStats,
    extend_graph,
    load_graph,
    save_graph,
)
from .traverse import (
    Neighborhood,
    ProcessMap,
    derive_neighborhood,
    derive_process_map,
    dfg_from_graph,
    neighborhood,
    path_frequencies,
    process_map,
)

__all__ = [
    "CSR", "EventGraph", "build_graph", "csr_from_dense", "dense_from_csr",
    "WindowIndex", "build_window_index",
    "GraphStore", "GraphStoreStats", "save_graph", "load_graph",
    "extend_graph",
    "Neighborhood", "ProcessMap", "dfg_from_graph", "neighborhood",
    "derive_neighborhood", "path_frequencies", "process_map",
    "derive_process_map",
    "ShardedLog", "open_sharded_log", "partition_memmap_log",
    "sharded_log_name",
]
