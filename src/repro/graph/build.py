"""Event-knowledge-graph construction — the paper's store, materialized.

The paper's central claim is that the event log should *live as a graph*
(Event / Case / Activity nodes with ``:DF``, ``:BELONGS_TO``, ``:OF_TYPE``
edges) so topology queries run inside the store instead of being re-derived
from flat arrays on every request.  :class:`EventGraph` is that store for
this codebase: a property graph held as **CSR adjacency in numpy/JAX
arrays**, built once per source and then answering DFG / neighborhood /
process-map queries as index lookups ("Native Directly Follows Operator",
Syamsiyah et al.; graph-vs-relational, Joishi & Sureka).

Two tiers, mirroring the columnar store:

* **full graph** — the three Event-node property columns in canonical
  (case-contiguous, time-sorted) order plus the ``:OF_TYPE`` (activity →
  events) and ``:BELONGS_TO`` (case → events) CSR indexes.  Event-level
  ``:DF`` edges stay implicit in the canonical order (event ``i`` →
  ``i+1`` within a case), exactly like :class:`EventRepository`;
* **topology-only graph** — for out-of-core memmap sources the event tables
  are skipped and only the aggregated activity-level ``:DF`` CSR (forward +
  reverse) plus node degrees are kept: O(A² + nnz) memory independent of E.

Aggregation runs as segment-sort / segment-sum: pair keys ``src·A + dst``
are sorted and run-length encoded (sparse regime), or counted densely
through the existing DFG backends (scatter / one-hot / Pallas MXU kernel —
"Pallas where it pays") and then sparsified.  Node degrees route through
:mod:`repro.kernels.segment_count` on TPU and ``np.bincount`` on CPU.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.repository import EventRepository
from repro.core.streaming import MemmapLog, MinerState, StreamingDFGMiner

__all__ = ["EventGraph", "CSR", "build_graph", "csr_from_dense", "dense_from_csr"]


#: above this many dense Ψ cells, aggregation goes through the sorted-key
#: (segment-sort / segment-sum) path instead of densify-then-sparsify
_DENSE_PSI_CELLS = 1 << 24


@dataclasses.dataclass
class CSR:
    """One adjacency direction of the aggregated ``:DF`` multigraph:
    ``indices[indptr[a]:indptr[a+1]]`` are the neighbor activity ids of
    ``a`` (ascending), ``counts`` the multiplicity (Ψ entries)."""

    indptr: np.ndarray  # (A+1,) int64
    indices: np.ndarray  # (nnz,) int32
    counts: np.ndarray  # (nnz,) int64

    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row(self, a: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[a]), int(self.indptr[a + 1])
        return self.indices[lo:hi], self.counts[lo:hi]

    def transpose(self) -> "CSR":
        """Reverse adjacency (CSC of the same matrix, as CSR)."""
        a = self.num_nodes
        rows = np.repeat(
            np.arange(a, dtype=np.int32), np.diff(self.indptr).astype(np.int64)
        )
        order = np.lexsort((rows, self.indices))
        indptr = np.zeros(a + 1, dtype=np.int64)
        np.cumsum(np.bincount(self.indices, minlength=a), out=indptr[1:])
        return CSR(
            indptr=indptr,
            indices=rows[order].astype(np.int32),
            counts=self.counts[order].astype(np.int64),
        )


def csr_from_dense(psi: np.ndarray) -> CSR:
    """Sparsify a dense Ψ count matrix (row-major ⇒ ascending indices)."""
    rows, cols = np.nonzero(psi)
    indptr = np.zeros(psi.shape[0] + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=psi.shape[0]), out=indptr[1:])
    return CSR(
        indptr=indptr,
        indices=cols.astype(np.int32),
        counts=psi[rows, cols].astype(np.int64),
    )


def dense_from_csr(csr: CSR) -> np.ndarray:
    """Densify back to the (A, A) Ψ matrix — bit-identical to the matrix the
    CSR was aggregated from (counts are exact int64)."""
    a = csr.num_nodes
    psi = np.zeros((a, a), dtype=np.int64)
    rows = np.repeat(np.arange(a), np.diff(csr.indptr).astype(np.int64))
    psi[rows, csr.indices] = csr.counts
    return psi


@dataclasses.dataclass(frozen=True)
class WindowIndex:
    """Time-sorted index over a full graph's event tables.

    A windowed query against the canonical (trace-major) tables costs
    O(E) in masking passes however narrow the window.  This index sorts
    the *event* side by time and the *pair* side by source-endpoint time
    once, so a window [t0, t1) resolves to two binary searches plus work
    proportional to the rows actually inside the window — the resident
    shard graphs of the sharded tier answer repeated dashboard windows
    without rescanning their whole tables.

    Correctness leans on canonical order being time-nondecreasing
    *within* each trace (so ``t_dst >= t_src`` for every :DF pair, making
    ``t_dst >= t0`` implied and ``t_src < t1`` part of the sorted range).
    The builder verifies that invariant and callers must fall back to the
    masked path when :func:`build_window_index` returns None.
    """

    num_events: int  # staleness check: extends grow the tables
    etimes: np.ndarray  # (E,) float64, sorted
    eacts: np.ndarray  # (E,) int32, activity ids in time order
    pt_src: np.ndarray  # (P,) float64 source-endpoint times, sorted
    pt_dst: np.ndarray  # (P,) float64 dst-endpoint times, pair order
    psrc: np.ndarray  # (P,) int32
    pdst: np.ndarray  # (P,) int32

    def counts(self, t0: float, t1: float, a: int) -> np.ndarray:
        """Per-activity event counts under [t0, t1)."""
        lo, hi = np.searchsorted(self.etimes, (t0, t1))
        return np.bincount(self.eacts[lo:hi], minlength=a).astype(np.int64)

    def psi(self, t0: float, t1: float, a: int) -> np.ndarray:
        """Ψ under [t0, t1) — bit-identical to the pair-endpoint mask over
        the full tables."""
        from repro.core.dfg import dfg_numpy

        plo, phi = np.searchsorted(self.pt_src, (t0, t1))
        valid = self.pt_dst[plo:phi] < t1
        return dfg_numpy(self.psrc[plo:phi], self.pdst[plo:phi], valid, a)

    def query(self, t0: float, t1: float, a: int):
        """(Ψ, node counts) under [t0, t1)."""
        return self.psi(t0, t1, a), self.counts(t0, t1, a)


def build_window_index(
    event_activity: np.ndarray,
    event_trace: np.ndarray,
    event_time: np.ndarray,
) -> Optional[WindowIndex]:
    """Build a :class:`WindowIndex`, or None when the tables violate the
    within-trace time order the O(window) query plan depends on."""
    acts = np.asarray(event_activity)
    traces = np.asarray(event_trace)
    times = np.asarray(event_time)
    n = acts.shape[0]
    eorder = np.argsort(times, kind="stable")
    if n < 2:
        empty_f = np.zeros((0,), dtype=np.float64)
        empty_i = np.zeros((0,), dtype=np.int32)
        return WindowIndex(
            num_events=n,
            etimes=np.ascontiguousarray(times[eorder]),
            eacts=np.ascontiguousarray(acts[eorder], dtype=np.int32),
            pt_src=empty_f, pt_dst=empty_f, psrc=empty_i, pdst=empty_i,
        )
    pair = np.flatnonzero(traces[:-1] == traces[1:])
    t_src, t_dst = times[pair], times[pair + 1]
    if not bool(np.all(t_dst >= t_src)):
        return None
    porder = np.argsort(t_src, kind="stable")
    return WindowIndex(
        num_events=n,
        etimes=np.ascontiguousarray(times[eorder]),
        eacts=np.ascontiguousarray(acts[eorder], dtype=np.int32),
        pt_src=np.ascontiguousarray(t_src[porder]),
        pt_dst=np.ascontiguousarray(t_dst[porder]),
        psrc=np.ascontiguousarray(acts[pair][porder], dtype=np.int32),
        pdst=np.ascontiguousarray(acts[pair + 1][porder], dtype=np.int32),
    )


@dataclasses.dataclass
class EventGraph:
    """In-process event-knowledge graph (see module docstring).

    ``adj`` / ``radj`` are the aggregated activity-level ``:DF`` relation
    (forward / reverse CSR) — the store's first-class topology.
    ``node_counts[a]`` is the ``:OF_TYPE`` in-degree of Activity node ``a``
    (events executing it), the process-map node significance.

    ``miner`` (memmap-sourced graphs) carries the resumable streaming state
    (Ψ + open-case tails) that lets :mod:`repro.graph.store` extend the CSR
    over an appended suffix instead of rebuilding — the PR 2 delta
    machinery applied to the graph tier.
    """

    activity_names: List[str]
    num_events: int
    num_traces: int
    node_counts: np.ndarray  # (A,) int64
    adj: CSR
    radj: CSR
    # -- full-graph tier (None ⇒ topology-only) -----------------------------
    event_activity: Optional[np.ndarray] = None  # (E,) int32, canonical order
    event_trace: Optional[np.ndarray] = None  # (E,) int32
    event_time: Optional[np.ndarray] = None  # (E,) float64
    act_indptr: Optional[np.ndarray] = None  # (A+1,) :OF_TYPE CSR
    act_events: Optional[np.ndarray] = None  # (E,) event ids by activity
    case_indptr: Optional[np.ndarray] = None  # (T+1,) :BELONGS_TO CSR
    # -- provenance / append machinery --------------------------------------
    source_fp: Optional[str] = None  # fingerprint of the source at build time
    rows_end: int = 0  # memmap rows consumed (0 for repositories)
    miner: Optional[MinerState] = None  # memmap-sourced: resumable Ψ state
    # lazily built time index for O(window) windowed queries; False marks a
    # graph whose tables can't support it (non-monotone trace times)
    _window_index: object = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def num_activities(self) -> int:
        return len(self.activity_names)

    @property
    def num_df_edges(self) -> int:
        """Total event-level ``:DF`` relations (Σ of the aggregated counts)."""
        return int(self.adj.counts.sum())

    @property
    def has_event_tables(self) -> bool:
        return self.event_activity is not None

    def psi(self) -> np.ndarray:
        """The dense Ψ count matrix — Algorithm 1's output, from the store."""
        return dense_from_csr(self.adj)

    def events_of_activity(self, a: int) -> np.ndarray:
        """``•a`` as a CSR lookup (full graphs only)."""
        if self.act_indptr is None:
            raise ValueError("topology-only graph has no event tables")
        lo, hi = int(self.act_indptr[a]), int(self.act_indptr[a + 1])
        return self.act_events[lo:hi]

    def events_of_case(self, t: int) -> Tuple[int, int]:
        """The ``:BELONGS_TO`` row of case ``t`` as a row range (events are
        case-contiguous in canonical order)."""
        if self.case_indptr is None:
            raise ValueError("topology-only graph has no event tables")
        return int(self.case_indptr[t]), int(self.case_indptr[t + 1])

    def window_index(self) -> Optional[WindowIndex]:
        """The lazily built :class:`WindowIndex` over this graph's event
        tables (None when unsupported: topology-only graphs, or tables
        whose within-trace times are not sorted).  Appends invalidate via
        the row count — an extend grows the tables, so the stale index is
        rebuilt on the next windowed query."""
        if self.event_time is None:
            return None
        idx = self._window_index
        if isinstance(idx, WindowIndex) and idx.num_events == self.num_events:
            return idx
        if idx == ("unsupported", self.num_events):
            return None
        idx = build_window_index(
            self.event_activity, self.event_trace, self.event_time
        )
        self._window_index = (
            idx if idx is not None else ("unsupported", self.num_events)
        )
        return idx


# ---------------------------------------------------------------------------
# Aggregation primitives (segment-sort / segment-sum)
# ---------------------------------------------------------------------------


def _aggregate_pairs_sparse(
    src: np.ndarray, dst: np.ndarray, valid: np.ndarray, a: int
) -> CSR:
    """Sort-based aggregation for graphs whose dense Ψ would not fit:
    segment-sort the pair keys, segment-sum the run lengths."""
    keys = src[valid].astype(np.int64) * a + dst[valid].astype(np.int64)
    keys.sort(kind="stable")
    if keys.shape[0] == 0:
        return CSR(
            indptr=np.zeros(a + 1, dtype=np.int64),
            indices=np.zeros((0,), dtype=np.int32),
            counts=np.zeros((0,), dtype=np.int64),
        )
    boundary = np.empty(keys.shape[0], dtype=bool)
    boundary[0] = True
    np.not_equal(keys[1:], keys[:-1], out=boundary[1:])
    uniq = keys[boundary]
    starts = np.nonzero(boundary)[0]
    counts = np.diff(np.append(starts, keys.shape[0])).astype(np.int64)
    rows = (uniq // a).astype(np.int64)
    indptr = np.zeros(a + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=a), out=indptr[1:])
    return CSR(
        indptr=indptr,
        indices=(uniq % a).astype(np.int32),
        counts=counts,
    )


def _aggregate_pairs(
    src: np.ndarray,
    dst: np.ndarray,
    valid: np.ndarray,
    a: int,
    backend: str = "auto",
) -> Tuple[CSR, np.ndarray]:
    """(forward CSR, dense Ψ or None) from directly-follows pair columns.

    Dense regime counts through the existing DFG backends (numpy scatter,
    jnp scatter, one-hot MXU, Pallas kernel) and sparsifies; the sparse
    regime segment-sorts the keys directly.
    """
    if a * a > _DENSE_PSI_CELLS:
        return _aggregate_pairs_sparse(src, dst, valid, a), None
    if backend == "auto":
        import jax

        backend = "numpy" if jax.default_backend() == "cpu" else "pallas"
    if backend == "numpy" or src.shape[0] == 0:
        from repro.core.dfg import dfg_numpy

        psi = dfg_numpy(np.asarray(src), np.asarray(dst), np.asarray(valid), a)
    else:
        from repro.core.dfg import dfg

        psi = dfg(src, dst, valid, a, backend=backend)
    return csr_from_dense(psi), psi


def _node_counts(
    event_activity: np.ndarray, a: int, backend: str = "auto"
) -> np.ndarray:
    """``:OF_TYPE`` node degrees.  ``np.bincount`` on CPU; the TPU-native
    path routes through the segment_count Pallas kernel."""
    if backend == "auto":
        import jax

        backend = "numpy" if jax.default_backend() == "cpu" else "pallas"
    if backend == "pallas":
        import jax.numpy as jnp

        from repro.kernels.segment_count import segment_count

        out = segment_count(
            jnp.asarray(event_activity, jnp.int32),
            jnp.ones(event_activity.shape, jnp.bool_),
            num_segments=a,
        )
        return np.asarray(out, dtype=np.int64)
    return np.bincount(event_activity, minlength=a).astype(np.int64)


def _miner_state_from_columns(
    psi: np.ndarray,
    event_activity: np.ndarray,
    case_ids: np.ndarray,
    num_events: int,
) -> MinerState:
    """The :class:`MinerState` a streaming scan of the same rows would have
    left behind: Ψ plus the last (time-ordered) activity of every case —
    constructed vectorized from canonical columns, no second scan."""
    last_by_case: Dict[int, int] = {}
    if num_events:
        is_end = np.ones(case_ids.shape[0], dtype=bool)
        is_end[:-1] = case_ids[:-1] != case_ids[1:]
        for c, a in zip(case_ids[is_end], event_activity[is_end]):
            last_by_case[int(c)] = int(a)
    return MinerState(
        psi=psi.astype(np.int64), last_by_case=last_by_case,
        events_seen=num_events,
    )


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def _event_tables(
    event_activity: np.ndarray,
    event_trace: np.ndarray,
    event_time: np.ndarray,
    a: int,
    t: int,
) -> dict:
    """The two node-expansion CSR indexes over canonical event columns."""
    order = np.argsort(event_activity, kind="stable")
    act_indptr = np.zeros(a + 1, dtype=np.int64)
    np.cumsum(np.bincount(event_activity, minlength=a), out=act_indptr[1:])
    case_indptr = np.zeros(t + 1, dtype=np.int64)
    np.cumsum(np.bincount(event_trace, minlength=t), out=case_indptr[1:])
    return dict(
        event_activity=np.ascontiguousarray(event_activity, dtype=np.int32),
        event_trace=np.ascontiguousarray(event_trace, dtype=np.int32),
        event_time=np.ascontiguousarray(event_time, dtype=np.float64),
        act_indptr=act_indptr,
        act_events=order.astype(np.int64),
        case_indptr=case_indptr,
    )


def _build_from_repository(
    repo: EventRepository, backend: str, source_fp: Optional[str]
) -> EventGraph:
    a = repo.num_activities
    src, dst, valid = repo.df_pairs()
    adj, psi = _aggregate_pairs(src, dst, valid, a, backend)
    return EventGraph(
        activity_names=list(repo.activity_names),
        num_events=repo.num_events,
        num_traces=repo.num_traces,
        node_counts=_node_counts(repo.event_activity, a, backend),
        adj=adj,
        radj=adj.transpose(),
        source_fp=source_fp,
        **_event_tables(
            repo.event_activity, repo.event_trace, repo.event_time,
            a, repo.num_traces,
        ),
    )


def _build_from_memmap(
    log: MemmapLog,
    backend: str,
    source_fp: Optional[str],
    memory_budget_events: Optional[int],
) -> EventGraph:
    a = log.num_activities
    in_budget = (
        memory_budget_events is None
        or log.num_events <= memory_budget_events
    )
    if in_budget:
        # one materialization gives canonical event tables *and* the pair
        # columns; the miner state is reconstructed vectorized (no rescan)
        from repro.query.execute import repository_from_memmap

        repo = repository_from_memmap(log)
        src, dst, valid = repo.df_pairs()
        adj, psi = _aggregate_pairs(src, dst, valid, a, backend)
        if psi is None:
            psi = dense_from_csr(adj)
        # miner keys are the log's raw case ids, not repo trace indices
        raw_case = np.asarray(log.case)
        order = np.lexsort(
            (np.arange(raw_case.shape[0]), np.asarray(log.time), raw_case)
        )
        miner = _miner_state_from_columns(
            psi, np.asarray(log.activity)[order], raw_case[order],
            log.num_events,
        )
        return EventGraph(
            activity_names=list(repo.activity_names),
            num_events=log.num_events,
            num_traces=repo.num_traces,
            node_counts=_node_counts(repo.event_activity, a, backend),
            adj=adj,
            radj=adj.transpose(),
            source_fp=source_fp,
            rows_end=log.num_events,
            miner=miner,
            **_event_tables(
                repo.event_activity, repo.event_trace, repo.event_time,
                a, repo.num_traces,
            ),
        )
    # out-of-core: one streaming scan, topology-only (O(A² + nnz) memory)
    miner = StreamingDFGMiner(a)
    node_counts = np.zeros(a, dtype=np.int64)
    for acts, cases, times in log.iter_chunks():
        miner.update(acts, cases, times)
        node_counts += np.bincount(acts, minlength=a)
    psi = miner.finalize()
    adj = csr_from_dense(psi)
    return EventGraph(
        activity_names=log.activity_labels(),
        num_events=log.num_events,
        num_traces=log.num_traces,
        node_counts=node_counts,
        adj=adj,
        radj=adj.transpose(),
        source_fp=source_fp,
        rows_end=log.num_events,
        miner=miner.snapshot(),
    )


def build_graph(
    source,
    *,
    backend: str = "auto",
    memory_budget_events: Optional[int] = None,
    source_fp: Optional[str] = None,
) -> EventGraph:
    """Construct the event-knowledge graph of a store.

    ``source`` is an :class:`EventRepository` or :class:`MemmapLog`;
    ``backend`` pins the dense-aggregation operator (``auto`` / ``numpy`` /
    ``scatter`` / ``onehot`` / ``pallas``).  Memmap logs beyond
    ``memory_budget_events`` build a topology-only graph in one streaming
    scan.  ``source_fp`` (a :func:`repro.query.cache.fingerprint` string)
    records provenance so snapshots can prove append-only extension.
    """
    if isinstance(source, EventRepository):
        return _build_from_repository(source, backend, source_fp)
    if isinstance(source, MemmapLog):
        return _build_from_memmap(
            source, backend, source_fp, memory_budget_events
        )
    raise TypeError(
        f"build_graph expects EventRepository or MemmapLog, "
        f"got {type(source).__name__}"
    )
