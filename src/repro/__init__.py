"""GraphPM-JAX — graph-based process mining (Jalali 2020) as a production
multi-pod JAX framework.

Subpackages:
  core       — the paper's contribution: event repositories, Algorithm 1 DFG,
               views, distributed/streaming execution, discovery, telemetry
  query      — declarative process-query engine (plans, cost model, cache)
  graph      — in-process event-knowledge graph (CSR store + snapshots)
  conformance— streaming/graph-native token replay + DFG alignments
  kernels    — Pallas TPU kernels (dfg_count, segment_count, align_dp)
  models     — assigned architecture zoo (dense/MoE/SSM/hybrid/enc-dec/VLM)
  configs    — one config per assigned architecture + input shapes
  sharding   — logical-axis sharding policies
  train      — optimizer, trainer, fault tolerance, grad compression
  serve      — KV caches, prefill/decode, batched engine
  checkpoint — sharded async checkpoints with elastic resharding
  data       — synthetic BPI-like log generator, XES/CSV IO, LM token pipeline
  launch     — mesh/dryrun/train/serve/mine CLIs
  roofline   — TPU v5e roofline analysis from compiled HLO
"""

__version__ = "0.1.0"
