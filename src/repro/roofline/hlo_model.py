"""Static execution model over compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — under a
scan-over-layers design that under-reports FLOPs/bytes/collectives by the
trip count (×n_layers, ×microbatches, …).  This module parses the HLO text
into its computation graph, walks calls/whiles/fusions with **multipliers
from ``known_trip_count`` annotations**, and accumulates:

* ``dot_flops``  — 2 · |result| · contracted-dim, per dot, × multiplier
  (matmul FLOPs, the quantity MFU is defined on);
* ``hbm_bytes``  — fusion-boundary traffic: Σ (operands + result) bytes of
  top-level ops (free ops excluded; dynamic-slice/gather charge the slice,
  not the sliced operand), × multiplier;
* ``collective_wire`` — per-device wire bytes with ring factors, × mult.

This is an analytic model of the *per-device execution*, exact on the
structure the compiler actually emitted.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloModel", "parse_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\]{},]+)\s+([\w\-]+)\((.*)$"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"(\d+)"')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)"
)
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")

FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
    # control ops: their carried buffers alias in place; the traffic is
    # charged inside the body computations (× trip count)
    "while", "conditional", "call",
}
SLICE_RESULT_ONLY = {"dynamic-slice", "gather", "slice", "broadcast"}
UPDATE_CHARGED = {"dynamic-update-slice", "scatter"}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes(text):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    result: str  # type text
    instr: str
    line: str
    args: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]  # param name -> type text
    ops: Dict[str, Op]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        m = _COMP_RE.match(raw)
        if m:
            name = m.group(2)
            params: Dict[str, str] = {}
            for p in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\))|[^,)]+)",
                                 m.group(3)):
                params[p.group(1)] = p.group(2)
            cur = Computation(name=name, params=params, ops={})
            comps[name] = cur
            if m.group(1):
                entry_name = name
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(raw)
        if om:
            name, rtype, instr, rest = om.groups()
            args = re.findall(r"%([\w.\-]+)", rest.split("),", 1)[0])
            cur.ops[name] = Op(name, rtype, instr, raw, args)
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


class HloModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self.dot_flops = 0.0
        self.hbm_bytes = 0.0
        self.collective_wire = 0.0
        self.collective_ops: Dict[str, Dict] = {}
        self.unknown_trip_whiles = 0
        self._fused: set = set()
        self._mark_fused()
        entry = self.comps.get("__entry__")
        if entry is not None:
            self._walk(entry, 1.0, set())

    # -- helpers ----------------------------------------------------------
    def _mark_fused(self):
        for comp in self.comps.values():
            for op in comp.ops.values():
                if op.instr == "fusion":
                    cm = _CALL_ATTR_RE.search(op.line)
                    if cm:
                        self._fused.add(cm.group(1))

    def _type_of(self, comp: Computation, name: str) -> Optional[str]:
        if name in comp.ops:
            return comp.ops[name].result
        if name in comp.params:
            return comp.params[name]
        return None

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        res = _shapes(op.result)
        n_out = 0
        for dt, dims in res:
            k = 1
            for d in dims:
                k *= d
            n_out += k
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        contract = 1
        if cm and op.args:
            lhs_t = self._type_of(comp, op.args[0])
            if lhs_t:
                lhs_shapes = _shapes(lhs_t)
                if lhs_shapes:
                    dims = lhs_shapes[0][1]
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contract *= dims[int(idx)]
        return 2.0 * n_out * contract

    def _op_bytes(self, comp: Computation, op: Op) -> float:
        if op.instr in FREE_OPS:
            return 0.0
        if op.instr in SLICE_RESULT_ONLY:
            return 2.0 * _shape_bytes(op.result)  # read slice + write result
        if op.instr in UPDATE_CHARGED and len(op.args) >= 2:
            t = self._type_of(comp, op.args[1])
            return 2.0 * _shape_bytes(t or op.result)
        if op.instr == "fusion":
            cm = _CALL_ATTR_RE.search(op.line)
            sub = self.comps.get(cm.group(1)) if cm else None
            if sub:
                return self._fusion_bytes(sub)
        total = _shape_bytes(op.result)
        for a in op.args:
            t = self._type_of(comp, a)
            if t:
                total += _shape_bytes(t)
        return float(total)

    def _chase(self, sub: Computation, name: str) -> str:
        """Follow convert/bitcast/copy chains back to the producing op."""
        seen = set()
        while (
            name in sub.ops
            and sub.ops[name].instr in ("convert", "bitcast", "copy")
            and sub.ops[name].args
            and name not in seen
        ):
            seen.add(name)
            name = sub.ops[name].args[0]
        return name

    def _fusion_bytes(self, sub: Computation) -> float:
        """Fusion I/O model: internal ops are in-register; HBM traffic is
        what the fusion *reads from parameters* and *writes as result*.

        - a parameter that is the (convert-chained) target of an internal
          dynamic-update-slice is an in-place update → charge 2×update;
        - a parameter consumed only through dynamic-slice/gather → charge
          the slices, not the buffer (a 60-layer stacked cache read per
          scan step would otherwise be charged wholesale);
        - the result is a write unless the root chains to an in-place DUS.
        """
        uses: Dict[str, List[Op]] = {p: [] for p in sub.params}
        root: Optional[Op] = None
        for o in sub.ops.values():
            if "ROOT" in o.line:
                root = o
            for a in o.args:
                if a in uses:
                    uses[a].append(o)

        total = 0.0
        dus_targets = set()
        for o in sub.ops.values():
            if o.instr in UPDATE_CHARGED and o.args:
                if len(o.args) >= 2:
                    t = self._type_of(sub, o.args[1])
                    total += 2.0 * _shape_bytes(t or "")
                dus_targets.add(self._chase(sub, o.args[0]))

        for p, ptype in sub.params.items():
            if p in dus_targets:
                continue  # charged via the update region
            pu = uses.get(p, [])
            direct = [u for u in pu if u.instr not in ("convert", "bitcast")]
            chained = [
                o for o in sub.ops.values()
                if o.args and self._chase(sub, o.args[0]) == p
            ]
            readers = direct or chained or pu
            if readers and all(
                u.instr in ("dynamic-slice", "gather") for u in readers
            ):
                for u in readers:
                    total += _shape_bytes(u.result)
            else:
                total += _shape_bytes(ptype)

        if root is not None:
            root_src = self._chase(sub, root.name)
            if not (
                root_src in sub.ops
                and sub.ops[root_src].instr in UPDATE_CHARGED
            ):
                total += _shape_bytes(root.result)
        return total

    def _collective(self, op: Op, mult: float):
        kind = op.instr.replace("-start", "")
        bytes_ = _shape_bytes(op.result)
        gm = _GROUPS_RE.search(op.line)
        if gm:
            n = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(op.line)
            n = len(gb.group(1).split(",")) if gb else 2
        n = max(n, 2)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * bytes_
        elif kind == "all-gather":
            wire = (n - 1) / n * bytes_
        elif kind == "reduce-scatter":
            wire = float(n - 1) * bytes_
        elif kind == "all-to-all":
            wire = (n - 1) / n * bytes_
        else:
            wire = float(bytes_)
        self.collective_wire += wire * mult
        slot = self.collective_ops.setdefault(
            kind, {"count": 0.0, "bytes": 0.0, "wire": 0.0}
        )
        slot["count"] += mult
        slot["bytes"] += bytes_ * mult
        slot["wire"] += wire * mult

    # -- traversal -------------------------------------------------------------
    def _walk(self, comp: Computation, mult: float, stack: set):
        if comp.name in stack:  # defensive: no recursion in HLO
            return
        stack = stack | {comp.name}
        in_fusion = comp.name in self._fused
        for op in comp.ops.values():
            if op.instr == "dot":
                self.dot_flops += self._dot_flops(comp, op) * mult
            if op.instr in COLLECTIVES and "-done" not in op.instr:
                self._collective(op, mult)
            if not in_fusion:
                self.hbm_bytes += self._op_bytes(comp, op) * mult

            if op.instr == "while":
                tm = _TRIP_RE.search(op.line)
                trip = float(tm.group(1)) if tm else 1.0
                if tm is None:
                    self.unknown_trip_whiles += 1
                for ref in _CALL_ATTR_RE.finditer(op.line):
                    sub = self.comps.get(ref.group(1))
                    if sub:
                        self._walk(sub, mult * trip, stack)
            elif op.instr == "fusion":
                cm = _CALL_ATTR_RE.search(op.line)
                if cm and cm.group(1) in self.comps:
                    self._walk(self.comps[cm.group(1)], mult, stack)
            elif op.instr in ("call", "custom-call", "reduce", "scatter",
                              "sort", "map", "reduce-window", "select-and-scatter"):
                for ref in _CALL_ATTR_RE.finditer(op.line):
                    sub = self.comps.get(ref.group(1))
                    if sub:
                        self._walk(sub, mult, stack)
            elif op.instr == "conditional":
                bm = _BRANCH_RE.search(op.line)
                if bm:
                    for nm in re.findall(r"%([\w.\-]+)", bm.group(1)):
                        sub = self.comps.get(nm)
                        if sub:
                            self._walk(sub, mult, stack)

    def summary(self) -> Dict:
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_wire_bytes": self.collective_wire,
            "collective_ops": self.collective_ops,
            "num_collectives": sum(
                v["count"] for v in self.collective_ops.values()
            ),
            "unknown_trip_whiles": self.unknown_trip_whiles,
        }
