"""Three-term roofline from a compiled (SPMD-partitioned) module.

``cost_analysis()`` and ``memory_analysis()`` report **per-device** numbers
(the partitioned HLO is the per-device program), so:

  compute_s    = flops / PEAK_FLOPS_BF16
  memory_s     = bytes_accessed / HBM_BW
  collective_s = Σ wire_bytes(op) / ICI_BW

Collective bytes are not in cost_analysis; they are parsed from
``compiled.as_text()``: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute result shape (per-device), weighted by the
ring factor on its replica-group size N:

  all-reduce      2·(N−1)/N · bytes      (reduce-scatter + all-gather phases)
  all-gather      (N−1)/N · bytes        (bytes = full gathered result)
  reduce-scatter  (N−1) · bytes          (bytes = scattered result; operand=N·bytes)
  all-to-all      (N−1)/N · bytes
  collective-permute  1 · bytes
"""

from __future__ import annotations

import re
from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from . import hw

__all__ = ["parse_collectives", "analyze_compiled", "model_flops"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%all-reduce.1 = f32[4,32]{1,0} all-reduce(` or tuple results
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9fbsupc]+\[[^=]*?)\s*"
    r"(all-reduce-start|all-gather-start|collective-permute-start|"
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict:
    """Per-device collective inventory from partitioned HLO text."""
    ops = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        if "-done" in line:
            continue  # async pair: count the start only
        shape_txt, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        bytes_ = _shape_bytes(shape_txt)
        gm = _GROUPS_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            n = len(gb.group(1).split(",")) if gb else 2
        n = max(n, 2)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * bytes_
        elif kind == "all-gather":
            wire = (n - 1) / n * bytes_
        elif kind == "reduce-scatter":
            wire = float(n - 1) * bytes_
        elif kind == "all-to-all":
            wire = (n - 1) / n * bytes_
        else:  # collective-permute
            wire = float(bytes_)
        ops.append({"kind": kind, "bytes": bytes_, "group": n, "wire": wire})

    by_kind: Dict[str, Dict] = {}
    for o in ops:
        k = by_kind.setdefault(o["kind"], {"count": 0, "bytes": 0, "wire": 0.0})
        k["count"] += 1
        k["bytes"] += o["bytes"]
        k["wire"] += o["wire"]
    return {
        "ops": by_kind,
        "num_collectives": len(ops),
        "wire_bytes": sum(o["wire"] for o in ops),
    }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Reference MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), with
    N = active params for MoE.  Global (whole step, all chips)."""
    n = cfg.active_params_count() if cfg.n_experts else cfg.params_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_compiled(compiled, cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict:
    """Three-term roofline from the compiled artifact.

    Primary source: the trip-count-aware static execution model
    (:mod:`repro.roofline.hlo_model`) over ``compiled.as_text()`` — XLA's
    ``cost_analysis()`` counts while (scan) bodies once, which under a
    scan-over-layers design under-reports by ×n_layers; both numbers are
    recorded (``*_raw`` = uncorrected cost_analysis)."""
    from .hlo_model import HloModel

    from repro.core.compat import cost_analysis

    chips = int(np.prod(list(mesh.shape.values())))
    cost = cost_analysis(compiled)
    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
    except Exception:  # noqa: BLE001 — backend may not support it
        pass
    per_device_bytes = (
        mem.get("argument_bytes", 0)
        + mem.get("temp_bytes", 0)
        + mem.get("output_bytes", 0)
        - mem.get("alias_bytes", 0)
    )

    model = HloModel(compiled.as_text()).summary()
    flops = model["dot_flops"]
    bytes_accessed = model["hbm_bytes"]

    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = bytes_accessed / hw.HBM_BW
    collective_s = model["collective_wire_bytes"] / hw.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mflops = model_flops(cfg, shape)
    mflops_per_chip = mflops / chips
    return {
        "chips": chips,
        "flops_per_device": flops,
        "bytes_accessed_per_device": bytes_accessed,
        "flops_per_device_raw": flops_raw,
        "bytes_accessed_per_device_raw": bytes_raw,
        "unknown_trip_whiles": model["unknown_trip_whiles"],
        "memory_analysis": mem,
        "per_device_bytes": per_device_bytes,
        "fits_hbm": per_device_bytes <= hw.HBM_BYTES,
        "collectives": {
            "ops": model["collective_ops"],
            "num_collectives": model["num_collectives"],
            "wire_bytes": model["collective_wire_bytes"],
        },
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant_term": dominant.replace("_s", ""),
        "model_flops_global": mflops,
        "model_flops_per_chip": mflops_per_chip,
        "useful_flops_ratio": (mflops_per_chip / flops) if flops else 0.0,
        "roofline_bound_s": max(terms.values()),
        "roofline_fraction": (
            (mflops_per_chip / hw.PEAK_FLOPS_BF16) / max(terms.values())
            if max(terms.values()) > 0
            else 0.0
        ),
    }
