from . import hw
from .analyze import analyze_compiled, model_flops, parse_collectives

__all__ = ["hw", "analyze_compiled", "model_flops", "parse_collectives"]
