"""TPU v5e hardware constants (per chip) — roofline targets."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (single-direction, conservative 1-link model)
HBM_BYTES = 16 * 2**30  # capacity per chip
VMEM_BYTES = 16 * 2**20
