"""End-to-end training driver: train an LM on the synthetic Markov language,
with checkpointing and telemetry, then verify the loss beat the uniform
floor and approach the bigram entropy.

Defaults are CPU-sized (preset=small trains a ~20M model for 200 steps in a
few minutes); on a pod, use --preset full to train the real config via the
dry-run-proven step functions.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or []) if True else sys.argv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="small")
    args, rest = ap.parse_known_args()

    from repro.launch import train as train_cli

    sys.argv = [
        "train", "--arch", args.arch, "--preset", args.preset,
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--mine",
    ] + rest
    train_cli.main()


if __name__ == "__main__":
    main()
