"""Batched serving example: ragged prompts, waves, per-sequence positions.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import ServeEngine

cfg = dataclasses.replace(
    get_config("gemma2-9b").reduced(), vocab_size=512, loss_chunk=32
)
params = init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, max_batch=4, max_cache=128, temperature=0.7)

rng = np.random.default_rng(0)
prompts = [
    rng.integers(1, cfg.vocab_size, size=n).tolist()
    for n in (3, 7, 12, 5, 9, 2, 20, 6)
]
t0 = time.perf_counter()
results = engine.generate(prompts, max_new_tokens=24)
dt = time.perf_counter() - t0

total = sum(len(r.tokens) for r in results)
print(f"{len(results)} requests, {total} tokens in {dt:.2f}s "
      f"({total / dt:.1f} tok/s on 1 CPU core)")
for r in results[:3]:
    print(f"  prompt[{len(r.prompt):2d} toks] → {r.tokens[:10]}… ({r.finished})")

# the engine records its own process events — minable like everything else
repo = engine.collector.to_repository()
print(f"\nserver telemetry: {repo.num_events} events "
      f"({', '.join(repo.activity_names)})")
