"""The framework mines ITSELF: train with an injected straggler + crash,
then apply graph-based process mining (the paper's technique) to the
trainer's own event log — the deviation shows up as a process variant.

    PYTHONPATH=src python examples/mine_training_run.py
"""

import dataclasses
import tempfile
import time

from repro.configs import get_config
from repro.configs.base import TrainHParams
from repro.core import (
    dfg_from_repository,
    discover_dependency_graph,
    filter_dfg,
    to_dot,
)
from repro.data.lm_data import TokenPipeline
from repro.train import Trainer

cfg = dataclasses.replace(
    get_config("starcoder2-3b").reduced(), vocab_size=64, loss_chunk=8
)
data = TokenPipeline(vocab_size=cfg.vocab_size, batch=2, seq_len=16, seed=3,
                     branching=4)
hp = TrainHParams(learning_rate=3e-3, warmup_steps=2, total_steps=100)

crashed = {"done": False}


def chaos(step):
    if step == 7:
        time.sleep(1.0)  # straggler
    if step == 11 and not crashed["done"]:
        crashed["done"] = True
        raise RuntimeError("injected node failure")


tr = Trainer(cfg, hp, data, tempfile.mkdtemp(), ckpt_every=5, q_chunk=16,
             failure_injector=chaos, straggler_threshold=3.0)
out = tr.run(16)
print(f"trained to step {out['final_step']} "
      f"(crash at 11 → restored from checkpoint 10 and replayed)")
print("straggler report:", out["stragglers"])

# --- mine the run --------------------------------------------------------
repo = tr.collector.to_repository()
psi = dfg_from_repository(repo)
names = repo.activity_names
print(f"\nevent log: {repo.num_events} events over {repo.num_traces} steps; "
      f"activities: {names}")

starts, ends = repo.trace_boundaries()
model = discover_dependency_graph(
    filter_dfg(psi, 1), names, starts, ends, min_count=1, min_dependency=0.0
)
print("\nDFG of the training process (note the failure/restart variant):")
print(to_dot(model))
