"""Privacy-preserving mining (the paper's §2.2 COVID example, §6.2 claims):
an analyst session that can ONLY obtain aggregates, through an
access-control view that coarsens activities to the department level.

    PYTHONPATH=src python examples/privacy_views.py
"""

from repro.core import (
    AccessPolicy,
    ActivityView,
    AnalystSession,
    EventRepository,
)
from repro.core.views import AccessDenied

# a hospital-ish process: activity names carry ward-level detail
repo = EventRepository.from_traces(
    [
        ["reg_desk_A", "triage_room_2", "lab_blood", "ward_3_admit"],
        ["reg_desk_B", "triage_room_1", "lab_xray", "ward_3_admit"],
        ["reg_desk_A", "triage_room_1", "lab_blood", "ward_5_admit"],
    ]
    * 50
)

view = ActivityView(
    mapping={
        "reg_desk_A": "registration", "reg_desk_B": "registration",
        "triage_room_1": "triage", "triage_room_2": "triage",
        "lab_blood": "lab", "lab_xray": "lab",
        "ward_3_admit": "admission", "ward_5_admit": "admission",
    }
)
policy = AccessPolicy(aggregate_only=True, view=view, min_group_count=5)
session = AnalystSession(repo, policy)

psi, names = session.dfg()
print("analyst sees the department-level DFG only:")
print("               " + "  ".join(f"{n:>12}" for n in names))
for n, row in zip(names, psi):
    print(f"{n:>14} " + "  ".join(f"{int(x):12d}" for x in row))

print("\nraw events are unreachable through the session:")
try:
    session.events()
except AccessDenied as e:
    print(f"  AccessDenied: {e}")

hist, hnames = session.activity_histogram()
print("\ncoarsened histogram:", dict(zip(hnames, hist.tolist())))
print("trace stats (aggregate):", session.trace_length_stats())
