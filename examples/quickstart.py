"""Quickstart: the paper's pipeline in 30 lines, through the query engine.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    check_columnar,
    discover_dependency_graph,
    filter_dfg,
    paper_example_repo,
    to_dot,
)
from repro.data import ProcessSpec, generate_repository
from repro.query import Q, default_engine

# --- 1. the paper's worked example (Fig. 3 → Table 1) ----------------------
repo = paper_example_repo()
res = Q.log(repo).dfg()
print("Table 1 (paper worked example):")
print("      " + "  ".join(res.names))
for name, row in zip(res.names, res.value):
    print(f"  {name}  " + "   ".join(str(int(x)) for x in row))

# --- 2. a bigger synthetic log: load → DFG in-store → discover -------------
repo = generate_repository(2_000, ProcessSpec(num_activities=12, seed=4))
assert check_columnar(repo).ok
psi = Q.log(repo).dfg(backend="scatter").value
print(f"\nlog: {repo.num_events} events, {repo.num_traces} traces, "
      f"{int(psi.sum())} directly-follows pairs")

starts, ends = repo.trace_boundaries()
model = discover_dependency_graph(
    filter_dfg(psi, min_count=20), repo.activity_names, starts, ends,
    min_count=20, min_dependency=0.5,
)
print(f"discovered dependency graph: {len(model.edges)} edges")
print(to_dot(model)[:400] + "\n…")

# --- 3. dicing (the paper's Experiment 2 semantics) -------------------------
t0 = float(np.quantile(repo.event_time, 0.25))
t1 = float(np.quantile(repo.event_time, 0.75))
diced = Q.log(repo).window(t0, t1).dfg()
print(f"\ndiced to the middle half of the horizon: "
      f"{int(diced.value.sum())} pairs ({int(psi.sum())} undiced)")

# --- 4. the query engine: plans, pushdowns, and the result cache ------------
print("\nquery plan for the diced query:")
print(Q.log(repo).window(t0, t1).explain())
again = Q.log(repo).window(t0, t1).dfg()
stats = default_engine().stats
print(f"\nre-issued the same query: from_cache={again.from_cache} "
      f"(engine: {stats.queries} queries, {stats.executions} executions, "
      f"{stats.cache_hits} cache hits)")

# --- 5. the event-knowledge-graph tier --------------------------------------
# in auto mode the planner builds the graph once a source crosses the
# measured repeat-query threshold; backend="graph" pins it explicitly
pm = Q.log(repo).process_map(top=0.2).value
print(f"\nprocess map (top 20% nodes/edges): {len(pm.activities)} activities,"
      f" {len(pm.edges)} edges (dropped {pm.dropped_activities} nodes, "
      f"{pm.dropped_edges} edges)")
center = pm.activities[0]
nb = Q.log(repo).neighborhood(center, k=2, direction="both", backend="graph")
print(f"2-hop neighborhood of {center!r}: {len(nb.value.activities)} "
      f"activities via backend={nb.physical.backend} "
      f"(graph store: {default_engine().graphs.stats})")

# --- 6. conformance: replay fitness + optimal alignments --------------------
# how well does the middle half of the horizon conform to the model
# discovered from the whole log?  (sequence semantics: the window re-links)
fit = Q.log(repo).window(t0, t1).fitness(model)
print(f"\nreplay fitness of the diced slice vs the discovered model: "
      f"{fit.value.fitness:.4f} ({fit.value.perfectly_fitting}/"
      f"{fit.value.trace_fitness.shape[0]} traces perfect, "
      f"backend={fit.physical.backend})")
worst = sorted(fit.value.deviating_edges.items(), key=lambda kv: -kv[1])[:3]
print(f"top deviating flows: {worst}")

ali = Q.log(repo).alignments(model)
print(f"optimal alignments (batched per variant, kernels/align_dp): "
      f"mean fitness {ali.value.fitness:.4f}, "
      f"mean cost {float(ali.value.trace_cost.mean()):.2f}, "
      f"cheapest model walk = {ali.value.empty_cost} moves")

# --- 7. observability: traces, metrics, and self-mining forensics -----------
# every result carries a trace of timed spans plus the planner's prediction
tr = again.trace
print(f"\ntrace q{tr.query_id}: backend={tr.executed_backend} "
      f"(planned={tr.planned_backend}) total={tr.total_s * 1e3:.3f}ms "
      f"coverage={tr.coverage() * 100:.1f}%")
print("  spans: " + ", ".join(
    f"{s.name}={s.duration_s * 1e3:.3f}ms" for s in tr.spans))
# explain(after=...) diffs the prediction against what actually ran
print(Q.log(repo).window(t0, t1).explain(after=again))

# the engine's counters/histograms export as dict, JSON lines, or Prometheus
snap = default_engine().metrics_snapshot()
lat = snap["query_latency_seconds{backend=cache,sink=dfg}"]
print(f"\ncache-hit latency: p50={lat['p50'] * 1e6:.0f}us "
      f"p99={lat['p99'] * 1e6:.0f}us over {lat['count']} hits "
      f"(hit ratio {snap['engine_cache_hit_ratio']:.2f})")

# self-mining: the engine's own spans are an event log — mine the miner
own = default_engine().own_telemetry()
forensics = Q.log(own).dfg()
print(f"forensics DFG over {own.num_events} engine events "
      f"({len(forensics.names)} phases): a full scan is the chain "
      f"parse -> cache_probe -> plan -> scan -> sink; hits stop at the probe")

# --- 8. the sharded graph tier: case-partitioned scale-out ------------------
# cases are assigned whole to K shards (case % K), so the global Ψ is a
# pure sum of per-shard counts; each shard keeps its own CSR snapshot,
# fingerprint slot, and delta path
import tempfile

from repro.data import generate_memmap_log
from repro.graph import partition_memmap_log
from repro.query import QueryEngine

tmp = tempfile.mkdtemp(prefix="quickstart_shard_")
log = generate_memmap_log(
    f"{tmp}/log", 60_000,
    ProcessSpec(num_activities=12, seed=8, horizon_days=90), seed=8,
)
sharded = partition_memmap_log(log, 4, f"{tmp}/shards")
eng = QueryEngine()
w0 = float(np.quantile(log.time, 0.25))
w1 = float(np.quantile(log.time, 0.75))
cold = Q.log(sharded).using(eng).window(w0, w1).dfg(backend="sharded-graph")
print(f"\nsharded DFG over K={sharded.num_shards} shards: "
      f"{int(cold.value.sum())} pairs, per-shard branches: "
      f"{[name for name, _ in cold.trace.branches]}")

# appends land on the owning shard only: the re-query extends one shard's
# graph over the 3-row suffix while the other shards' graphs are pure hits
grown = sharded.append(
    np.array([1, 2, 3], dtype=np.int32),       # activities
    np.array([6, 6, 6], dtype=np.int32),       # one case → one owning shard
    log.time[-1] + np.arange(1.0, 4.0),        # appends stay time-ordered
)
rows_before = eng.stats.rows_scanned
warm = Q.log(grown).using(eng).dfg(backend="sharded-graph")
print(f"after a 3-event append: rescanned "
      f"{eng.stats.rows_scanned - rows_before} rows "
      f"(owning shard's suffix only: {eng.graphs.stats.extends} extend, "
      f"{eng.graphs.stats.hits} warm shard hits)")

# --- 9. production serving: admission, coalescing, SLO lanes ----------------
# the transport tier wraps QueryService in an asyncio HTTP layer; here we
# drive its app core in-process (TransportServer serves the same app on a
# socket: POST /query, /query/stream NDJSON, GET /metrics, /stream/*)
import asyncio

from repro.serve import QueryService
from repro.transport import TransportApp, canonical_payload

svc = QueryService(eng)
svc.register("bpi", repo)


async def serve_demo():
    app = TransportApp(svc)
    # 8 identical concurrent dashboard queries coalesce into ONE engine
    # execution; everyone shares the result
    req = {"log": "bpi", "sink": "process_map", "top": 1.0}
    before = eng.stats.executions
    resps = await asyncio.gather(*[app.handle(req) for _ in range(8)])
    fanned = sum(1 for r in resps if r.headers["X-Coalesced"] == "1")
    print(f"\n8 concurrent identical queries -> "
          f"{eng.stats.executions - before} execution(s), "
          f"{fanned} coalesced, lane={resps[0].headers['X-Lane']}")
    assert canonical_payload(resps[0].payload) == canonical_payload(
        svc.query(req)
    )  # the transport path is bit-identical to the direct dict path
    # the live metrics feed already includes the transport's own health
    metrics = (await app.handle({"sink": "metrics"})).payload["metrics"]
    print("transport fanout counter:",
          metrics["transport_coalesce_fanout_total"])
    app.close()


asyncio.run(serve_demo())

# --- 10. distributed observability: traces, exemplars, SLOs, trace store ----
# every request carries one trace id end to end (transport span -> engine
# trace -> per-shard sub-traces), histograms keep exemplar trace ids, the
# SLO engine turns the live metrics into verdicts + burn rates, and the
# persisted trace ring mines like any other event log
import tempfile

from repro.obs import mint_context
from repro.transport import TransportConfig

trace_dir = tempfile.mkdtemp(prefix="quickstart_traces_")
svc2 = QueryService()
svc2.register("bpi", repo)


async def obs_demo():
    app = TransportApp(svc2, TransportConfig(trace_dir=trace_dir))
    inbound = mint_context()  # e.g. parsed from an inbound traceparent
    resp = await app.handle(
        {"log": "bpi", "sink": "dfg"},
        traceparent=inbound.to_traceparent(),
    )
    print(f"\none trace id end to end: request={inbound.trace_id}")
    print(f"  response X-Trace-Id={resp.headers['X-Trace-Id']}"
          f"  payload trace_id={resp.payload['trace_id']}")
    await app.handle({"log": "bpi", "sink": "dfg"})  # a cache hit, traced too

    # SLO verdicts + error budgets + burn rates from the live registry
    slo = (await app.handle({"sink": "slo"})).payload
    for o in slo["objectives"]:
        print(f"  slo {o['name']}: ok={o['ok']} "
              f"budget_left={o['error_budget_remaining']}")

    # exemplars: the worst recent trace id per latency bucket, in the
    # Prometheus exposition (OpenMetrics syntax)
    prom = svc2.engine.metrics.to_prometheus()
    print("  exemplar lines:",
          sum(1 for l in prom.splitlines() if "trace_id=" in l))

    # the persisted ring reads back as an event log: mine your own traces
    # with the same Algorithm 1 the engine serves
    own = app.trace_store.to_repository()
    spans_dfg = Q.log(own).dfg()
    print(f"  mined {own.num_traces} persisted trace(s): "
          f"{spans_dfg.names[:4]}…")
    app.close()


asyncio.run(obs_demo())

# the invariants behind all of the above are machine-checked: run
#   python -m repro.analysis --fail-on-new        (lint: sinks/keys/locks)
#   REPRO_LOCKDEP=1 pytest tests/test_obs.py      (runtime lock-order sanitizer)
#   python -m repro.analysis --kernel-report BENCH_analysis.json
# see the "Static analysis" section of README.md
