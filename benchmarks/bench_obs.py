"""Observability overhead benchmark: what does always-on tracing cost?

Wall-clock scan timings on a shared host are far noisier (±10% and more)
than the effect being measured (microseconds per query), so the headline
number is built from a noise-robust estimator:

* **per-query tax** — paired cache-hit loops, tracing on vs off, run in
  BOTH orders (on,off / off,on).  A hit is the cheapest query the engine
  serves (~0.3 ms of fingerprint + probe), so the constant per-query
  tracing cost (trace alloc, span stamps, histogram observe, forensics
  batch) is fully exposed.  Whichever side runs first in a pair is
  systematically slower (branch/cache state), so the tax is estimated as
  half the difference of the two orders' median deltas — the position
  bias cancels exactly.
* **workload** — one cold full scan + a fan of windowed misses + the
  same fan as cache hits, interleaved on/off repeats, best-of each; used
  as the denominator and recorded for context.

``trace_overhead`` = per-query tax × queries ÷ untraced workload wall —
the tracing tax a real scan-bearing dashboard workload actually pays —
and is asserted under the 3% budget.  The raw workload-vs-workload delta
is recorded too but not asserted: at bench scale the true signal
(~0.5 ms over hundreds of ms) sits far below host noise.

Also checks the acceptance criterion that a traced query's spans cover
≥95% of its wall time, and (direct invocation only) stamps the measured
``trace_overhead`` into every committed ``BENCH_*.json`` record so each
benchmark carries the observability tax it was measured under.

Emits CSV rows (and ``BENCH_obs.json``).
"""

from __future__ import annotations

import glob
import json
import math
import os
import statistics
import sys
import tempfile
import time

import numpy as np

# runnable directly (`python benchmarks/bench_obs.py`) without PYTHONPATH
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

EVENTS = int(os.environ.get("BENCH_EVENTS", 2_000_000))
REPEATS = 4
WINDOWS = 16
HIT_PAIRS = 4_000
OVERHEAD_BUDGET = 0.03
#: head-sampling stride for the benchmark's trace store: the traced side
#: pays the real serving-tier cost (keep/drop decision every query, an
#: actual JSONL write every Nth)
STORE_SAMPLE_EVERY = 256


def _windows(log, k: int):
    ts = np.asarray(log.time)
    qs = np.linspace(0.05, 0.95, k + 1)
    edges = [float(np.quantile(ts, q)) for q in qs]
    return list(zip(edges[:-1], edges[1:]))


def _paired_delta_us(qa, qb, pairs: int) -> float:
    """Median of per-pair (a − b) call-time deltas, microseconds."""
    pc = time.perf_counter
    ds = []
    for _ in range(pairs):
        t0 = pc()
        qa.dfg()
        t1 = pc()
        qb.dfg()
        t2 = pc()
        ds.append(((t1 - t0) - (t2 - t1)) * 1e6)
    return statistics.median(ds)


def _traced_engine(store_dir: str):
    """The traced side runs the full distributed-observability stack: a
    trace store offered every finished root query (tail-sampling decision
    on the hot path, a JSONL write every ``STORE_SAMPLE_EVERY``-th) and
    latency-histogram observes carrying trace-id exemplars."""
    from repro.obs import TraceStore
    from repro.query import QueryEngine

    eng = QueryEngine()
    eng.trace_store = TraceStore(
        store_dir, sample_every=STORE_SAMPLE_EVERY, metrics=eng.metrics
    )
    return eng


def _per_query_tax_us(log, store_dir, pairs):
    """Bias-cancelled per-query tracing cost on the cache-hit hot path,
    with context propagation on: every traced query binds as a child of an
    ambient request context, exactly as under the transport tier."""
    from repro.obs.context import mint_context
    from repro.query import Q, QueryEngine

    eng_on = _traced_engine(store_dir)
    q_on = Q.log(log).using(eng_on)
    q_off = Q.log(log).using(QueryEngine(trace=False))
    q_on.dfg()  # populate both caches
    q_off.dfg()
    with eng_on.trace_scope(mint_context()):
        d_on_first = _paired_delta_us(q_on, q_off, pairs)
        d_off_first = _paired_delta_us(q_off, q_on, pairs)
    # d_on_first  = (c_on − c_off) + bias;  d_off_first = (c_off − c_on) + bias
    tax = (d_on_first - d_off_first) / 2.0
    # per-hit wall for context (median of the off side, second position)
    pc = time.perf_counter
    t0 = pc()
    for _ in range(1000):
        q_off.dfg()
    hit_us = (pc() - t0) / 1000 * 1e6
    return max(0.0, tax), hit_us


def _workload_s(trace: bool, log, windows, store_dir=None) -> float:
    import contextlib

    from repro.obs.context import mint_context
    from repro.query import Q, QueryEngine

    if trace:
        eng = _traced_engine(store_dir)  # fresh: cold plan/result cache
        scope = eng.trace_scope(mint_context())
    else:
        eng = QueryEngine(trace=False)
        scope = contextlib.nullcontext()
    t0 = time.perf_counter()
    with scope:
        Q.log(log).using(eng).dfg()  # cold full scan (cached after)
        for w0, w1 in windows:  # windowed fan: misses
            Q.log(log).using(eng).window(w0, w1).dfg()
        for w0, w1 in windows:  # same fan again: pure cache-hit hot path
            Q.log(log).using(eng).window(w0, w1).dfg()
    return time.perf_counter() - t0


def run(write_json: bool = False, fast: bool = False) -> list:
    """CSV rows; ``write_json=True`` (direct invocation only) also rewrites
    ``BENCH_obs.json`` and stamps ``trace_overhead`` into the other
    committed ``BENCH_*.json`` records — the aggregator's and CI's reduced
    ``--fast`` runs must not clobber them."""
    from repro.data import ProcessSpec, generate_memmap_log
    from repro.query import Q, QueryEngine

    events = min(EVENTS, 200_000) if fast else EVENTS
    hit_pairs = 400 if fast else HIT_PAIRS
    repeats = 2 if fast else REPEATS

    rows = []
    tmp = tempfile.mkdtemp(prefix="graphpm_bencho_")
    log = generate_memmap_log(
        os.path.join(tmp, "log"), events,
        ProcessSpec(num_activities=64, seed=31, horizon_days=120), seed=31,
    )
    windows = _windows(log, WINDOWS)

    # warm the jitted kernels so neither side pays compile time
    warm = QueryEngine()
    Q.log(log).using(warm).dfg()

    tax_us, hit_us = _per_query_tax_us(
        log, os.path.join(tmp, "traces_tax"), hit_pairs
    )
    rows.append((
        "obs_per_query_tax", tax_us,
        f"hit_us={hit_us:.1f};tax_of_hit={tax_us / hit_us * 100:.2f}%",
    ))

    # -- scan-bearing workload (denominator; noisy on shared hosts) ----------
    n_queries = 1 + 2 * WINDOWS
    on_s = off_s = math.inf
    for rep in range(repeats):
        order = (True, False) if rep % 2 else (False, True)
        for trace in order:
            dt = _workload_s(
                trace, log, windows,
                store_dir=os.path.join(tmp, f"traces_wl{rep}"),
            )
            if trace:
                on_s = min(on_s, dt)
            else:
                off_s = min(off_s, dt)
    overhead = (tax_us * 1e-6 * n_queries) / off_s
    rows.append((
        "obs_trace_overhead", on_s * 1e6,
        f"off_us={off_s * 1e6:.0f};overhead={overhead * 100:.3f}%;"
        f"queries={n_queries}",
    ))

    # acceptance: spans cover >=95% of a traced query's wall time
    eng = QueryEngine()
    res = Q.log(log).using(eng).dfg()
    coverage = res.trace.coverage()
    rows.append((
        "obs_trace_coverage", res.trace.total_s * 1e6,
        f"coverage={coverage * 100:.1f}%;spans={len(res.trace.spans)}",
    ))

    if not write_json:
        return rows

    assert overhead < OVERHEAD_BUDGET, (
        f"tracing overhead {overhead:.3%} exceeds {OVERHEAD_BUDGET:.0%} "
        f"budget (tax={tax_us:.1f}us/query × {n_queries} queries over "
        f"{off_s:.3f}s untraced workload)"
    )
    assert coverage >= 0.95, f"span coverage {coverage:.2%} below 95%"

    record = {
        "events": log.num_events,
        "queries": n_queries,
        "repeats": repeats,
        "propagation": True,   # traced side: ambient context + trace store
        "store_sample_every": STORE_SAMPLE_EVERY,
        "per_query_tax_us": tax_us,
        "hit_us": hit_us,
        "workload_traced_s": on_s,
        "workload_untraced_s": off_s,
        "trace_overhead": overhead,
        "trace_coverage": coverage,
    }
    with open("BENCH_obs.json", "w") as f:
        json.dump(record, f, indent=1)

    # stamp the measured tax into every other committed benchmark record
    for path in sorted(glob.glob("BENCH_*.json")):
        if os.path.basename(path) == "BENCH_obs.json":
            continue
        with open(path) as f:
            data = json.load(f)
        data["trace_overhead"] = overhead
        with open(path, "w") as f:
            json.dump(data, f, indent=1)
    return rows


if __name__ == "__main__":
    _fast = "--fast" in sys.argv[1:]
    for r in run(write_json=not _fast, fast=_fast):
        print(",".join(str(x) for x in r))
