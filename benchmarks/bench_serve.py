"""Serving-tier benchmark — admission, coalescing, and SLO isolation under
concurrent load.

Drives :class:`repro.transport.TransportApp` directly (the HTTP framing
above it is protocol plumbing measured in the transport tests; the
contended paths — probe, admission, coalescing, the two lanes, the engine
— are all exercised here) with a mixed-traffic load test over an
out-of-core memmap log whose cold scans are genuinely expensive.

Measurements (CSV rows; ``BENCH_serve.json`` on direct invocation):

* **coalesce** — N identical concurrent cold requests execute the engine
  exactly once (asserted through ``EngineStats``: one execution, one full
  scan) and every fanned-out response is bit-identical to the leader's.
* **mixed_load** — ≥8 concurrent clients, ≥20% cold traffic (fresh
  windows, real streaming scans) against warm cached dashboards.  The
  contract: warm-lane p99 stays under 25 ms *while the cold lane is
  saturated* — cold scans never head-of-line-block warm traffic.
* **shed** — a starved tenant's over-quota requests get 429 + Retry-After
  instead of queueing.
* **identity** — transport responses equal the direct
  ``QueryService.query`` dict path (modulo execution provenance).
* **calibration** — the measured hot/cold boundary
  (``slo_hot_cutoff_s``: the geometric mean of warm-lane p99 and
  cold-lane median) consumed by ``planner.load_calibration``.
"""

from __future__ import annotations

import asyncio
import json
import os
import sys
import tempfile
import time

import numpy as np

# runnable directly (`python benchmarks/bench_serve.py`) without PYTHONPATH
_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

EVENTS = int(os.environ.get("BENCH_EVENTS", 1_000_000))
CLIENTS = int(os.environ.get("BENCH_CLIENTS", 12))
REQUESTS_PER_CLIENT = int(os.environ.get("BENCH_REQUESTS", 24))
COALESCE_N = 16
COLD_EVERY = 4  # every 4th request is a fresh cold window: 25% cold


def _pct(samples, q):
    return float(np.percentile(np.asarray(samples, dtype=np.float64), q))


def run(write_json: bool = False) -> list:
    """CSV rows; ``write_json=True`` (direct invocation only) also rewrites
    the committed ``BENCH_serve.json`` record — the aggregator's reduced
    ``--fast`` runs must not clobber it (same guard as bench_shard)."""
    from repro.data import ProcessSpec, generate_memmap_log
    from repro.query import QueryEngine
    from repro.query.planner import SLO_HOT_CUTOFF_S
    from repro.serve import QueryService
    from repro.transport import TransportApp, TransportConfig, canonical_payload

    rows = []
    results = {}
    tmp = tempfile.mkdtemp(prefix="graphpm_benchserve_")
    log = generate_memmap_log(
        os.path.join(tmp, "log"), EVENTS,
        ProcessSpec(num_activities=24, seed=17, horizon_days=120), seed=17,
    )
    t_all = np.concatenate([t for _, _, t in log.iter_chunks()])
    t_min, t_max = float(t_all[0]), float(t_all[-1])
    span = t_max - t_min
    del t_all

    # the log is out of the materialization budget: every fresh window is a
    # genuine streaming scan, which is exactly what the cold lane is for
    engine = QueryEngine(memory_budget_events=max(log.num_events // 4, 1))
    svc = QueryService(engine)
    svc.register("bpi", log)
    # pin the static boundary: this run *measures* the calibrated value and
    # must not read a previous run's BENCH_serve.json back as its input
    app = TransportApp(svc, TransportConfig(
        rate=100_000.0, burst=100_000.0, hot_cutoff_s=SLO_HOT_CUTOFF_S,
    ))
    results["events"] = log.num_events
    results["clients"] = CLIENTS
    results["requests_per_client"] = REQUESTS_PER_CLIENT

    rng = np.random.default_rng(3)

    def fresh_window():
        a = float(rng.uniform(0.0, 0.55))
        w = float(rng.uniform(0.25, 0.4))
        return (t_min + a * span, t_min + (a + w) * span)

    # -- 1. coalescing: N identical concurrent requests, one execution -------
    coalesce_req = {"log": "bpi", "sink": "dfg", "window": list(fresh_window())}
    before = engine.stats

    async def coalesce_phase():
        t0 = time.perf_counter()
        resps = await asyncio.gather(*[
            app.handle(coalesce_req) for _ in range(COALESCE_N)
        ])
        return resps, time.perf_counter() - t0

    resps, coalesce_wall = asyncio.run(coalesce_phase())
    after = engine.stats
    executions = after.executions - before.executions
    fanned = sum(1 for r in resps if r.headers["X-Coalesced"] == "1")
    payloads = [canonical_payload(r.payload) for r in resps]
    identical = all(r.status == 200 for r in resps) and all(
        p == payloads[0] for p in payloads
    )
    rows.append((
        "serve_coalesce", coalesce_wall * 1e6,
        f"n={COALESCE_N};executions={executions};fanout={fanned};"
        f"identical={identical}",
    ))
    results["coalesce"] = {
        "n": COALESCE_N,
        "executions": int(executions),
        "fanout": int(fanned),
        "wall_us": coalesce_wall * 1e6,
    }
    if executions != 1 or fanned != COALESCE_N - 1 or not identical:
        raise AssertionError(
            "coalescing contract violated: "
            f"executions={executions} fanout={fanned} identical={identical}"
        )

    # -- 2. mixed-traffic load test ------------------------------------------
    warm_reqs = [
        {"log": "bpi", "sink": "dfg"},
        {"log": "bpi", "sink": "histogram"},
        {"log": "bpi", "sink": "process_map", "top": 1.0},
    ]
    cold_windows = [
        fresh_window()
        for _ in range(CLIENTS * REQUESTS_PER_CLIENT // COLD_EVERY + CLIENTS)
    ]

    async def load_phase():
        for r in warm_reqs:  # pre-warm: the steady-state dashboard set
            assert (await app.handle(r)).status == 200
        lat = {"hot": [], "cold": []}
        overlapped = [0]

        async def client(ci):
            for j in range(REQUESTS_PER_CLIENT):
                seq = ci * REQUESTS_PER_CLIENT + j
                if seq % COLD_EVERY == 0:
                    req = {
                        "log": "bpi", "sink": "dfg",
                        "window": list(cold_windows[seq // COLD_EVERY]),
                    }
                else:
                    req = warm_reqs[seq % len(warm_reqs)]
                if app.scheduler.depth("cold") > 0:
                    overlapped[0] += 1
                t0 = time.perf_counter()
                resp = await app.handle(req, tenant=f"client{ci}")
                dt = time.perf_counter() - t0
                assert resp.status == 200, resp.payload
                lat[resp.headers["X-Lane"]].append(dt)
                await asyncio.sleep(0)  # yield: clients interleave

        t0 = time.perf_counter()
        await asyncio.gather(*[client(i) for i in range(CLIENTS)])
        return lat, time.perf_counter() - t0, overlapped[0]

    lat, load_wall, overlapped = asyncio.run(load_phase())
    warm_p50 = _pct(lat["hot"], 50)
    warm_p99 = _pct(lat["hot"], 99)
    cold_p50 = _pct(lat["cold"], 50)
    cold_p99 = _pct(lat["cold"], 99)
    cold_share = len(lat["cold"]) / max(len(lat["hot"]) + len(lat["cold"]), 1)
    total = len(lat["hot"]) + len(lat["cold"])
    rows.append((
        "serve_warm_lane", warm_p99 * 1e6,
        f"p50_us={warm_p50 * 1e6:.0f};p99_ms={warm_p99 * 1e3:.2f};"
        f"budget_ms=25",
    ))
    rows.append((
        "serve_cold_lane", cold_p50 * 1e6,
        f"p99_ms={cold_p99 * 1e3:.1f};share={cold_share:.2f}",
    ))
    rows.append((
        "serve_mixed_load", load_wall * 1e6,
        f"clients={CLIENTS};requests={total};"
        f"rps={total / max(load_wall, 1e-9):.0f};"
        f"overlapped={overlapped}",
    ))
    results["mixed_load"] = {
        "requests": total,
        "cold_share": cold_share,
        "warm_p50_us": warm_p50 * 1e6,
        "warm_p99_ms": warm_p99 * 1e3,
        "cold_p50_ms": cold_p50 * 1e3,
        "cold_p99_ms": cold_p99 * 1e3,
        "wall_s": load_wall,
        "rps": total / max(load_wall, 1e-9),
        "cold_overlapped_requests": overlapped,
    }
    if cold_share < 0.20:
        raise AssertionError(f"cold share {cold_share:.2f} below the 20% floor")
    if warm_p99 >= 0.025:
        raise AssertionError(
            f"warm-lane p99 {warm_p99 * 1e3:.2f} ms blew the 25 ms SLO "
            "while the cold lane was loaded"
        )

    # the SLO engine must reproduce the warm-lane verdict just asserted
    # from the live request_latency_seconds{lane=hot} series alone
    slo = svc.slo.evaluate()
    warm_obj = next(
        o for o in slo["objectives"] if o["name"] == "warm_latency"
    )
    rows.append((
        "serve_slo_verdict", (warm_obj["measured"] or 0.0) * 1e6,
        f"ok={warm_obj['ok']};budget_left="
        f"{warm_obj['error_budget_remaining']:.3f}",
    ))
    results["slo"] = {
        "warm_latency_ok": warm_obj["ok"],
        "warm_latency_measured_p99_ms": (warm_obj["measured"] or 0.0) * 1e3,
        "error_budget_remaining": warm_obj["error_budget_remaining"],
    }
    if warm_obj["ok"] is not True:
        raise AssertionError(
            "SLO engine disagrees with the measured warm-lane verdict: "
            f"{warm_obj}"
        )

    # -- 3. admission: a starved tenant sheds, never queues ------------------
    app.admission.set_quota("starved", rate=0.5, burst=4.0)

    async def shed_phase():
        out = []
        for _ in range(12):
            out.append(await app.handle(warm_reqs[0], tenant="starved"))
        return out

    shed_resps = asyncio.run(shed_phase())
    shed = [r for r in shed_resps if r.status == 429]
    retry_ok = all(float(r.headers["Retry-After"]) > 0 for r in shed)
    rows.append((
        "serve_shed", float(len(shed)),
        f"sent=12;shed={len(shed)};retry_after_ok={retry_ok}",
    ))
    results["shed"] = {"sent": 12, "shed": len(shed), "retry_after_ok": retry_ok}
    if len(shed) != 8 or not retry_ok:
        raise AssertionError(
            f"admission contract violated: shed={len(shed)} retry={retry_ok}"
        )

    # -- 4. bit-identity with the direct dict path ---------------------------
    probe_reqs = warm_reqs + [
        {"log": "bpi", "sink": "dfg", "window": list(cold_windows[0])},
        {"log": "bpi", "sink": "histogram", "window": list(cold_windows[1])},
    ]

    async def identity_phase():
        return [await app.handle(r) for r in probe_reqs]

    ident = all(
        canonical_payload(resp.payload) == canonical_payload(svc.query(req))
        for req, resp in zip(probe_reqs, asyncio.run(identity_phase()))
    )
    rows.append(("serve_identity", float(ident), f"requests={len(probe_reqs)}"))
    results["identity"] = {"requests": len(probe_reqs), "identical": ident}
    if not ident:
        raise AssertionError("transport response diverged from direct path")

    # -- 5. calibration: the measured hot/cold boundary ----------------------
    # The boundary should sit between what the hot lane actually delivers
    # and what a real cold scan costs: the geometric mean of warm-lane p99
    # and cold-lane median, clamped to the planner's rails.
    cutoff = float(np.sqrt(max(warm_p99, 1e-6) * max(cold_p50, 1e-6)))
    cutoff = min(max(cutoff, 1e-4), 2.0)
    results["calibration"] = {"slo_hot_cutoff_s": cutoff}
    rows.append((
        "serve_calibration", cutoff * 1e6,
        f"slo_hot_cutoff_s={cutoff:.6f};warm_p99_s={warm_p99:.6f};"
        f"cold_p50_s={cold_p50:.6f}",
    ))

    app.close()
    if not write_json:
        return rows
    with open("BENCH_serve.json", "w") as f:
        json.dump(results, f, indent=1)
    return rows


if __name__ == "__main__":
    if "--fast" in sys.argv:
        os.environ.setdefault("BENCH_EVENTS", "400000")
        EVENTS = int(os.environ.get("BENCH_EVENTS", EVENTS))
    for r in run(write_json=True):
        print(",".join(str(x) for x in r))
