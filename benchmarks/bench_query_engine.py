"""Query-engine benchmarks: what does the declarative layer cost, and what
do its pushdowns buy?

Three measurements, emitted as CSV rows (and ``BENCH_query.json``):

* **overhead** — ``Q.log(repo).dfg(backend=...)`` with a cold cache vs the
  hand-dispatched direct call.  The delta is fingerprint + canonicalize +
  plan; it must stay small relative to counting.
* **pushdown** — a 1/8-horizon dice on a memmap log (paper Experiment 2
  shape): the engine's row-range pushdown via the chunk time index vs a
  full-log scan.  Time should scale with the dice, not the log.
* **cache** — the same diced query re-issued: plan/result-cache hit
  latency vs cold execution.
* **calibration** — the measured numpy↔device crossover (``tiny_pairs``)
  and a machine-sized memory budget, written as the ``calibration`` section
  that :func:`repro.query.planner.load_calibration` feeds back into the
  cost model (ROADMAP "smarter cost model": measured thresholds when
  available, the static constants as fallback).
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

# runnable directly (`python benchmarks/bench_query_engine.py`) without
# PYTHONPATH
_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

EVENTS = int(os.environ.get("BENCH_EVENTS", 2_000_000))
REPEAT = 5


def _best(fn, n=REPEAT) -> float:
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(write_json: bool = False) -> list:
    """CSV rows; ``write_json=True`` (direct invocation only) also rewrites
    the committed ``BENCH_query.json`` record — the aggregator's reduced
    ``--fast`` runs must not clobber it (same guard as bench_delta)."""
    from repro.core import dfg_from_repository, streaming_dfg
    from repro.data import ProcessSpec, generate_memmap_log, generate_repository
    from repro.query import Q, QueryEngine

    rows = []
    results = {}

    # -- 1. planning overhead on an in-memory repository --------------------
    repo = generate_repository(5_000, ProcessSpec(num_activities=32, seed=11))
    direct_us = _best(lambda: dfg_from_repository(repo, backend="scatter"))

    eng = QueryEngine()

    def planned():
        eng.cache.clear()  # keep the executor honest: no result reuse
        Q.log(repo).using(eng).dfg(backend="scatter")

    planned_us = _best(planned)
    overhead_us = max(planned_us - direct_us, 0.0)
    rows.append((
        "query_overhead", planned_us,
        f"direct_us={direct_us:.0f};overhead_us={overhead_us:.0f};"
        f"ratio={planned_us / max(direct_us, 1):.2f}x",
    ))
    results["overhead"] = {
        "events": repo.num_events,
        "direct_us": direct_us,
        "planned_us": planned_us,
        "overhead_us": overhead_us,
    }

    # -- 2. predicate pushdown on a diced memmap log -------------------------
    tmp = tempfile.mkdtemp(prefix="graphpm_benchq_")
    log = generate_memmap_log(
        os.path.join(tmp, "log"), EVENTS,
        ProcessSpec(num_activities=64, seed=17, horizon_days=120), seed=17,
    )
    t_min, t_max = float(log.time[0]), float(log.time[-1])
    window = (t_min, t_min + (t_max - t_min) / 8.0)
    lo, hi = log.rows_for_window(*window)
    ooc = QueryEngine(memory_budget_events=0)  # always out-of-core

    def diced():
        ooc.cache.clear()
        Q.log(log).using(ooc).window(*window).dfg()

    diced_us = _best(diced, n=3)
    full_us = _best(lambda: streaming_dfg(log), n=3)
    rows.append((
        "query_pushdown_dice8", diced_us,
        f"diced_events={hi - lo};full_scan_us={full_us:.0f};"
        f"win={full_us / max(diced_us, 1):.2f}x",
    ))
    results["pushdown"] = {
        "events": log.num_events,
        "diced_events": hi - lo,
        "diced_us": diced_us,
        "full_scan_us": full_us,
    }

    # -- 3. plan/result cache hit ---------------------------------------------
    ooc.cache.clear()
    t0 = time.perf_counter()
    first = Q.log(log).using(ooc).window(*window).dfg()
    cold_us = (time.perf_counter() - t0) * 1e6
    hit_holder = {}

    def hit():
        hit_holder["r"] = Q.log(log).using(ooc).window(*window).dfg()

    hit_us = _best(hit)
    assert hit_holder["r"].from_cache and not first.from_cache
    assert (hit_holder["r"].value == first.value).all()
    rows.append((
        "query_cache_hit", hit_us,
        f"cold_us={cold_us:.0f};speedup={cold_us / max(hit_us, 1):.0f}x",
    ))
    results["cache"] = {"cold_us": cold_us, "hit_us": hit_us}

    # -- 4. cost-model calibration (consumed by planner.load_calibration) ----
    from repro.core.dfg import dfg as dfg_device
    from repro.core.dfg import dfg_numpy

    rng = np.random.default_rng(3)

    def measure_crossover(a_count: int) -> int:
        for n in (512, 1024, 2048, 4096, 8192):
            src = rng.integers(0, a_count, n).astype(np.int32)
            dst = rng.integers(0, a_count, n).astype(np.int32)
            valid = np.ones(n, dtype=bool)
            np_us = _best(lambda: dfg_numpy(src, dst, valid, a_count), n=3)
            dev_us = _best(
                lambda: dfg_device(
                    src, dst, valid, a_count, backend="scatter"
                ),
                n=3,
            )
            if dev_us <= np_us:
                return n
        return 8192  # device never won in the measured range

    # the crossover moves with the activity count (the device pays a fixed
    # (A, A) output cost): measure it at several sizes and emit both the
    # mid-size scalar (back-compat) and the fitted curve over
    # work = pairs × activities that resolve_threshold() interpolates
    curve_pts = []
    by_a = {}
    for a in (8, 32, 128):
        cx = measure_crossover(a)
        by_a[a] = cx
        curve_pts.append([cx * a, cx])
    crossover = by_a[32]
    # budget: a quarter of physical RAM at ~24 B/event (three columns +
    # canonicalization slack), inside the planner's sanity rails
    try:
        phys = os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_PHYS_PAGES")
        budget = max(min(phys // 4 // 24, 1 << 26), 1 << 20)
    except (ValueError, OSError, AttributeError):
        budget = 1 << 22
    results["calibration"] = {
        "tiny_pairs": int(crossover),
        "memory_budget_events": int(budget),
        # fitted per-backend crossover curve: tiny_pairs measured at
        # several problem sizes, keyed by work = pairs × activities, so the
        # planner interpolates instead of applying one scalar everywhere
        "curves": {
            "tiny_pairs": curve_pts,
        },
    }
    rows.append((
        "query_calibration", float(crossover),
        f"tiny_pairs={crossover};memory_budget_events={budget}",
    ))

    if not write_json:
        return rows
    with open("BENCH_query.json", "w") as f:
        json.dump(results, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run(write_json=True):
        print(",".join(str(x) for x in r))
