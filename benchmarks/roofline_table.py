"""Aggregate dry-run artifacts into the §Roofline table (markdown + CSV)."""

from __future__ import annotations

import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

COLS = [
    "arch", "shape", "mesh", "tag", "compute_s", "memory_s", "collective_s",
    "dominant_term", "useful_flops_ratio", "roofline_fraction",
    "per_device_gib", "fits_hbm", "num_collectives", "compile_s",
]


def load(tag: str = "baseline", mesh: str = "16x16") -> List[Dict]:
    rows = []
    if not os.path.isdir(ART):
        return rows
    for f in sorted(os.listdir(ART)):
        if not f.endswith(f"__{tag}.json"):
            continue
        d = json.load(open(os.path.join(ART, f)))
        if mesh and d.get("mesh") != mesh:
            continue
        d["per_device_gib"] = d.get("per_device_bytes", 0) / 2**30
        d["num_collectives"] = d.get("collectives", {}).get("num_collectives", 0)
        rows.append(d)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = (
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful_flops | roofline_frac | GiB/chip | fits |\n"
        "|---|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for d in rows:
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['compute_s']:.4f} | "
            f"{d['memory_s']:.4f} | {d['collective_s']:.4f} | "
            f"{d['dominant_term']} | {d['useful_flops_ratio']:.2f} | "
            f"{d['roofline_fraction']:.3f} | {d['per_device_gib']:.2f} | "
            f"{'✓' if d.get('fits_hbm') else '✗'} |"
        )
    return "\n".join(lines)


def run() -> list:
    out = []
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh=mesh)
        for d in rows:
            out.append((
                f"roofline_{d['arch']}_{d['shape']}_{mesh}",
                d["roofline_bound_s"] * 1e6,
                f"dominant={d['dominant_term']};frac={d['roofline_fraction']:.3f};"
                f"gib={d['per_device_gib']:.2f}",
            ))
    return out


if __name__ == "__main__":
    rows = load()
    print(to_markdown(rows))
