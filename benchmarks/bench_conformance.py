"""Conformance-tier benchmark: what do graph-native + streaming replay buy?

Discovers a reference model from a mined memmap log, then measures the
subsystem's three promises:

* **repeated conformance** — the engine's graph/cached path vs what every
  query used to cost (materialize the log, replay columnar): the first
  query pays once, every repeat is a cache hit / stored-table walk;
* **streaming replay** — one O(A² + chunk) pass for out-of-core logs
  (and the measured streaming↔materialize crossover
  ``planner.load_calibration`` feeds back into the cost model);
* **append + delta** — a 1% append replays only the suffix (rows_scanned
  asserted through engine stats) instead of the whole log.

Emits CSV rows (and ``BENCH_conformance.json`` on direct invocation only —
the aggregator's reduced ``--fast`` runs must not clobber the committed
2M-event record; same guard as bench_delta/bench_graph).
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

# runnable directly (`python benchmarks/bench_conformance.py`) without PYTHONPATH
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

EVENTS = int(os.environ.get("BENCH_EVENTS", 2_000_000))
REPEAT_QUERIES = 10
APPEND_FRACTION = 0.01


def _timed(fn, repeat: int = 1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn()
    return out, (time.perf_counter() - t0) * 1e6 / repeat


def run(write_json: bool = False) -> list:
    from repro.conformance import replay_fitness_graph, replay_fitness_streaming
    from repro.core.conformance import replay_fitness
    from repro.core.dfg import dfg_numpy
    from repro.core.discovery import discover_dependency_graph
    from repro.data import ProcessSpec, generate_memmap_log
    from repro.graph import build_graph
    from repro.query import Q, QueryEngine, fingerprint
    from repro.query.execute import repository_from_memmap

    rows = []
    tmp = tempfile.mkdtemp(prefix="graphpm_benchc_")
    log = generate_memmap_log(
        os.path.join(tmp, "log"), EVENTS,
        ProcessSpec(num_activities=64, seed=41, horizon_days=120), seed=41,
    )

    # reference model (pinned for every measurement below)
    repo = repository_from_memmap(log)
    s, d, v = repo.df_pairs()
    psi = dfg_numpy(s, d, v, repo.num_activities)
    starts, ends = repo.trace_boundaries()
    model = discover_dependency_graph(
        psi, repo.activity_names, starts, ends,
        min_count=max(EVENTS // 10_000, 1), min_dependency=0.3,
    )

    # -- what every query used to cost: materialize + columnar replay --------
    def recompute():
        return replay_fitness(repository_from_memmap(log), model)

    base, recompute_us = _timed(recompute)
    rows.append((
        "conformance_recompute", recompute_us,
        f"events={log.num_events};fitness={base.fitness:.4f}",
    ))

    # -- streaming: one O(A²+chunk) pass (the out-of-core path) --------------
    stream, streaming_us = _timed(
        lambda: replay_fitness_streaming(log, model)
    )
    assert np.array_equal(stream.trace_fitness, base.trace_fitness)
    rows.append((
        "conformance_streaming", streaming_us,
        f"recompute_us={recompute_us:.0f};"
        f"speedup={recompute_us / max(streaming_us, 1):.1f}x",
    ))

    # -- graph path: replay the stored event tables (no re-materialization) --
    g, build_us = _timed(lambda: build_graph(log))
    graph_res, graph_us = _timed(
        lambda: replay_fitness_graph(g, model), repeat=3
    )
    assert np.array_equal(graph_res.trace_fitness, base.trace_fitness)
    rows.append((
        "conformance_graph_replay", graph_us,
        f"build_us={build_us:.0f};recompute_us={recompute_us:.0f};"
        f"speedup={recompute_us / max(graph_us, 1):.1f}x",
    ))

    # -- repeated conformance through the engine (graph/cached path) ---------
    eng = QueryEngine(graph_crossover=1)
    eng.graphs.put(fingerprint(log), g)  # graph tier warm (built above)

    def engine_repeat():
        for _ in range(REPEAT_QUERIES):
            Q.log(log).using(eng).fitness(model)

    _, eng_total_us = _timed(engine_repeat)
    eng_q_us = eng_total_us / REPEAT_QUERIES
    repeat_speedup = recompute_us / max(eng_q_us, 1e-9)
    rows.append((
        "conformance_repeat_cached", eng_q_us,
        f"recompute_us={recompute_us:.0f};queries={REPEAT_QUERIES};"
        f"speedup={repeat_speedup:.1f}x",
    ))

    # -- append 1%: delta replay scans only the suffix -----------------------
    eng2 = QueryEngine(
        memory_budget_events=1, replay_crossover=1  # force streaming+delta
    )
    Q.log(log).using(eng2).fitness(model)
    rows_before = eng2.stats.rows_scanned
    n_app = max(int(EVENTS * APPEND_FRACTION), 1)
    rng = np.random.default_rng(43)
    last_t = float(np.asarray(log.time[-1]))
    grown = log.append(
        rng.integers(0, log.num_activities, n_app).astype(np.int32),
        rng.integers(0, log.num_traces, n_app).astype(np.int32),
        np.sort(rng.uniform(last_t, last_t + 86_400.0, n_app)),
    )
    _, delta_us = _timed(lambda: Q.log(grown).using(eng2).fitness(model))
    suffix_rows = eng2.stats.rows_scanned - rows_before
    assert eng2.stats.delta_hits == 1 and suffix_rows == n_app
    full, full_us = _timed(lambda: replay_fitness_streaming(grown, model))
    rows.append((
        "conformance_delta_append", delta_us,
        f"appended={n_app};rows_scanned={suffix_rows};"
        f"full_replay_us={full_us:.0f};"
        f"speedup={full_us / max(delta_us, 1):.1f}x",
    ))

    # -- alignments, batched per variant (mainstream behaviour: top-2000) ----
    from repro.conformance import align_repository
    from repro.core.variants import variant_filtered_repository

    ali_repo = variant_filtered_repository(repo, 2_000)
    ali, align_us = _timed(lambda: align_repository(ali_repo, model))
    rows.append((
        "conformance_alignments", align_us,
        f"variants={ali.variant_costs.shape[0]};"
        f"traces={ali.trace_cost.shape[0]};fitness={ali.fitness:.4f}",
    ))

    # -- the streaming↔materialize crossover the planner learns --------------
    # both paths are linear in E, so the measurement is a rate comparison:
    # if one streaming pass beats materialize+replay at this size it wins at
    # any size (crossover → clamp floor); otherwise materialization stays
    # preferred until the memory-budget rail (crossover → clamp ceiling)
    crossover = (
        1 << 18 if streaming_us < recompute_us else 1 << 26
    )
    rows.append((
        "replay_crossover", crossover,
        f"streaming_us={streaming_us:.0f};recompute_us={recompute_us:.0f}",
    ))

    if not write_json:
        return rows
    with open("BENCH_conformance.json", "w") as f:
        json.dump({
            "events": log.num_events,
            "num_activities": log.num_activities,
            "recompute_us": recompute_us,
            "streaming_us": streaming_us,
            "graph_build_us": build_us,
            "graph_replay_us": graph_us,
            "repeat_cached_us_per_query": eng_q_us,
            "repeat_cached_speedup": repeat_speedup,
            "delta_append_rows_scanned": suffix_rows,
            "delta_append_us": delta_us,
            "delta_full_replay_us": full_us,
            "alignments_us": align_us,
            "alignment_variants": int(ali.variant_costs.shape[0]),
            "calibration": {"replay_streaming_crossover": crossover},
        }, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run(write_json=True):
        print(",".join(str(x) for x in r))
