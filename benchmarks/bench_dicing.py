"""Paper Fig. 5 (Experiment 2): DFG time vs diced event count — Claim C3.

Accumulating time windows (paper: +1 day per round over ~4 months).  Two
systems, fixed resources:

* pm4py-equivalent baseline: parse/load the **full** log, then filter —
  time ≈ constant in the dice size (dominated by the full-log load);
* graph-store path: the per-chunk time index maps the window to a row
  range — time ∝ events *in the dice*.

The paper's crossover (~2M events, Neo4j slower beyond) came from Neo4j's
per-event metadata overhead; our columnar adaptation removes most of that,
so the graph path stays at-or-below the baseline all the way to the full
log — reported as a beyond-paper result, with the full-log ratio printed
so the C2-style overhead remains visible.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import InMemoryDFGBaseline, streaming_dfg
from repro.data import ProcessSpec, generate_memmap_log

EVENTS = int(os.environ.get("BENCH_EVENTS", 2_000_000))
ROUNDS = 8


def run() -> list:
    rows = []
    tmp = tempfile.mkdtemp(prefix="graphpm_fig5_")
    spec = ProcessSpec(num_activities=64, seed=13, horizon_days=120)
    log = generate_memmap_log(os.path.join(tmp, "log"), EVENTS, spec, seed=13)
    t_min = float(log.time[0])
    t_max = float(log.time[-1])

    # preload baseline's full in-memory representation ONCE per query round
    # (pm4py re-loads the XES per analysis session; we charge the load to
    # each query, exactly like the paper's per-round measurements)
    for r in range(1, ROUNDS + 1):
        t1 = t_min + (t_max - t_min) * r / ROUNDS
        window = (t_min, t1)
        lo, hi = log.rows_for_window(*window)
        n_diced = hi - lo

        t0 = time.perf_counter()
        base = InMemoryDFGBaseline()
        rows_iter = (
            (int(c), int(a), float(t))
            for A, C, T in log.iter_chunks()
            for a, c, t in zip(A, C, T)
        )
        psi_b = base.dfg(rows_iter, log.num_activities, time_window=window)
        t_base = (time.perf_counter() - t0) * 1e6

        t0 = time.perf_counter()
        psi_g = streaming_dfg(log, time_window=window)
        t_graph = (time.perf_counter() - t0) * 1e6

        match = bool((psi_b == psi_g).all())
        rows.append((
            f"fig5_round{r}", t_graph,
            f"diced_events={n_diced};graph_us={t_graph:.0f};"
            f"pm4py_us={t_base:.0f};speedup={t_base / max(t_graph, 1):.2f}x;"
            f"match={match}"
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
