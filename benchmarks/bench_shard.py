"""Sharded graph tier benchmark — the paper's horizontal scaling story on
case-partitioned CSR shards.

The workload is a synthetic log *larger than the single-host graph
materialization budget*: the single-host graph tier can only build a
topology-only graph for it, so a windowed ``backend="graph"`` query is
impossible on one host — while the sharded tier (K case-partitioned
shards, each in budget, merged by a pure aligned psum) computes it, and
bit-identically to the Algorithm 1 streaming oracle on a dicing that fits.

Measurements (CSV rows; ``BENCH_shard.json`` on direct invocation):

* **partition** — two-pass case-wise split throughput (``case % K``).
* **out_of_budget** — the capability gap: windowed pinned-graph query
  raises on the single host, succeeds sharded, equals the oracle.
* **warm** — repeated *varying-window* DFG / process-map queries once the
  shard CSRs are resident: per-shard vectorized table serves vs the
  single host's only option, a streaming rescan.  Target ≥ K/2×.
* **append** — per-shard delta resume: an append touches one shard; the
  re-query extends only that shard's graph (suffix rows only).
* **two_tier** — the store's disk tier: with ``max_graphs < K`` evicted
  shard snapshots spill and page back in (O(metadata)) instead of
  rebuilding (O(E)).
* **calibration** — the measured sharded-vs-single-host crossover
  (``sharded_single_crossover`` + fitted curve) consumed by
  ``planner.load_calibration``.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

# runnable directly (`python benchmarks/bench_shard.py`) without PYTHONPATH
_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

EVENTS = int(os.environ.get("BENCH_EVENTS", 1_200_000))
K = int(os.environ.get("BENCH_SHARDS", 8))
WINDOWS = 10


def _median_us(samples):
    return float(np.median(np.asarray(samples, dtype=np.float64))) * 1e6


def run(write_json: bool = False) -> list:
    """CSV rows; ``write_json=True`` (direct invocation only) also rewrites
    the committed ``BENCH_shard.json`` record — the aggregator's reduced
    ``--fast`` runs must not clobber it (same guard as bench_delta)."""
    from repro.core.streaming import streaming_dfg
    from repro.data import ProcessSpec, generate_memmap_log
    from repro.graph import partition_memmap_log
    from repro.query import Q, QueryEngine, QueryPlanError

    rows = []
    results = {}
    tmp = tempfile.mkdtemp(prefix="graphpm_benchshard_")
    log = generate_memmap_log(
        os.path.join(tmp, "log"), EVENTS,
        ProcessSpec(num_activities=48, seed=23, horizon_days=240), seed=23,
    )
    # the single-host materialization budget: a third of the log, so the
    # whole log is out of budget while each of the K shards fits easily
    budget = max(log.num_events // 3, 1)
    results["events"] = log.num_events
    results["num_shards"] = K
    results["budget_events"] = budget

    # -- 1. case-wise partitioning -------------------------------------------
    t0 = time.perf_counter()
    sharded = partition_memmap_log(log, K, os.path.join(tmp, "shards"))
    part_us = (time.perf_counter() - t0) * 1e6
    rows.append((
        "shard_partition", part_us,
        f"events={log.num_events};k={K};"
        f"events_per_s={log.num_events / (part_us / 1e6):.0f}",
    ))
    results["partition_us"] = part_us

    t_all = np.concatenate([t for _, _, t in log.iter_chunks()])
    t_min, t_max = float(t_all[0]), float(t_all[-1])
    span = t_max - t_min

    # -- 2. the capability gap: out-of-budget windowed graph query -----------
    single = QueryEngine(memory_budget_events=budget)
    shard_eng = QueryEngine(memory_budget_events=budget)
    w_gap = (t_min + span / 8.0, t_min + 3.0 * span / 8.0)
    try:
        Q.log(log).using(single).window(*w_gap).dfg(backend="graph")
        single_raised = False
    except QueryPlanError:
        single_raised = True  # topology-only graph: no event tables
    r_shard = (
        Q.log(sharded).using(shard_eng).window(*w_gap)
        .dfg(backend="sharded-graph")
    )
    oracle = streaming_dfg(log, time_window=w_gap)
    identical = bool(np.array_equal(r_shard.value, oracle))
    rows.append((
        "shard_out_of_budget", float(identical),
        f"single_host_graph_raises={single_raised};"
        f"sharded_equals_oracle={identical}",
    ))
    results["out_of_budget"] = {
        "single_host_graph_raises": single_raised,
        "sharded_equals_oracle": identical,
        "window": list(w_gap),
    }
    if not (single_raised and identical):
        raise AssertionError(
            "sharded tier capability contract violated: "
            f"raises={single_raised} identical={identical}"
        )

    # -- 3. warm varying-window queries: resident shard CSRs vs streaming ----
    # Exact repeats are O(1) result-cache hits on both paths, so the honest
    # warm workload is *fresh* windows against warm state: the sharded tier
    # answers each from the K resident per-shard event tables (vectorized),
    # the single host has no materialized/graph option out of budget and
    # must stream the window's rows (Python chunk loop) every time.
    rng = np.random.default_rng(5)
    windows = []
    for _ in range(WINDOWS):
        a = rng.uniform(0.0, 0.6)
        windows.append((t_min + a * span, t_min + (a + 0.35) * span))

    shard_t, single_t = [], []
    for w in windows:
        t0 = time.perf_counter()
        rs = (
            Q.log(sharded).using(shard_eng).window(*w)
            .dfg(backend="sharded-graph")
        )
        shard_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        ru = Q.log(log).using(single).window(*w).dfg()
        single_t.append(time.perf_counter() - t0)
        assert not rs.from_cache and not ru.from_cache
        assert np.array_equal(rs.value, ru.value)
    sharded_us = _median_us(shard_t)
    single_us = _median_us(single_t)
    speedup = single_us / max(sharded_us, 1e-9)
    rows.append((
        "shard_warm_window_dfg", sharded_us,
        f"single_streaming_us={single_us:.0f};k={K};"
        f"speedup={speedup:.2f}x",
    ))

    pm_shard, pm_single = [], []
    for w in windows[: max(WINDOWS // 2, 2)]:
        w = (w[0] + span / 64.0, w[1] - span / 64.0)  # fresh plan keys
        t0 = time.perf_counter()
        Q.log(sharded).using(shard_eng).window(*w).process_map(
            backend="sharded-graph"
        )
        pm_shard.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        Q.log(log).using(single).window(*w).process_map()
        pm_single.append(time.perf_counter() - t0)
    pm_sharded_us = _median_us(pm_shard)
    pm_single_us = _median_us(pm_single)
    pm_speedup = pm_single_us / max(pm_sharded_us, 1e-9)
    rows.append((
        "shard_warm_window_process_map", pm_sharded_us,
        f"single_streaming_us={pm_single_us:.0f};"
        f"speedup={pm_speedup:.2f}x",
    ))
    workload_speedup = (single_us + pm_single_us) / max(
        sharded_us + pm_sharded_us, 1e-9
    )
    rows.append((
        "shard_warm_workload", workload_speedup,
        f"dfg={speedup:.2f}x;process_map={pm_speedup:.2f}x;"
        f"target={K / 2.0:.0f}x",
    ))
    results["warm"] = {
        "windows": len(windows),
        "dfg_sharded_us": sharded_us,
        "dfg_single_streaming_us": single_us,
        "dfg_speedup": speedup,
        "process_map_sharded_us": pm_sharded_us,
        "process_map_single_streaming_us": pm_single_us,
        "process_map_speedup": pm_speedup,
        "workload_speedup": workload_speedup,
        "target_speedup": K / 2.0,
    }

    # -- 4. append → per-shard delta resume ----------------------------------
    rows_before = shard_eng.stats.rows_scanned
    batch = 64
    cases = np.full(batch, 7, dtype=np.int32)  # one owning shard: 7 % K
    acts = np.arange(batch, dtype=np.int32) % sharded.num_activities
    times = t_max + 1.0 + np.arange(batch, dtype=np.float64)
    grown = sharded.append(acts, cases, times)
    t0 = time.perf_counter()
    Q.log(grown).using(shard_eng).dfg(backend="sharded-graph")
    requery_us = (time.perf_counter() - t0) * 1e6
    delta_rows = shard_eng.stats.rows_scanned - rows_before
    rows.append((
        "shard_append_requery", requery_us,
        f"appended={batch};rows_rescanned={delta_rows};"
        f"owning_shard_only={delta_rows == batch}",
    ))
    results["append"] = {
        "appended": batch,
        "rows_rescanned": int(delta_rows),
        "requery_us": requery_us,
    }

    # -- 5. two-tier store: spill + page-in vs rebuild -----------------------
    spill_eng = QueryEngine(
        memory_budget_events=budget,
        max_graphs=max(K // 2, 1),
        graph_spill_dir=os.path.join(tmp, "spill"),
    )
    Q.log(sharded).using(spill_eng).dfg(backend="sharded-graph")
    t0 = time.perf_counter()
    Q.log(sharded).using(spill_eng).window(*w_gap).dfg(
        backend="sharded-graph"
    )
    pagein_us = (time.perf_counter() - t0) * 1e6
    gs = spill_eng.graphs.stats
    rebuild_eng = QueryEngine(
        memory_budget_events=budget, max_graphs=max(K // 2, 1),
    )
    Q.log(sharded).using(rebuild_eng).dfg(backend="sharded-graph")
    t0 = time.perf_counter()
    Q.log(sharded).using(rebuild_eng).window(*w_gap).dfg(
        backend="sharded-graph"
    )
    rebuild_us = (time.perf_counter() - t0) * 1e6
    rows.append((
        "shard_two_tier_pagein", pagein_us,
        f"spills={gs.spills};pageins={gs.pageins};"
        f"rebuild_us={rebuild_us:.0f};"
        f"win={rebuild_us / max(pagein_us, 1):.2f}x",
    ))
    results["two_tier"] = {
        "spills": int(gs.spills),
        "pageins": int(gs.pageins),
        "pagein_query_us": pagein_us,
        "rebuild_query_us": rebuild_us,
    }

    # -- 6. calibration: sharded-vs-single-host crossover --------------------
    # Below the crossover a one-host materialized count beats the K-way
    # merge's fixed per-query cost.  Estimate it from this machine's
    # measured numbers: the warm sharded per-query cost equals a
    # single-host scan of (cost × measured single-host throughput) events.
    window_rows = int(np.mean([
        log.rows_for_window(*w)[1] - log.rows_for_window(*w)[0]
        for w in windows
    ]))
    single_events_per_s = window_rows / max(single_us / 1e6, 1e-9)
    crossover = int(max(sharded_us, 1.0) / 1e6 * single_events_per_s)
    a_count = log.num_activities
    results["calibration"] = {
        "sharded_single_crossover": crossover,
        "curves": {
            "sharded_single_crossover": [
                [float(crossover) * a_count / 2.0, crossover],
                [float(crossover) * a_count * 2.0, crossover],
            ],
        },
    }
    rows.append((
        "shard_calibration", float(crossover),
        f"sharded_single_crossover={crossover};"
        f"single_events_per_s={single_events_per_s:.0f}",
    ))

    if not write_json:
        return rows
    with open("BENCH_shard.json", "w") as f:
        json.dump(results, f, indent=1)
    return rows


if __name__ == "__main__":
    if "--fast" in sys.argv:
        os.environ.setdefault("BENCH_EVENTS", "200000")
        EVENTS = int(os.environ.get("BENCH_EVENTS", EVENTS))
    for r in run(write_json=True):
        print(",".join(str(x) for x in r))
