"""Graph-tier benchmark: what does materializing the :DF relation buy?

Builds the event-knowledge graph of a mined memmap log once, then re-issues
the serve tier's topology queries two ways:

* **columnar recompute** — what every query used to cost: re-derive Ψ from
  the flat pair columns, then filter/traverse it;
* **graph** — the aggregated CSR answers the same query as a store lookup
  (DFG densify, neighborhood BFS, process-map sort).

Also measures build throughput and derives the columnar↔graph crossover
(the repeat-query count above which paying the build wins) that
``planner.load_calibration`` feeds back into the cost model.

Emits CSV rows (and ``BENCH_graph.json`` on direct invocation).
"""

from __future__ import annotations

import json
import math
import os
import sys
import tempfile
import time

import numpy as np

# runnable directly (`python benchmarks/bench_graph.py`) without PYTHONPATH
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

EVENTS = int(os.environ.get("BENCH_EVENTS", 2_000_000))
REPEAT_QUERIES = 20


def _timed(fn, repeat: int = 1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn()
    return out, (time.perf_counter() - t0) * 1e6 / repeat


def run(write_json: bool = False) -> list:
    """CSV rows; ``write_json=True`` (direct invocation only) also rewrites
    the committed ``BENCH_graph.json`` record — the aggregator's reduced
    ``--fast`` runs must not clobber it (same guard as bench_delta)."""
    from repro.core.dfg import dfg_numpy
    from repro.data import ProcessSpec, generate_memmap_log
    from repro.graph import (
        build_graph,
        derive_neighborhood,
        derive_process_map,
        csr_from_dense,
    )
    from repro.query.execute import repository_from_memmap

    rows = []
    tmp = tempfile.mkdtemp(prefix="graphpm_benchg_")
    log = generate_memmap_log(
        os.path.join(tmp, "log"), EVENTS,
        ProcessSpec(num_activities=64, seed=31, horizon_days=120), seed=31,
    )

    # -- build throughput ----------------------------------------------------
    g, build_us = _timed(lambda: build_graph(log))
    rows.append((
        "graph_build", build_us,
        f"events={log.num_events};nnz={g.adj.nnz};"
        f"events_per_s={log.num_events / (build_us / 1e6):.0f}",
    ))

    # -- DFG: store lookup vs columnar recompute -----------------------------
    repo = repository_from_memmap(log)
    src, dst, valid = repo.df_pairs()

    def columnar_dfg():
        return dfg_numpy(src, dst, valid, repo.num_activities)

    psi_cold, columnar_dfg_us = _timed(columnar_dfg, repeat=3)
    psi_graph, graph_dfg_us = _timed(g.psi, repeat=3)
    assert np.array_equal(psi_cold, psi_graph)
    rows.append((
        "dfg_from_graph", graph_dfg_us,
        f"recompute_us={columnar_dfg_us:.0f};"
        f"speedup={columnar_dfg_us / max(graph_dfg_us, 1):.1f}x",
    ))

    # -- repeated topology queries: graph vs columnar ------------------------
    names = g.activity_names
    centers = [names[i % len(names)] for i in range(REPEAT_QUERIES)]

    def columnar_neigh():
        # what the engine's columnar path does per query: recount Ψ from
        # the pair columns, then traverse — every query pays the recount
        for c in centers:
            adj = csr_from_dense(
                dfg_numpy(src, dst, valid, repo.num_activities)
            )
            derive_neighborhood(adj, adj.transpose(), names, c, 2, "both")

    def graph_neigh():
        for c in centers:
            derive_neighborhood(g.adj, g.radj, names, c, 2, "both")

    _, col_neigh_us = _timed(columnar_neigh)
    _, g_neigh_us = _timed(graph_neigh)
    col_q = col_neigh_us / REPEAT_QUERIES
    g_q = g_neigh_us / REPEAT_QUERIES
    neigh_speedup = col_q / max(g_q, 1e-9)
    rows.append((
        "neighborhood_repeat", g_q,
        f"columnar_us={col_q:.0f};queries={REPEAT_QUERIES};"
        f"speedup={neigh_speedup:.1f}x",
    ))

    def columnar_pm():
        psi = dfg_numpy(src, dst, valid, repo.num_activities)
        counts = np.bincount(
            repo.event_activity, minlength=repo.num_activities
        ).astype(np.int64)
        return derive_process_map(csr_from_dense(psi), counts, names, 0.2)

    pm_cold, col_pm_us = _timed(columnar_pm, repeat=3)
    pm_graph, g_pm_us = _timed(
        lambda: derive_process_map(g.adj, g.node_counts, names, 0.2),
        repeat=3,
    )
    assert pm_cold.edges == pm_graph.edges
    pm_speedup = col_pm_us / max(g_pm_us, 1e-9)
    rows.append((
        "process_map_repeat", g_pm_us,
        f"columnar_us={col_pm_us:.0f};speedup={pm_speedup:.1f}x",
    ))

    # -- the columnar↔graph crossover the planner learns ---------------------
    saving_us = max(col_q - g_q, 1.0)
    crossover = max(1, math.ceil(build_us / saving_us))
    rows.append((
        "graph_crossover", crossover,
        f"build_us={build_us:.0f};per_query_saving_us={saving_us:.0f}",
    ))

    if not write_json:
        return rows
    with open("BENCH_graph.json", "w") as f:
        json.dump({
            "events": log.num_events,
            "num_activities": log.num_activities,
            "nnz": g.adj.nnz,
            "build_us": build_us,
            "columnar_dfg_us": columnar_dfg_us,
            "graph_dfg_us": graph_dfg_us,
            "neighborhood_columnar_us_per_query": col_q,
            "neighborhood_graph_us_per_query": g_q,
            "neighborhood_speedup": neigh_speedup,
            "process_map_columnar_us": col_pm_us,
            "process_map_graph_us": g_pm_us,
            "process_map_speedup": pm_speedup,
            "calibration": {"graph_repeat_crossover": crossover},
        }, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run(write_json=True):
        print(",".join(str(x) for x in r))
