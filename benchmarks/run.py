"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Set ``BENCH_EVENTS`` to scale
the Fig.4/Fig.5 logs (default 2M events ≈ the paper's dicing range start).
Use ``--fast`` for a reduced smoke pass (CI).
"""

from __future__ import annotations

import os
import sys
import time

# `python benchmarks/run.py` (direct path) puts benchmarks/ itself on
# sys.path instead of the repo root that `python -m benchmarks.run` gets
# from the cwd; add the root (and src/, so PYTHONPATH=src is optional)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    if "--fast" in sys.argv:
        os.environ.setdefault("BENCH_EVENTS", "200000")
    print("name,us_per_call,derived")
    t0 = time.time()
    from benchmarks import (
        bench_conformance,
        bench_delta,
        bench_dfg_example,
        bench_dicing,
        bench_graph,
        bench_kernels,
        bench_memory_scaling,
        bench_multilog,
        bench_obs,
        bench_query_engine,
        bench_serve,
        bench_shard,
        roofline_table,
    )

    for mod, label in (
        (bench_dfg_example, "table1"),
        (bench_memory_scaling, "fig4"),
        (bench_dicing, "fig5"),
        (bench_kernels, "kernels"),
        (bench_query_engine, "query"),
        (bench_delta, "delta"),
        (bench_multilog, "multilog"),
        (bench_graph, "graph"),
        (bench_conformance, "conformance"),
        (bench_obs, "obs"),
        (bench_shard, "shard"),
        (bench_serve, "serve"),
        (roofline_table, "roofline"),
    ):
        try:
            for row in mod.run():
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{label}_ERROR,0,{e!r}", flush=True)
    print(f"total_wall,{(time.time() - t0) * 1e6:.0f},seconds="
          f"{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
