"""Delta-plan benchmark: what does append-awareness buy a live dashboard?

Appends 1% to a mined memmap log and re-issues the same queries three ways:

* **recompute** — cold cache: the full O(E) streaming rescan every append
  used to force;
* **delta** — the engine proves the change append-only (prefix-preserving
  fingerprint) and resumes the cached Ψ + open-case tails over just the
  appended suffix;
* **free rewrite** — a window entirely inside the old time range: the
  append cannot touch it, the cached result is served without any scan.

Emits CSV rows (and ``BENCH_delta.json``).
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

# runnable directly (`python benchmarks/bench_delta.py`) without PYTHONPATH
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

EVENTS = int(os.environ.get("BENCH_EVENTS", 2_000_000))
APPEND_FRACTION = 0.01


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def run(write_json: bool = False) -> list:
    """CSV rows; ``write_json=True`` (direct invocation only) also rewrites
    the committed ``BENCH_delta.json`` record — the aggregator's reduced
    ``--fast`` runs must not clobber it."""
    from repro.core.streaming import streaming_dfg
    from repro.data import ProcessSpec, generate_memmap_log
    from repro.query import Q, QueryEngine

    rows = []
    tmp = tempfile.mkdtemp(prefix="graphpm_benchd_")
    log = generate_memmap_log(
        os.path.join(tmp, "log"), EVENTS,
        ProcessSpec(num_activities=64, seed=23, horizon_days=120), seed=23,
    )

    eng = QueryEngine(memory_budget_events=0)  # streaming-first: resumable
    _, cold_us = _timed(lambda: Q.log(log).using(eng).dfg())

    # a windowed dashboard query over the middle half of the old horizon
    ts = np.asarray(log.time)
    w0 = float(np.quantile(ts, 0.25))
    w1 = float(np.quantile(ts, 0.75))
    _, win_cold_us = _timed(lambda: Q.log(log).using(eng).window(w0, w1).dfg())

    # -- append 1% (time-ordered, reusing case ids → boundary pairs) ---------
    n_app = max(int(EVENTS * APPEND_FRACTION), 1)
    rng = np.random.default_rng(7)
    act = rng.integers(0, log.num_activities, n_app).astype(np.int32)
    case = rng.integers(0, log.num_traces, n_app).astype(np.int32)
    times = float(log.time[-1]) + np.sort(rng.uniform(0.0, 3600.0, n_app))
    grown, append_us = _timed(lambda: log.append(act, case, times))

    # -- delta: suffix-only scan ---------------------------------------------
    scan_before = eng.stats.rows_scanned
    delta_res, delta_us = _timed(lambda: Q.log(grown).using(eng).dfg())
    assert delta_res.physical.backend == "delta", delta_res.physical.describe()
    rows_scanned_delta = eng.stats.rows_scanned - scan_before

    # -- recompute: what a fingerprint-invalidated cache used to cost --------
    cold_eng = QueryEngine(memory_budget_events=0)
    full_res, recompute_us = _timed(lambda: Q.log(grown).using(cold_eng).dfg())
    assert np.array_equal(delta_res.value, full_res.value)
    assert np.array_equal(delta_res.value, streaming_dfg(grown))

    speedup = recompute_us / max(delta_us, 1.0)
    rows.append((
        "delta_append_1pct", delta_us,
        f"recompute_us={recompute_us:.0f};suffix_rows={n_app};"
        f"speedup={speedup:.1f}x",
    ))

    # -- free rewrite: window predates the append ----------------------------
    free_res, free_us = _timed(
        lambda: Q.log(grown).using(eng).window(w0, w1).dfg()
    )
    assert free_res.from_cache and eng.stats.delta_free_hits >= 1
    assert np.array_equal(
        free_res.value, streaming_dfg(grown, time_window=(w0, w1))
    )
    rows.append((
        "delta_free_rewrite", free_us,
        f"cold_us={win_cold_us:.0f};win={win_cold_us / max(free_us, 1):.0f}x",
    ))

    if not write_json:
        return rows
    with open("BENCH_delta.json", "w") as f:
        json.dump({
            "events": grown.num_events,
            "append_rows": n_app,
            "cold_full_scan_us": cold_us,
            "append_us": append_us,
            "delta_us": delta_us,
            "recompute_us": recompute_us,
            "speedup_vs_recompute": speedup,
            "windowed_cold_us": win_cold_us,
            "free_rewrite_us": free_us,
            "rows_scanned_delta": int(rows_scanned_delta),
        }, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run(write_json=True):
        print(",".join(str(x) for x in r))
