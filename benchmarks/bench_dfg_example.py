"""Paper Table 1: the worked example, verified + timed across backends."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    dfg_algorithm1,
    dfg_from_repository,
    paper_example_repo,
)
from repro.data import ProcessSpec, generate_repository

TABLE_1 = np.array(
    [[0, 1, 0, 0], [0, 0, 2, 0], [0, 0, 0, 1], [0, 0, 0, 0]], dtype=np.int64
)


def _time(fn, reps=3):
    fn()  # warm (jit)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def run() -> list:
    rows = []
    repo = paper_example_repo()
    psi = dfg_from_repository(repo)
    ok = bool((psi == TABLE_1).all())
    psi_lit, _ = dfg_algorithm1(repo.to_graph())
    ok_lit = bool((psi_lit == TABLE_1).all())
    rows.append(("table1_correct_columnar", _time(lambda: dfg_from_repository(repo)), f"match={ok}"))
    rows.append(
        ("table1_correct_algorithm1",
         _time(lambda: dfg_algorithm1(repo.to_graph())),
         f"match={ok_lit}")
    )

    # timing at a realistic size, per backend
    big = generate_repository(20_000, ProcessSpec(num_activities=64, seed=1))
    for backend in ("scatter", "onehot", "pallas"):
        us = _time(lambda b=backend: dfg_from_repository(big, backend=b))
        rows.append((f"dfg_{backend}_{big.num_events}ev", us,
                     f"events={big.num_events}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
