"""Paper Fig. 4 (Experiment 1): DFG vs available memory — Claims C1/C2.

The paper varies container RAM with the log fixed.  We fix a disk-resident
log *larger than the working-memory budget* and vary the budget:

* in-memory baseline (pm4py-equivalent): loads everything first → FAILS
  whenever budget < in-memory log footprint (C1's pm4py OOM);
* graph-store streaming path: peak memory ≈ chunk size (budget-driven),
  succeeds at every budget; more memory (bigger chunks) → faster (the
  paper's "increasing memory reduces Neo4j time");
* at ample memory on the *full* log the in-memory path is competitive/
  faster (C2) — the graph tier pays chunk/carry overhead.

Peak memory measured with tracemalloc (python+numpy allocations).
"""

from __future__ import annotations

import os
import tempfile
import time
import tracemalloc

import numpy as np

from repro.core import InMemoryDFGBaseline, streaming_dfg
from repro.core.baseline import LogTooLargeError
from repro.data import ProcessSpec, generate_memmap_log

EVENTS = int(os.environ.get("BENCH_EVENTS", 2_000_000))
BUDGETS_MB = [8, 32, 128, 512]


def _rows(log):
    for a, c, t in log.iter_chunks():
        for i in range(a.shape[0]):
            yield int(c[i]), int(a[i]), float(t[i])


def run() -> list:
    rows = []
    tmp = tempfile.mkdtemp(prefix="graphpm_fig4_")
    t0 = time.perf_counter()
    log = generate_memmap_log(
        os.path.join(tmp, "log"), EVENTS,
        ProcessSpec(num_activities=64, seed=11), seed=11,
    )
    rows.append(("fig4_loggen", (time.perf_counter() - t0) * 1e6,
                 f"events={log.num_events}"))
    disk_bytes = log.num_events * (4 + 4 + 8)

    for budget_mb in BUDGETS_MB:
        budget = budget_mb * 2**20

        # --- in-memory baseline under budget (python-object footprint) ----
        base = InMemoryDFGBaseline(memory_budget_bytes=budget)
        t0 = time.perf_counter()
        try:
            base.dfg(_rows(log), log.num_activities)
            status = "ok"
        except LogTooLargeError:
            status = "OOM"
        t_base = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig4_pm4py_{budget_mb}MB", t_base,
                     f"status={status};log_bytes={disk_bytes}"))

        # --- graph-store streaming path, chunk sized to the budget --------
        chunk_rows = max(1024, budget // (4 + 4 + 8) // 4)
        tracemalloc.start()
        t0 = time.perf_counter()
        psi = streaming_dfg(log, chunk_rows=chunk_rows)
        t_graph = (time.perf_counter() - t0) * 1e6
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        rows.append((
            f"fig4_graphpm_{budget_mb}MB", t_graph,
            f"status=ok;peak_mb={peak / 2**20:.1f};"
            f"within_budget={peak <= budget * 1.5};pairs={int(psi.sum())}"
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
