"""dfg_count kernel benchmark: interpret-mode validation + analytic v5e
roofline (no TPU in this container — the kernel's TPU cost is derived from
its block schedule, and the jnp backends give measured CPU baselines)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.dfg import dfg_onehot, dfg_scatter
from repro.kernels.dfg_count import dfg_count, dfg_count_ref, pick_blocks
from repro.roofline import hw


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_analytic_v5e(n_pairs: int, num_acts: int) -> dict:
    """Roofline terms of the kernel's block schedule on one v5e core."""
    be, ba = pick_blocks(num_acts)
    a_pad = max(ba, -(-num_acts // ba) * ba)
    e_pad = max(be, -(-n_pairs // be) * be)
    grid = (a_pad // ba) * (a_pad // ba) * (e_pad // be)
    # per grid step: build 2 one-hots (BE·BA cmp) + matmul 2·BE·BA·BA flops
    flops = grid * 2 * be * ba * ba
    # HBM traffic: ids re-read per (i,j) tile + output written once
    bytes_hbm = (a_pad // ba) ** 2 * e_pad * (4 + 4 + 1) + a_pad * a_pad * 4
    return {
        "block_e": be, "block_a": ba, "grid": grid,
        "compute_s": flops / hw.PEAK_FLOPS_BF16,
        "memory_s": bytes_hbm / hw.HBM_BW,
        "flops": flops,
    }


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for n_pairs, acts in [(100_000, 64), (1_000_000, 64), (1_000_000, 512)]:
        src = jnp.asarray(rng.integers(0, acts, n_pairs), jnp.int32)
        dst = jnp.asarray(rng.integers(0, acts, n_pairs), jnp.int32)
        valid = jnp.asarray(rng.random(n_pairs) < 0.9)

        t_scatter = _time(
            lambda: dfg_scatter(src, dst, valid, num_activities=acts).block_until_ready()
        )
        t_onehot = _time(
            lambda: dfg_onehot(src, dst, valid, num_activities=acts).block_until_ready()
        )
        rows.append((f"dfg_scatter_cpu_{n_pairs}x{acts}", t_scatter, "measured"))
        rows.append((f"dfg_onehot_cpu_{n_pairs}x{acts}", t_onehot, "measured"))

        # interpret-mode correctness on a subsample (full E is slow in python)
        sub = 20_000
        got = dfg_count(src[:sub], dst[:sub], valid[:sub],
                        num_activities=acts, interpret=True)
        want = dfg_count_ref(src[:sub], dst[:sub], valid[:sub],
                             num_activities=acts)
        ok = bool((np.asarray(got) == np.asarray(want)).all())

        a = kernel_analytic_v5e(n_pairs, acts)
        dom = "compute" if a["compute_s"] > a["memory_s"] else "memory"
        rows.append((
            f"dfg_pallas_v5e_{n_pairs}x{acts}",
            max(a["compute_s"], a["memory_s"]) * 1e6,
            f"analytic;blocks=({a['block_e']},{a['block_a']});"
            f"dominant={dom};interpret_match={ok}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
