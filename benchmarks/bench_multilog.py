"""Multi-source query benchmark: what does the union algebra buy?

Two measurements on a pair of memmap logs (half the ``BENCH_EVENTS`` budget
each), emitted as CSV rows (and ``BENCH_multilog.json``):

* **union vs pre-concatenated** — ``Q.logs(a, b).dfg()`` (per-branch scans,
  merged on the aligned vocabulary) against the same events mined as one
  pre-concatenated single-source repository.  The union pays alignment but
  keeps the branches separately cached — which is what makes the next
  measurement possible at all;
* **append to one branch** — after a 1% append to log ``a``, the union
  re-runs as one branch-``a`` delta scan (suffix only) plus a branch-``b``
  cache hit, vs the full recompute a pre-concatenated store would need;
* **compare vs hand-rolled** — ``Q.logs(a, b).compare()`` against issuing
  two independent single-log queries and differencing by hand (the numpy
  workflow the ISSUE's motivation wants to retire).

Correctness is asserted inline against the concatenation oracle.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

# runnable directly (`python benchmarks/bench_multilog.py`) without PYTHONPATH
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

EVENTS = int(os.environ.get("BENCH_EVENTS", 2_000_000))
APPEND_FRACTION = 0.01


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def run(write_json: bool = False) -> list:
    """CSV rows; ``write_json=True`` (direct invocation only) also rewrites
    the committed ``BENCH_multilog.json`` record."""
    from repro.core import concat_repositories
    from repro.data import ProcessSpec, generate_memmap_log
    from repro.query import Q, QueryEngine
    from repro.query.execute import repository_from_memmap

    rows = []
    tmp = tempfile.mkdtemp(prefix="graphpm_benchm_")
    half = max(EVENTS // 2, 1)
    logs = [
        generate_memmap_log(
            os.path.join(tmp, f"log{i}"), half,
            ProcessSpec(num_activities=48 + 16 * i, seed=31 + i,
                        horizon_days=120),
            seed=31 + i,
        )
        for i in range(2)
    ]
    log_a, log_b = logs

    eng = QueryEngine(memory_budget_events=0)  # streaming-first: resumable
    union_res, union_us = _timed(
        lambda: Q.logs((log_a, "a"), (log_b, "b")).using(eng).dfg()
    )
    assert union_res.physical.backend == "union"

    # the pre-concatenated alternative: one single-source store holding the
    # same events (materialized once, outside the timed region)
    concat = concat_repositories([
        ("a", repository_from_memmap(log_a, "a")),
        ("b", repository_from_memmap(log_b, "b")),
    ])
    cold = QueryEngine()
    concat_res, concat_us = _timed(lambda: Q.log(concat).using(cold).dfg())
    assert np.array_equal(union_res.value, concat_res.value)
    rows.append((
        "multilog_union_cold", union_us,
        f"preconcat_us={concat_us:.0f};"
        f"ratio={union_us / max(concat_us, 1):.2f}x",
    ))

    # -- append 1% to branch a: union re-runs as suffix-delta + cache hit ----
    n_app = max(int(EVENTS * APPEND_FRACTION), 1)
    rng = np.random.default_rng(5)
    act = rng.integers(0, log_a.num_activities, n_app).astype(np.int32)
    case = rng.integers(0, log_a.num_traces, n_app).astype(np.int32)
    times = float(log_a.time[-1]) + np.sort(rng.uniform(0.0, 3600.0, n_app))
    grown_a = log_a.append(act, case, times)

    scan_before = eng.stats.rows_scanned
    delta_res, delta_us = _timed(
        lambda: Q.logs((grown_a, "a"), (log_b, "b")).using(eng).dfg()
    )
    rows_scanned = eng.stats.rows_scanned - scan_before
    assert eng.stats.delta_hits >= 1 and rows_scanned == n_app

    cold2 = QueryEngine(memory_budget_events=0)
    full_res, recompute_us = _timed(
        lambda: Q.logs((grown_a, "a"), (log_b, "b")).using(cold2).dfg()
    )
    assert np.array_equal(delta_res.value, full_res.value)
    speedup = recompute_us / max(delta_us, 1.0)
    rows.append((
        "multilog_append_one_branch", delta_us,
        f"recompute_us={recompute_us:.0f};suffix_rows={n_app};"
        f"speedup={speedup:.1f}x",
    ))

    # -- compare vs two hand-rolled independent queries ----------------------
    cmp_eng = QueryEngine(memory_budget_events=0)
    cmp_res, compare_us = _timed(
        lambda: Q.logs((grown_a, "a"), (log_b, "b")).using(cmp_eng).compare()
    )

    def hand_rolled():
        e = QueryEngine(memory_budget_events=0)
        pa = Q.log(grown_a).using(e).dfg().value
        pb = Q.log(log_b).using(e).dfg().value
        names = sorted(
            set(grown_a.activity_labels()) | set(log_b.activity_labels())
        )
        idx = {n: i for i, n in enumerate(names)}
        out = []
        for psi, src in ((pa, grown_a), (pb, log_b)):
            ids = np.asarray([idx[n] for n in src.activity_labels()])
            m = np.zeros((len(names), len(names)), np.int64)
            m[np.ix_(ids, ids)] = psi
            out.append(m)
        return out[0], out[1], out[1] - out[0]

    (ha, hb, hdiff), hand_us = _timed(hand_rolled)
    assert np.array_equal(cmp_res.value.psis[0], ha)
    assert np.array_equal(cmp_res.value.psis[1], hb)
    assert np.array_equal(cmp_res.value.diffs[1], hdiff)
    rows.append((
        "multilog_compare", compare_us,
        f"hand_rolled_us={hand_us:.0f};"
        f"ratio={compare_us / max(hand_us, 1):.2f}x",
    ))

    if not write_json:
        return rows
    with open("BENCH_multilog.json", "w") as f:
        json.dump({
            "events_total": log_a.num_events + log_b.num_events + n_app,
            "append_rows": n_app,
            "union_cold_us": union_us,
            "preconcat_us": concat_us,
            "union_delta_us": delta_us,
            "union_recompute_us": recompute_us,
            "delta_speedup": speedup,
            "rows_scanned_delta": int(rows_scanned),
            "compare_us": compare_us,
            "hand_rolled_us": hand_us,
        }, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run(write_json=True):
        print(",".join(str(x) for x in r))
