"""The transport tier — admission, coalescing, two-lane scheduling, HTTP.

Pins: token-bucket math and 429 + Retry-After shedding (quota and queue
bounds); exactly-one engine execution per coalesced group (asserted
through ``EngineStats``); the stale-fanout regression — an append that
moves a log's fingerprint splits pre-/post-append waiters into different
coalescing groups, so a result computed from old bytes is never fanned
out past the append; SLO lane classification and warm-lane isolation
under a saturated cold lane; bit-identity of every transport response
with the direct ``QueryService.query`` dict; transport health in the
engine's own metrics registry; the NDJSON stream round-trip; the HTTP
endpoints end to end; and the measured ``slo_hot_cutoff_s`` calibration
path."""

import asyncio
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.views import AccessPolicy, ActivityView
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.query import QueryEngine
from repro.query.planner import SLO_HOT_CUTOFF_S, load_calibration
from repro.serve import QueryService, RequestProbe
from repro.transport import (
    AdmissionController,
    TokenBucket,
    TransportApp,
    TransportConfig,
    TransportServer,
    canonical_payload,
    iter_ndjson,
    reassemble_ndjson,
)

EVENTS = 6_000


def run(coro):
    return asyncio.run(coro)


class GatedService(QueryService):
    """QueryService whose ``query`` can be held at a barrier — before or
    after the engine executes — so tests can freeze requests mid-flight
    deterministically."""

    def __init__(self, engine=None):
        super().__init__(engine)
        self.gate = threading.Event()
        self.gate.set()
        self.calls = []
        self._calls_lock = threading.Lock()
        self.gate_pred = lambda request: False
        self.gate_after_execute = False
        self.gate_first_call_only = False

    def query(self, request, trace_context=None):
        with self._calls_lock:
            self.calls.append(dict(request))
            nth = len(self.calls)
        gated = self.gate_pred(request) and not (
            self.gate_first_call_only and nth > 1
        )
        if gated and not self.gate_after_execute:
            assert self.gate.wait(timeout=30), "gate timeout"
        out = super().query(request, trace_context)
        if gated and self.gate_after_execute:
            assert self.gate.wait(timeout=30), "gate timeout"
        return out


@pytest.fixture()
def repo():
    return generate_repository(300, ProcessSpec(seed=11), seed=11)


@pytest.fixture()
def memmap_log(tmp_path):
    return generate_memmap_log(
        str(tmp_path / "log"), EVENTS,
        ProcessSpec(num_activities=10, seed=5, horizon_days=30), seed=5,
    )


def make_app(service, **cfg):
    cfg.setdefault("hot_cutoff_s", SLO_HOT_CUTOFF_S)
    return TransportApp(service, TransportConfig(**cfg))


# -- admission control --------------------------------------------------------

def test_token_bucket_math():
    b = TokenBucket(rate=10.0, burst=5.0, now=0.0)
    assert b.take(0.0, 5.0) == 0.0  # full burst admitted
    assert b.take(0.0, 1.0) == pytest.approx(0.1)  # empty: 1 token at 10/s
    # refill is continuous: at t=0.05 the bucket holds 0.5 tokens
    assert b.take(0.05, 1.0) == pytest.approx(0.05)
    assert b.take(1.0, 1.0) == 0.0  # refilled well past 1 token
    assert b.tokens < b.burst  # and capped at burst, never beyond
    assert TokenBucket(rate=0.0, burst=0.0, now=0.0).take(1.0) == float("inf")


def test_admission_controller_per_tenant():
    ac = AdmissionController(rate=1.0, burst=2.0)
    assert ac.admit("a") is None
    assert ac.admit("a") is None
    wait = ac.admit("a")  # burst spent
    assert wait is not None and 0 < wait <= 1.0
    assert ac.admit("b") is None  # tenants are isolated
    ac.set_quota("paid", rate=1000.0, burst=1000.0)
    assert all(ac.admit("paid") is None for _ in range(100))
    assert ac.tenants() == 3


def test_quota_shed_maps_to_429_with_retry_after(repo):
    svc = QueryService()
    svc.register("bpi", repo)
    app = make_app(svc, rate=1.0, burst=2.0)

    async def go():
        req = {"log": "bpi", "sink": "dfg"}
        r1 = await app.handle(req, tenant="t")
        r2 = await app.handle(req, tenant="t")
        r3 = await app.handle(req, tenant="t")
        return r1, r2, r3

    r1, r2, r3 = run(go())
    app.close()
    assert r1.status == 200 and r2.status == 200
    assert r3.status == 429
    assert float(r3.headers["Retry-After"]) > 0
    assert r3.payload["retry_after_s"] > 0


# -- SLO classification -------------------------------------------------------

def _probe(cached=False, delta=False, cost=1.0):
    return RequestProbe(
        sink="dfg", names=("x",), fingerprint="f", policy_token="p",
        plan_token="k", backend="stream", cached=cached, delta_hint=delta,
        estimated_cost_s=cost, coalescable=True,
    )


def test_lane_classification(repo):
    svc = QueryService()
    svc.register("bpi", repo)
    app = make_app(svc, hot_cutoff_s=0.01)
    assert app.classify(_probe(cached=True, cost=9.0)) == "hot"
    assert app.classify(_probe(delta=True, cost=9.0)) == "hot"
    assert app.classify(_probe(cost=0.005)) == "hot"
    assert app.classify(_probe(cost=0.5)) == "cold"
    app.close()


def test_explicit_cutoff_wins_over_calibration(repo):
    svc = QueryService()
    svc.register("bpi", repo)
    app = TransportApp(svc, TransportConfig(hot_cutoff_s=0.123))
    assert app.hot_cutoff_s == 0.123
    app.close()


def test_slo_cutoff_calibration(tmp_path):
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps({"calibration": {"slo_hot_cutoff_s": 0.02}}))
    assert load_calibration(serve_path=str(p))["slo_hot_cutoff_s"] == 0.02
    # out-of-range measurements are clamped to the rails, not trusted
    p.write_text(json.dumps({"calibration": {"slo_hot_cutoff_s": 99.0}}))
    assert load_calibration(serve_path=str(p))["slo_hot_cutoff_s"] == 2.0
    # no artifact -> static fallback
    missing = str(tmp_path / "nope" / "BENCH_serve.json")
    assert (
        load_calibration(serve_path=missing)["slo_hot_cutoff_s"]
        == SLO_HOT_CUTOFF_S
    )


# -- coalescing ---------------------------------------------------------------

def test_coalesced_group_executes_exactly_once(memmap_log):
    engine = QueryEngine(memory_budget_events=1_000)  # force real scans
    svc = GatedService(engine)
    svc.register("live", memmap_log)
    svc.gate_pred = lambda r: r.get("sink") == "dfg"
    svc.gate.clear()
    app = make_app(svc)
    req = {"log": "live", "sink": "dfg"}
    before = engine.stats

    async def go():
        tasks = [asyncio.create_task(app.handle(req)) for _ in range(16)]
        # let the leader reach the gate and every follower join its group
        while len(svc.calls) < 1 or len(app.coalescer) < 1:
            await asyncio.sleep(0.01)
        await asyncio.sleep(0.05)
        svc.gate.set()
        return await asyncio.gather(*tasks)

    resps = run(go())
    after = engine.stats
    app.close()
    assert all(r.status == 200 for r in resps)
    # exactly one engine execution, one full scan, for 16 identical requests
    assert len(svc.calls) == 1
    assert after.executions - before.executions == 1
    assert after.rows_scanned - before.rows_scanned == memmap_log.num_events
    coalesced = [r for r in resps if r.headers["X-Coalesced"] == "1"]
    assert len(coalesced) == 15
    payloads = [canonical_payload(r.payload) for r in resps]
    assert all(p == payloads[0] for p in payloads)


def test_distinct_plans_do_not_coalesce(repo):
    svc = GatedService()
    svc.register("bpi", repo)
    app = make_app(svc)

    async def go():
        r1 = await app.handle({"log": "bpi", "sink": "dfg"})
        r2 = await app.handle({"log": "bpi", "sink": "histogram"})
        return r1, r2

    r1, r2 = run(go())
    app.close()
    assert r1.status == r2.status == 200
    assert len(svc.calls) == 2


def test_append_splits_coalescing_groups(memmap_log):
    """The stale-fanout regression: a leader whose result was computed
    from fingerprint F must not fan out to a waiter that enqueued after an
    append moved the log to F'."""
    svc = GatedService()
    svc.register("live", memmap_log)
    svc.gate_pred = lambda r: r.get("sink") == "histogram"
    svc.gate_after_execute = True  # freeze AFTER executing, BEFORE fanout
    svc.gate_first_call_only = True
    svc.gate.clear()
    app = make_app(svc)
    req = {"log": "live", "sink": "histogram"}
    old_events = memmap_log.num_events
    t_last = 10.0 * 365 * 24 * 3600.0  # far past the generated horizon

    fp_before = svc.probe(req).fingerprint

    async def go():
        t1 = asyncio.create_task(app.handle(req))
        while len(svc.calls) < 1:  # leader has computed, holding at gate
            await asyncio.sleep(0.01)
        await asyncio.sleep(0.05)
        # live append while the leader's group is still open
        svc.append({
            "log": "live",
            "activity": [0, 1, 2],
            "case": [0, 0, 0],
            "time": [t_last, t_last + 1, t_last + 2],
        })
        # post-append request: new fingerprint, must NOT join the group
        t2 = asyncio.create_task(app.handle(req))
        r2 = await t2
        svc.gate.set()
        r1 = await t1
        return r1, r2

    r1, r2 = run(go())
    app.close()
    fp_after = svc.probe(req).fingerprint
    assert fp_before != fp_after  # append moved the fingerprint
    assert r1.status == r2.status == 200
    assert len(svc.calls) == 2  # two groups, two executions
    assert r2.headers["X-Coalesced"] == "0"
    # the leader's payload is the pre-append data; the post-append waiter
    # sees the appended rows
    assert sum(r1.payload["counts"]) == old_events
    assert sum(r2.payload["counts"]) == old_events + 3


# -- two-lane scheduling ------------------------------------------------------

def test_queue_bound_sheds_with_retry_after(repo):
    svc = GatedService()
    svc.register("bpi", repo)
    svc.gate_pred = lambda r: True
    svc.gate.clear()
    app = make_app(
        svc, hot_cutoff_s=1e-9, cold_workers=1, max_depth_cold=1
    )

    async def go():
        t1 = asyncio.create_task(app.handle({"log": "bpi", "sink": "dfg"}))
        while app.scheduler.depth("cold") < 1:
            await asyncio.sleep(0.01)
        # lane full: a distinct cold request is shed, not queued
        r2 = await app.handle({"log": "bpi", "sink": "histogram"})
        svc.gate.set()
        r1 = await t1
        return r1, r2

    r1, r2 = run(go())
    assert r1.status == 200
    assert r2.status == 429
    assert float(r2.headers["Retry-After"]) > 0
    snap = svc.engine.metrics_snapshot()
    assert snap['transport_shed_total{reason=queue}'] >= 1
    app.close()


def test_warm_lane_isolated_from_saturated_cold_lane(repo):
    svc = GatedService()
    svc.register("bpi", repo)
    app = make_app(
        svc, hot_cutoff_s=1e-9, cold_workers=1, max_depth_cold=4
    )
    warm_req = {"log": "bpi", "sink": "dfg"}

    async def go():
        warm0 = await app.handle(warm_req)  # populate the cache
        assert warm0.headers["X-Lane"] == "cold"  # uncached -> cold
        svc.gate_pred = lambda r: r.get("sink") == "histogram"
        svc.gate.clear()
        cold = asyncio.create_task(
            app.handle({"log": "bpi", "sink": "histogram"})
        )
        while app.scheduler.depth("cold") < 1:
            await asyncio.sleep(0.01)
        t0 = time.perf_counter()
        warm = await app.handle(warm_req)  # cached -> hot lane
        warm_latency = time.perf_counter() - t0
        assert not cold.done()  # cold lane still saturated
        svc.gate.set()
        await cold
        return warm, warm_latency

    warm, warm_latency = run(go())
    app.close()
    assert warm.status == 200
    assert warm.headers["X-Lane"] == "hot"
    assert warm.payload["from_cache"] is True
    assert warm_latency < 1.0  # never queued behind the blocked cold scan


# -- bit-identity with the direct path ----------------------------------------

def test_transport_responses_bit_identical_to_direct_path(repo, tmp_path):
    other = generate_repository(200, ProcessSpec(seed=12), seed=12)
    svc = QueryService()
    svc.register("bpi", repo)
    svc.register("other", other)
    app = make_app(svc)
    center = svc.query({"log": "bpi", "sink": "dfg"})["names"][0]
    requests = [
        {"log": "bpi", "sink": "dfg"},
        {"log": "bpi", "sink": "histogram"},
        {"log": "bpi", "sink": "variants", "k": 5},
        {"log": "bpi", "sink": "process_map", "top": 1.0},
        {"log": "bpi", "sink": "neighborhood", "activity": center, "k": 2},
        {"log": "bpi", "sink": "fitness"},
        {"log": "bpi", "sink": "alignments"},
        {"logs": ["bpi", "other"], "sink": "compare"},
    ]

    async def go():
        return [await app.handle(r) for r in requests]

    resps = run(go())
    app.close()
    for req, resp in zip(requests, resps):
        assert resp.status == 200, req
        assert canonical_payload(resp.payload) == canonical_payload(
            svc.query(req)
        ), req


# -- error mapping ------------------------------------------------------------

def test_error_mapping(repo):
    view = ActivityView(mapping={})
    svc = QueryService()
    svc.register("bpi", repo)
    svc.register("sealed", repo, AccessPolicy(view=view))
    app = make_app(svc)

    async def go():
        return (
            await app.handle({"log": "nope", "sink": "dfg"}),
            await app.handle({"log": "sealed", "sink": "variants"}),
            await app.handle({"log": "bpi", "sink": "wat"}),
            await app.handle({"sink": "dfg"}),
        )

    unknown, denied, bad_sink, no_log = run(go())
    app.close()
    assert unknown.status == 404
    assert denied.status == 403
    assert bad_sink.status == 400
    assert no_log.status == 404
    assert "error" in unknown.payload and "detail" in unknown.payload


# -- transport health in the engine registry ----------------------------------

def test_transport_metrics_in_engine_registry(repo):
    svc = QueryService()
    svc.register("bpi", repo)
    app = make_app(svc, rate=1.0, burst=1.0)

    async def go():
        await app.handle({"log": "bpi", "sink": "dfg"}, tenant="t")
        await app.handle({"log": "bpi", "sink": "dfg"}, tenant="t")  # shed
        return await app.handle({"sink": "metrics"})

    resp = run(go())
    app.close()
    assert resp.status == 200
    snap = resp.payload["metrics"]
    assert snap['transport_requests_total{lane=hot}'] >= 1
    assert snap['transport_shed_total{reason=quota}'] >= 1
    assert snap['transport_coalesce_groups_total'] >= 1
    assert 'transport_queue_depth{lane=cold}' in snap
    assert snap['request_latency_seconds{lane=hot}']["count"] >= 1


# -- NDJSON streaming ---------------------------------------------------------

def test_ndjson_round_trip_exact():
    payload = {
        "sink": "alignments", "fitness": 0.93, "log": "bpi",
        "deviations": [{"edge": ["a", "b"], "count": 3}] * 4,
        "names": ["a", "b"], "nested": {"k": [1, 2]},  # inner lists stay put
    }
    lines = list(iter_ndjson(payload))
    assert json.loads(lines[-1]) == {"end": True}
    assert reassemble_ndjson(lines) == payload
    with pytest.raises(ValueError):
        reassemble_ndjson(lines[:-1])  # truncated stream is detected
    with pytest.raises(ValueError):
        reassemble_ndjson([])


# -- HTTP end to end ----------------------------------------------------------

def _http(method, url, body=None, headers=None):
    data = json.dumps(body).encode() if body is not None else None
    req = urllib.request.Request(
        url, data=data, method=method, headers=headers or {}
    )
    try:
        with urllib.request.urlopen(req, timeout=30) as f:
            return f.status, dict(f.headers), f.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


def test_http_server_end_to_end(repo):
    svc = QueryService()
    svc.register("bpi", repo)
    app = make_app(svc)
    app.admission.set_quota("starved", rate=0.001, burst=1.0)
    direct = svc.query({"log": "bpi", "sink": "dfg"})

    async def go():
        srv = TransportServer(app)
        await srv.start()
        loop = asyncio.get_running_loop()

        def exercise():
            out = {}
            out["query"] = _http(
                "POST", srv.address + "/query",
                {"log": "bpi", "sink": "dfg"},
            )
            out["stream"] = _http(
                "POST", srv.address + "/query/stream",
                {"log": "bpi", "sink": "dfg"},
            )
            out["metrics"] = _http("GET", srv.address + "/metrics")
            out["live"] = _http(
                "GET",
                srv.address + "/stream/metrics?interval=0.01&count=2",
            )
            out["healthz"] = _http("GET", srv.address + "/healthz")
            out["missing"] = _http("GET", srv.address + "/nope")
            out["bad_json"] = _http(
                "POST", srv.address + "/query", {"log": "nope"},
            )
            _http("POST", srv.address + "/query",
                  {"log": "bpi", "sink": "dfg"},
                  headers={"X-Tenant": "starved"})
            out["shed"] = _http(
                "POST", srv.address + "/query",
                {"log": "bpi", "sink": "dfg"},
                headers={"X-Tenant": "starved"},
            )
            return out

        out = await loop.run_in_executor(None, exercise)
        await srv.stop()
        return out

    out = run(go())
    status, headers, body = out["query"]
    assert status == 200
    assert headers["X-Lane"] in ("hot", "cold")
    assert canonical_payload(json.loads(body)) == canonical_payload(direct)

    status, headers, body = out["stream"]
    assert status == 200
    assert headers["Content-Type"] == "application/x-ndjson"
    reassembled = reassemble_ndjson(body.decode().splitlines())
    assert canonical_payload(reassembled) == canonical_payload(direct)

    status, _, body = out["metrics"]
    assert status == 200
    assert b"transport_requests_total" in body
    assert b"transport_queue_depth" in body
    assert b"request_latency_seconds_bucket" in body

    status, _, body = out["live"]
    lines = body.decode().splitlines()
    assert status == 200
    assert json.loads(lines[0])["body"]["sink"] == "metrics"
    assert json.loads(lines[-1]) == {"end": True}

    assert out["healthz"][0] == 200
    assert out["missing"][0] == 404
    assert out["bad_json"][0] == 404  # unknown log through HTTP

    status, headers, body = out["shed"]
    assert status == 429
    assert float(headers["Retry-After"]) > 0


def test_http_append_round_trip(memmap_log):
    svc = QueryService()
    svc.register("live", memmap_log)
    old = memmap_log.num_events
    t_last = 10.0 * 365 * 24 * 3600.0

    async def go():
        srv = TransportServer(TransportApp(svc))
        await srv.start()
        loop = asyncio.get_running_loop()

        def exercise():
            appended = _http(
                "POST", srv.address + "/append",
                {"log": "live", "activity": [0, 1], "case": [0, 0],
                 "time": [t_last, t_last + 1]},
            )
            after = _http(
                "POST", srv.address + "/query",
                {"log": "live", "sink": "histogram"},
            )
            return appended, after

        appended, after = await loop.run_in_executor(None, exercise)
        await srv.stop()
        return appended, after

    appended, after = run(go())
    status, _, body = appended
    assert status == 200
    assert json.loads(body)["num_events"] == old + 2
    status, _, body = after
    assert status == 200
    assert sum(json.loads(body)["counts"]) == old + 2
