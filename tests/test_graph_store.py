"""repro.graph — the event-knowledge-graph tier.

Pins: CSR construction against the Algorithm 1 oracle, snapshot
persistence (build → save → load → append → extend ≡ fresh build, array
for array, with a prefix-preserving fingerprint), the graph-native sinks
(DFG / neighborhood / process map / path frequencies), the ``graph``
physical backend's bit-identity across windows / views / filters / unions,
the planner's columnar↔graph crossover, and the serving exposure with the
k-anonymity floor on process-map edges.
"""

import os

import numpy as np
import pytest

from repro.core import dfg_algorithm1, dfg_numpy, paper_example_repo
from repro.core.repository import EventRepository, concat_repositories
from repro.core.streaming import streaming_dfg
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.graph import (
    GraphStore,
    build_graph,
    csr_from_dense,
    dense_from_csr,
    derive_neighborhood,
    derive_process_map,
    extend_graph,
    load_graph,
    neighborhood,
    path_frequencies,
    process_map,
    save_graph,
)
from repro.graph.store import _proves_append_only
from repro.query import Q, QueryEngine, QueryPlanError
from repro.query.cache import (
    fingerprint_memmap,
    parse_memmap_fingerprint,
    prefix_digest,
)
from repro.query.planner import load_calibration


@pytest.fixture()
def engine():
    # crossover pinned so tests don't depend on the committed BENCH record
    return QueryEngine(graph_crossover=3)


@pytest.fixture(scope="module")
def repo():
    return generate_repository(500, ProcessSpec(num_activities=13, seed=17))


@pytest.fixture()
def mmlog(tmp_path):
    return generate_memmap_log(
        str(tmp_path / "log"), 20_000,
        ProcessSpec(num_activities=14, seed=21), seed=21,
    )


def _append_batch(log, n, seed=1, new_activity=False):
    rng = np.random.default_rng(seed)
    hi = log.num_activities + (1 if new_activity else 0)
    act = rng.integers(0, hi, n).astype(np.int32)
    if new_activity:
        act[0] = hi - 1  # make sure the new id actually occurs
    case = rng.integers(0, log.num_traces, n).astype(np.int32)
    times = float(log.time[-1]) + np.sort(rng.uniform(0.0, 100.0, n))
    return log.append(act, case, times)


def _assert_same_csr(a, b):
    np.testing.assert_array_equal(np.asarray(a.indptr), np.asarray(b.indptr))
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.counts), np.asarray(b.counts))


# ---------------------------------------------------------------------------
# construction — CSR ≡ Algorithm 1
# ---------------------------------------------------------------------------


def test_graph_psi_matches_algorithm1_oracle():
    repo = paper_example_repo()
    want, _acts = dfg_algorithm1(repo.to_graph())
    g = build_graph(repo)
    assert g.activity_names == repo.activity_names
    np.testing.assert_array_equal(g.psi(), want)
    np.testing.assert_array_equal(dense_from_csr(g.adj), want)
    np.testing.assert_array_equal(dense_from_csr(g.radj), want.T)


@pytest.mark.parametrize("backend", ["numpy", "scatter", "onehot", "pallas"])
def test_build_backends_agree(repo, backend):
    src, dst, valid = repo.df_pairs()
    want = dfg_numpy(src, dst, valid, repo.num_activities)
    g = build_graph(repo, backend=backend)
    np.testing.assert_array_equal(g.psi(), want)


def test_csr_structure_and_node_tables(repo):
    g = build_graph(repo)
    a = repo.num_activities
    assert g.adj.indptr.shape == (a + 1,)
    assert np.all(np.diff(g.adj.indptr) >= 0)
    for i in range(a):
        row = g.adj.indices[g.adj.indptr[i] : g.adj.indptr[i + 1]]
        assert np.all(np.diff(row) > 0)  # ascending, no duplicates
    assert np.all(g.adj.counts > 0)
    _assert_same_csr(g.radj, g.adj.transpose())
    np.testing.assert_array_equal(
        g.node_counts, np.bincount(repo.event_activity, minlength=a)
    )
    # :OF_TYPE expansion reproduces events_of_activity
    for i, name in enumerate(repo.activity_names):
        np.testing.assert_array_equal(
            np.sort(g.events_of_activity(i)), repo.events_of_activity(name)
        )
    # :BELONGS_TO rows cover the canonical order exactly
    assert g.case_indptr[0] == 0 and g.case_indptr[-1] == repo.num_events
    for t in range(repo.num_traces):
        lo, hi = g.events_of_case(t)
        assert np.all(repo.event_trace[lo:hi] == t)


def test_sparse_aggregation_matches_dense(repo):
    import repro.graph.build as build_mod

    src, dst, valid = repo.df_pairs()
    want = csr_from_dense(dfg_numpy(src, dst, valid, repo.num_activities))
    got = build_mod._aggregate_pairs_sparse(
        src, dst, valid, repo.num_activities
    )
    _assert_same_csr(got, want)


def test_segment_count_kernel_matches_bincount():
    import jax.numpy as jnp

    from repro.kernels.segment_count import segment_count

    rng = np.random.default_rng(3)
    ids = rng.integers(0, 200, 10_000).astype(np.int32)
    valid = rng.random(10_000) < 0.7
    out = segment_count(
        jnp.asarray(ids), jnp.asarray(valid), num_segments=200
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.bincount(ids[valid], minlength=200)
    )


def test_memmap_build_full_and_topology_only(mmlog):
    want = streaming_dfg(mmlog)
    full = build_graph(mmlog)
    topo = build_graph(mmlog, memory_budget_events=100)
    assert full.has_event_tables and not topo.has_event_tables
    np.testing.assert_array_equal(full.psi(), want)
    _assert_same_csr(full.adj, topo.adj)
    np.testing.assert_array_equal(full.node_counts, topo.node_counts)


# ---------------------------------------------------------------------------
# persistence — build → save → load → append → extend round-trip
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip_identical_arrays(repo, tmp_path):
    g = build_graph(repo)
    save_graph(g, str(tmp_path / "snap"))
    g2 = load_graph(str(tmp_path / "snap"))
    _assert_same_csr(g.adj, g2.adj)
    _assert_same_csr(g.radj, g2.radj)
    np.testing.assert_array_equal(g.node_counts, g2.node_counts)
    for f in ("event_activity", "event_trace", "event_time",
              "act_indptr", "act_events", "case_indptr"):
        np.testing.assert_array_equal(
            np.asarray(getattr(g, f)), np.asarray(getattr(g2, f))
        )
    assert g2.activity_names == g.activity_names
    assert (g2.num_events, g2.num_traces) == (g.num_events, g.num_traces)


@pytest.mark.parametrize("new_activity", [False, True])
def test_snapshot_append_extend_roundtrip(mmlog, tmp_path, new_activity):
    fp0 = fingerprint_memmap(mmlog)
    g = build_graph(mmlog, source_fp=fp0)
    save_graph(g, str(tmp_path / "snap"))

    grown = _append_batch(mmlog, 700, new_activity=new_activity)
    loaded = load_graph(str(tmp_path / "snap"))
    # the stored fingerprint is prefix-preserving: the proof recomputes the
    # prefix digest on the *current* bytes and matches the snapshot's
    old = parse_memmap_fingerprint(loaded.source_fp)
    assert old.num_events == mmlog.num_events
    assert prefix_digest(grown, old.num_events) == old.prefix
    assert _proves_append_only(loaded, grown)

    ext = extend_graph(loaded, grown)
    fresh = build_graph(grown)
    _assert_same_csr(ext.adj, fresh.adj)
    _assert_same_csr(ext.radj, fresh.radj)
    np.testing.assert_array_equal(ext.node_counts, fresh.node_counts)
    np.testing.assert_array_equal(
        np.asarray(ext.event_activity), np.asarray(fresh.event_activity)
    )
    np.testing.assert_array_equal(
        np.asarray(ext.act_events), np.asarray(fresh.act_events)
    )
    np.testing.assert_array_equal(
        np.asarray(ext.case_indptr), np.asarray(fresh.case_indptr)
    )
    assert ext.source_fp == fingerprint_memmap(grown)
    # an extended snapshot re-saves and still round-trips
    save_graph(ext, str(tmp_path / "snap"))
    again = load_graph(str(tmp_path / "snap"))
    _assert_same_csr(again.adj, fresh.adj)


def test_rewritten_log_fails_the_proof(mmlog, tmp_path):
    g = build_graph(mmlog, source_fp=fingerprint_memmap(mmlog))
    # rewrite a prefix byte in place: same shape growth afterwards
    arr = np.memmap(
        os.path.join(mmlog.path, "activity.i32"), dtype=np.int32, mode="r+",
        shape=(mmlog.num_events,),
    )
    arr[0] = (int(arr[0]) + 1) % mmlog.num_activities
    arr.flush()
    del arr
    grown = _append_batch(mmlog, 100)
    assert not _proves_append_only(g, grown)


def test_graph_store_hit_extend_rebuild(mmlog):
    store = GraphStore()
    fp0 = fingerprint_memmap(mmlog)
    g1 = store.graph_for(mmlog, fp0)
    assert store.graph_for(mmlog, fp0) is g1
    assert store.stats.hits == 1 and store.stats.builds == 1

    grown = _append_batch(mmlog, 500)
    fp1 = fingerprint_memmap(grown)
    g2 = store.graph_for(grown, fp1)
    assert store.stats.extends == 1 and store.stats.builds == 1
    _assert_same_csr(g2.adj, build_graph(grown).adj)
    # the superseded generation is dropped — its fingerprint names bytes
    # that no source will ever present again
    assert not store.peek(fp0) and store.peek(fp1)
    assert len(store) == 1


def test_graph_store_concurrent_requests_build_once(repo):
    import threading

    from repro.query.cache import fingerprint_repository

    store = GraphStore()
    fp = fingerprint_repository(repo)
    out, errors = [], []

    def worker():
        try:
            out.append(store.graph_for(repo, fp))
        except Exception as e:  # pragma: no cover - surfacing only
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert store.stats.builds == 1
    assert all(g is out[0] for g in out)


# ---------------------------------------------------------------------------
# graph-native sinks
# ---------------------------------------------------------------------------


def test_neighborhood_directions():
    repo = EventRepository.from_traces(
        [["a", "b", "c"], ["a", "b", "d"], ["x", "a"]],
        activity_vocab=["a", "b", "c", "d", "x"],
    )
    g = build_graph(repo)
    out = neighborhood(g, "a", k=1, direction="out")
    assert out.activities == ["a", "b"]
    assert out.hops == {"a": 0, "b": 1}
    inn = neighborhood(g, "a", k=1, direction="in")
    assert inn.activities == ["a", "x"]
    both = neighborhood(g, "a", k=2, direction="both")
    assert set(both.activities) == {"a", "b", "c", "d", "x"}
    assert both.hops["c"] == 2 and both.hops["x"] == 1
    # induced edges only span reached nodes, with exact counts
    assert ("a", "b", 2) in both.edges


def test_path_frequencies_match_matrix_powers(repo):
    g = build_graph(repo)
    psi = g.psi().astype(np.float64)
    s, d = repo.activity_names[0], repo.activity_names[3]
    i, j = 0, 3
    got = path_frequencies(g, s, d, max_hops=3)
    acc = np.eye(psi.shape[0])
    for hop in range(3):
        acc = acc @ psi
        assert got[hop] == acc[i, j]


def test_process_map_filtering_deterministic(repo):
    g = build_graph(repo)
    full = process_map(g, top=1.0)
    assert full.dropped_activities == 0 and full.dropped_edges == 0
    # edges sorted by count desc, ties by (src, dst)
    counts = [c for _, _, c in full.edges]
    assert counts == sorted(counts, reverse=True)
    psi = g.psi()
    names = g.activity_names
    for s, d, c in full.edges:
        assert psi[names.index(s), names.index(d)] == c

    some = process_map(g, top=0.3)
    assert len(some.activities) < len(full.activities)
    assert some.dropped_activities + len(some.activities) == len(
        full.activities
    )
    # kept nodes are the most frequent ones
    kept_min = min(
        g.node_counts[names.index(a)] for a in some.activities
    )
    dropped_max = max(
        (g.node_counts[i] for i, n in enumerate(names)
         if n not in some.activities and g.node_counts[i] > 0),
        default=0,
    )
    assert kept_min >= dropped_max


def test_process_map_validates_top(repo):
    g = build_graph(repo)
    with pytest.raises(ValueError):
        process_map(g, top=0.0)
    with pytest.raises(ValueError):
        process_map(g, top=1.5)


# ---------------------------------------------------------------------------
# engine: the `graph` physical backend — bit-identity
# ---------------------------------------------------------------------------


def _reference_dfg(repo, window=None, keep=None, view=None):
    from repro.core.dicing import pair_mask_for_window

    src, dst, valid = repo.df_pairs()
    if window is not None:
        valid = valid & pair_mask_for_window(repo, window)
    if keep is not None:
        ids = np.asarray([repo.activity_names.index(a) for a in keep])
        m = np.isin(repo.event_activity, ids)
        valid = valid & m[:-1] & m[1:]
    psi = dfg_numpy(src, dst, valid, repo.num_activities)
    if view is not None:
        psi = view.apply_to_dfg(psi, repo.activity_names)
    return psi


def test_graph_backend_dfg_equals_oracle(repo, engine):
    from repro.core import ActivityView

    names = repo.activity_names
    t0 = float(np.quantile(repo.event_time, 0.25))
    t1 = float(np.quantile(repo.event_time, 0.8))
    keep = names[1:6]
    view = ActivityView({a: f"g{i % 3}" for i, a in enumerate(names)})
    cases = [
        (Q.log(repo).using(engine), dict()),
        (Q.log(repo).using(engine).window(t0, t1), dict(window=(t0, t1))),
        (Q.log(repo).using(engine).activities(keep), dict(keep=keep)),
        (Q.log(repo).using(engine).view(view), dict(view=view)),
        (
            Q.log(repo).using(engine).window(t0, t1).activities(keep)
            .view(view),
            dict(window=(t0, t1), keep=keep, view=view),
        ),
    ]
    for q, ref_kw in cases:
        res = q.dfg(backend="graph")
        assert res.physical.backend == "graph"
        np.testing.assert_array_equal(res.value, _reference_dfg(repo, **ref_kw))


def test_graph_backend_on_union_equals_concat_oracle(engine):
    ra = generate_repository(300, ProcessSpec(num_activities=9, seed=4))
    rb = generate_repository(260, ProcessSpec(num_activities=11, seed=5))
    res = Q.logs((ra, "a"), (rb, "b")).using(engine).dfg(backend="graph")
    cat = concat_repositories([("a", ra), ("b", rb)])
    src, dst, valid = cat.df_pairs()
    want = dfg_numpy(src, dst, valid, cat.num_activities)
    np.testing.assert_array_equal(res.value, want)
    assert res.names == cat.activity_names
    # the per-branch sub-queries really ran on the graph store
    assert engine.graphs.stats.builds == 2


def test_graph_sinks_equal_columnar_everywhere(repo, engine):
    """process_map / neighborhood: graph backend ≡ every columnar backend,
    windowed and plain."""
    t0 = float(np.quantile(repo.event_time, 0.2))
    t1 = float(np.quantile(repo.event_time, 0.9))
    for q_kw in (dict(), dict(window=True)):
        def q():
            base = Q.log(repo).using(engine)
            return base.window(t0, t1) if q_kw else base

        want_pm = q().process_map(top=0.5, backend="numpy").value
        want_nb = q().neighborhood(
            repo.activity_names[2], k=2, direction="both", backend="numpy"
        ).value
        for backend in ("scatter", "pallas", "graph"):
            pm = q().process_map(top=0.5, backend=backend).value
            assert pm.activities == want_pm.activities
            np.testing.assert_array_equal(pm.node_counts, want_pm.node_counts)
            assert pm.edges == want_pm.edges
            nb = q().neighborhood(
                repo.activity_names[2], k=2, direction="both", backend=backend
            ).value
            assert nb == want_nb


def test_graph_sinks_streaming_vs_graph_on_memmap(mmlog):
    eng = QueryEngine(memory_budget_events=0, graph_crossover=10**6)
    cold = Q.log(mmlog).using(eng).process_map(top=0.4)
    assert cold.physical.backend == "streaming"
    hot = Q.log(mmlog).using(eng).process_map(top=0.4, backend="graph")
    assert hot.physical.backend == "graph"
    assert cold.value.activities == hot.value.activities
    assert cold.value.edges == hot.value.edges


def test_empty_window_short_circuits_graph_sinks(repo, engine):
    res = Q.log(repo).using(engine).window(5.0, 5.0).process_map(top=0.5)
    assert res.value.activities == [] and res.value.edges == []
    center = repo.activity_names[0]
    nb = Q.log(repo).using(engine).window(5.0, 5.0).neighborhood(center)
    assert nb.value.activities == [center] and nb.value.edges == []


def test_neighborhood_unknown_center_rejected(repo, engine):
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(engine).neighborhood("nope")
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(engine).neighborhood(
            repo.activity_names[0], direction="sideways"
        )


def test_graph_backend_rejects_barriers(repo, engine):
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(engine).top_variants(2).dfg(backend="graph")


def test_windowed_graph_on_out_of_core_rejected(mmlog):
    eng = QueryEngine(memory_budget_events=0)
    with pytest.raises(QueryPlanError):
        Q.log(mmlog).using(eng).window(0.0, 1e12).dfg(backend="graph")


# ---------------------------------------------------------------------------
# planner: the columnar↔graph crossover
# ---------------------------------------------------------------------------


def test_auto_routes_to_graph_after_crossover(repo):
    eng = QueryEngine(graph_crossover=3)
    names = repo.activity_names
    r1 = Q.log(repo).using(eng).neighborhood(names[0])
    r2 = Q.log(repo).using(eng).neighborhood(names[1])
    assert r1.physical.backend != "graph"
    assert r2.physical.backend != "graph"
    # third distinct topology miss crosses the threshold: graph built
    r3 = Q.log(repo).using(eng).neighborhood(names[2])
    assert r3.physical.backend == "graph"
    assert eng.graphs.stats.builds == 1
    # and every later topology query is a store lookup
    r4 = Q.log(repo).using(eng).process_map(top=0.5)
    assert r4.physical.backend == "graph"
    assert eng.graphs.stats.builds == 1
    assert eng.stats.graph_queries == 2


def test_cache_hits_do_not_advance_crossover(repo):
    eng = QueryEngine(graph_crossover=3)
    for _ in range(5):  # one miss + four hits
        Q.log(repo).using(eng).process_map(top=0.5)
    assert eng.graphs.stats.builds == 0


def test_append_keeps_graph_tier_warm(mmlog):
    eng = QueryEngine(memory_budget_events=0, graph_crossover=2)
    names0 = Q.log(mmlog).using(eng).histogram().names
    Q.log(mmlog).using(eng).neighborhood(names0[0])
    r = Q.log(mmlog).using(eng).neighborhood(names0[1])
    assert r.physical.backend == "graph"
    assert eng.graphs.stats.builds == 1
    grown = _append_batch(mmlog, 400)
    # new fingerprint, but the registered graph is extendable: stays graph
    r2 = Q.log(grown).using(eng).neighborhood(names0[1])
    assert r2.physical.backend == "graph"
    assert eng.graphs.stats.extends == 1 and eng.graphs.stats.builds == 1
    fresh = QueryEngine(memory_budget_events=0)
    want = Q.log(grown).using(fresh).neighborhood(names0[1])
    assert want.physical.backend == "streaming"
    assert r2.value == want.value


def test_graph_calibration_loaded_and_clamped(tmp_path, monkeypatch):
    from repro.query.planner import GRAPH_REPEAT_CROSSOVER

    monkeypatch.delenv("GRAPHPM_BENCH_GRAPH", raising=False)
    bench = tmp_path / "BENCH_graph.json"
    bench.write_text('{"calibration": {"graph_repeat_crossover": 7}}')
    cal = load_calibration(
        str(tmp_path / "nope.json"), graph_path=str(bench)
    )
    assert cal["graph_repeat_crossover"] == 7
    bench.write_text('{"calibration": {"graph_repeat_crossover": 100000}}')
    cal = load_calibration(str(tmp_path / "nope.json"), graph_path=str(bench))
    assert cal["graph_repeat_crossover"] == 64  # clamped
    # corrupt → static fallback
    bench.write_text("{not json")
    cal = load_calibration(str(tmp_path / "nope.json"), graph_path=str(bench))
    assert cal["graph_repeat_crossover"] == GRAPH_REPEAT_CROSSOVER
    # engine picks the measured crossover up through the env var
    bench.write_text('{"calibration": {"graph_repeat_crossover": 9}}')
    monkeypatch.setenv("GRAPHPM_BENCH_GRAPH", str(bench))
    assert QueryEngine().graph_crossover == 9
    assert QueryEngine(graph_crossover=2).graph_crossover == 2


# ---------------------------------------------------------------------------
# serving: exposure + the k-anonymity floor on process-map edges
# ---------------------------------------------------------------------------


def test_service_process_map_floor():
    from repro.core.views import AccessPolicy
    from repro.serve import QueryService

    repo = EventRepository.from_traces(
        [["a", "b"]] * 5 + [["a", "c"]],  # a→b ×5, a→c ×1
        activity_vocab=["a", "b", "c"],
    )
    svc = QueryService()
    svc.register("bpi", repo, AccessPolicy(min_group_count=3))
    out = svc.query({"log": "bpi", "sink": "process_map", "top": 1.0})
    assert ["a", "b", 5] in out["edges"]
    # a→c (count 1) and node c (count 1) are below the floor: gone
    assert all(e[2] >= 3 for e in out["edges"])
    assert "c" not in out["activities"]
    assert out["dropped_edges"] >= 1
    assert out["sink"] == "process_map" and out["log"] == "bpi"

    nb = svc.query(
        {"log": "bpi", "sink": "neighborhood", "activity": "a", "k": 1}
    )
    assert nb["edges"] == [["a", "b", 5]]
    assert nb["activities"] == ["a", "b"]  # c dropped with its only edge


def test_service_neighborhood_requires_activity():
    from repro.serve import QueryService

    svc = QueryService()
    svc.register("bpi", paper_example_repo())
    with pytest.raises(KeyError):
        svc.query({"log": "bpi", "sink": "neighborhood"})
