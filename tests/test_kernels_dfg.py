"""Pallas dfg_count kernel vs pure-jnp oracle: shape/dtype sweeps + property
tests, all in interpret mode on CPU (per the kernel-validation protocol)."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.dfg_count import (
    dfg_count,
    dfg_count_diced,
    dfg_count_diced_ref,
    dfg_count_ref,
    pick_blocks,
)


def _random_pairs(rng, n, a):
    src = rng.integers(0, a, size=n).astype(np.int32)
    dst = rng.integers(0, a, size=n).astype(np.int32)
    valid = rng.random(n) < 0.8
    return src, dst, valid


# -- shape sweep -------------------------------------------------------------
@pytest.mark.parametrize("n_pairs", [0, 1, 7, 128, 1000, 5000])
@pytest.mark.parametrize("num_acts", [1, 3, 26, 130, 257])
def test_kernel_matches_ref_shapes(n_pairs, num_acts):
    rng = np.random.default_rng(n_pairs * 1000 + num_acts)
    src, dst, valid = _random_pairs(rng, n_pairs, num_acts)
    got = dfg_count(src, dst, valid, num_activities=num_acts, interpret=True)
    want = dfg_count_ref(src, dst, valid, num_activities=num_acts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- block-size sweep --------------------------------------------------------
@pytest.mark.parametrize("block_e", [512, 1024, 2048])
@pytest.mark.parametrize("block_a", [128, 256])
def test_kernel_block_sizes(block_e, block_a):
    rng = np.random.default_rng(42)
    src, dst, valid = _random_pairs(rng, 3000, 200)
    got = dfg_count(
        src, dst, valid, num_activities=200,
        block_e=block_e, block_a=block_a, interpret=True,
    )
    want = dfg_count_ref(src, dst, valid, num_activities=200)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- input dtype tolerance -----------------------------------------------------
@pytest.mark.parametrize("id_dtype", [np.int32, np.int64, np.int16])
@pytest.mark.parametrize("valid_dtype", [bool, np.int32, np.float32])
def test_kernel_dtypes(id_dtype, valid_dtype):
    rng = np.random.default_rng(7)
    src = rng.integers(0, 50, size=900).astype(id_dtype)
    dst = rng.integers(0, 50, size=900).astype(id_dtype)
    valid = (rng.random(900) < 0.5).astype(valid_dtype)
    got = dfg_count(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(valid),
        num_activities=50, interpret=True,
    )
    want = dfg_count_ref(
        jnp.asarray(src).astype(jnp.int32),
        jnp.asarray(dst).astype(jnp.int32),
        jnp.asarray(valid).astype(jnp.bool_),
        num_activities=50,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- fused dicing vs oracle ----------------------------------------------------
@pytest.mark.parametrize("window", [(0.0, 1.0), (0.2, 0.7), (0.9, 0.95), (2.0, 3.0)])
def test_diced_kernel_matches_ref(window):
    rng = np.random.default_rng(11)
    n, a = 2500, 40
    src, dst, valid = _random_pairs(rng, n, a)
    ts_src = rng.random(n).astype(np.float32)
    ts_dst = rng.random(n).astype(np.float32)
    win = np.asarray(window, dtype=np.float32)
    got = dfg_count_diced(
        src, dst, valid, ts_src, ts_dst, win,
        num_activities=a, interpret=True,
    )
    want = dfg_count_diced_ref(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(valid),
        jnp.asarray(ts_src), jnp.asarray(ts_dst), jnp.asarray(win),
        num_activities=a,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_diced_full_window_equals_undediced():
    rng = np.random.default_rng(3)
    n, a = 1500, 30
    src, dst, valid = _random_pairs(rng, n, a)
    ts = rng.random(n).astype(np.float32)
    win = np.asarray([0.0, 2.0], dtype=np.float32)
    a1 = dfg_count_diced(
        src, dst, valid, ts, ts, win, num_activities=a, interpret=True
    )
    a2 = dfg_count(src, dst, valid, num_activities=a, interpret=True)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


# -- properties ---------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=600),
    a=st.integers(min_value=1, max_value=70),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_property_kernel_equals_ref(n, a, seed):
    rng = np.random.default_rng(seed)
    src, dst, valid = _random_pairs(rng, n, a)
    got = dfg_count(src, dst, valid, num_activities=a, interpret=True)
    want = dfg_count_ref(src, dst, valid, num_activities=a)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=400),
    a=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_property_total_equals_valid_count(n, a, seed):
    rng = np.random.default_rng(seed)
    src, dst, valid = _random_pairs(rng, n, a)
    got = np.asarray(dfg_count(src, dst, valid, num_activities=a, interpret=True))
    assert got.sum() == valid.sum()
    assert (got >= 0).all()


def test_pick_blocks_alignment():
    for a in [1, 26, 127, 128, 500, 5000]:
        be, ba = pick_blocks(a)
        assert be % 512 == 0 and be >= 512
        assert ba in (128, 256, 512)
        # VMEM estimate under budget
        assert 2 * 4 * be * ba + 4 * ba * ba <= (8 << 20) + 4 * ba * ba
